//! Device memory ledger: tracks live allocations (parameters, optimizer
//! state, activation stashes) against a capacity, recording the peak.
//! This is the per-GPU "Memory" column of Table 1 measured rather than
//! assumed.

use anyhow::Result;

#[derive(Clone, Debug)]
pub struct DeviceMem {
    pub capacity: u64,
    used: u64,
    peak: u64,
    /// (label, bytes) of live allocations, for diagnostics.
    live: Vec<(String, u64)>,
}

impl DeviceMem {
    pub fn new(capacity: u64) -> Self {
        Self { capacity, used: 0, peak: 0, live: Vec::new() }
    }

    /// Unbounded device (measurement-only mode).
    pub fn unbounded() -> Self {
        Self::new(u64::MAX)
    }

    pub fn alloc(&mut self, label: &str, bytes: u64) -> Result<()> {
        anyhow::ensure!(
            self.used + bytes <= self.capacity,
            "device OOM: {} + {} > {} (live: {:?})",
            self.used,
            bytes,
            self.capacity,
            self.live
        );
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.live.push((label.to_string(), bytes));
        Ok(())
    }

    /// Free the most recent allocation with this label.
    pub fn free(&mut self, label: &str) -> Result<()> {
        let idx = self
            .live
            .iter()
            .rposition(|(l, _)| l == label)
            .ok_or_else(|| anyhow::anyhow!("free of unknown allocation `{label}`"))?;
        let (_, bytes) = self.live.remove(idx);
        self.used -= bytes;
        Ok(())
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_peak() {
        let mut d = DeviceMem::new(100);
        d.alloc("a", 40).unwrap();
        d.alloc("b", 50).unwrap();
        assert_eq!(d.used(), 90);
        d.free("a").unwrap();
        assert_eq!(d.used(), 50);
        d.alloc("c", 10).unwrap();
        assert_eq!(d.peak(), 90);
    }

    #[test]
    fn oom_is_an_error() {
        let mut d = DeviceMem::new(10);
        assert!(d.alloc("x", 11).is_err());
        d.alloc("x", 10).unwrap();
        assert!(d.alloc("y", 1).is_err());
    }

    #[test]
    fn free_unknown_label_errors() {
        let mut d = DeviceMem::new(10);
        assert!(d.free("ghost").is_err());
    }

    #[test]
    fn lifo_free_with_duplicate_labels() {
        let mut d = DeviceMem::new(100);
        d.alloc("act", 10).unwrap();
        d.alloc("act", 20).unwrap();
        d.free("act").unwrap(); // frees the 20
        assert_eq!(d.used(), 10);
    }
}
