//! Multi-process launcher: one OS process per worker, rendezvousing
//! over a wire transport (`comm::transport`) instead of sharing an
//! in-process fabric.
//!
//! The launcher (`cdp launch`) spawns N copies of its own executable
//! running `cdp worker --worker-id w ...`; each child binds its wire
//! endpoint in the shared rendezvous directory, trains, and worker 0
//! prints one `CDP_LOSS <step> <f64-bits-hex>` line per step so the
//! launcher (and tests) can compare losses *bit*-exactly across process
//! boundaries — text-formatted floats would round.

use std::path::PathBuf;
use std::process::{Command, Output, Stdio};

use anyhow::{Context, Result};

use crate::comm::WireKind;

/// Everything a launch needs to spawn its worker fleet.
pub struct LaunchSpec {
    pub workers: usize,
    pub transport: WireKind,
    /// Shared rendezvous directory (socket files / port files).
    pub rendezvous: PathBuf,
    /// Executable to run; `None` means this process's own binary.
    pub exe: Option<PathBuf>,
    /// Arguments forwarded verbatim to every `cdp worker` child after
    /// the launcher-owned flags (trainer, rule, steps, wire faults...).
    pub forward: Vec<String>,
}

/// Fresh per-launch rendezvous directory under the system temp dir,
/// unique across concurrent launches on the same machine.
pub fn default_rendezvous_dir() -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    std::env::temp_dir().join(format!("cdp-rdv-{}-{nanos}", std::process::id()))
}

/// The command line for worker `w`: launcher-owned flags first, then the
/// spec's forwarded trainer arguments.
pub fn worker_command(spec: &LaunchSpec, w: usize) -> Result<Command> {
    let exe = match &spec.exe {
        Some(p) => p.clone(),
        None => std::env::current_exe().context("locate the cdp executable")?,
    };
    let mut cmd = Command::new(exe);
    cmd.arg("worker")
        .arg("--worker-id")
        .arg(w.to_string())
        .arg("--workers")
        .arg(spec.workers.to_string())
        .arg("--transport")
        .arg(spec.transport.name())
        .arg("--rendezvous")
        .arg(&spec.rendezvous);
    cmd.args(&spec.forward);
    Ok(cmd)
}

/// Spawn the whole fleet, wait for every worker, and fail with the
/// stderr of each non-zero exit.  Outputs come back in rank order with
/// stdout/stderr captured (worker 0's stdout carries the loss lines).
pub fn launch(spec: &LaunchSpec) -> Result<Vec<Output>> {
    anyhow::ensure!(spec.workers >= 2, "a fleet needs at least 2 workers");
    let mut children = Vec::with_capacity(spec.workers);
    for w in 0..spec.workers {
        let mut cmd = worker_command(spec, w)?;
        let child = cmd
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn worker process {w}"))?;
        children.push(child);
    }
    let mut outs = Vec::with_capacity(spec.workers);
    let mut failures = Vec::new();
    for (w, child) in children.into_iter().enumerate() {
        let out = child
            .wait_with_output()
            .with_context(|| format!("wait for worker process {w}"))?;
        if !out.status.success() {
            failures.push(format!(
                "worker {w} exited with {}:\n{}",
                out.status,
                String::from_utf8_lossy(&out.stderr).trim_end()
            ));
        }
        outs.push(out);
    }
    anyhow::ensure!(failures.is_empty(), "{}", failures.join("\n---\n"));
    Ok(outs)
}

/// Extract `(step, loss)` pairs from a worker-0 stdout.  Losses travel
/// as `f64::to_bits` hex so the comparison against an in-process run is
/// exact, not printf-rounded.
pub fn parse_loss_bits(stdout: &str) -> Result<Vec<(u64, f64)>> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        if let Some(rest) = line.strip_prefix("CDP_LOSS ") {
            let mut it = rest.split_whitespace();
            let step: u64 = it
                .next()
                .context("CDP_LOSS line missing step")?
                .parse()
                .context("CDP_LOSS step")?;
            let bits = u64::from_str_radix(
                it.next().context("CDP_LOSS line missing bits")?,
                16,
            )
            .context("CDP_LOSS bits")?;
            out.push((step, f64::from_bits(bits)));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_bits_round_trip_exactly() {
        let losses = [0.123456789f64, -1.5e-300, f64::MIN_POSITIVE, 3.0];
        let stdout: String = losses
            .iter()
            .enumerate()
            .map(|(t, l)| format!("step {t} extraneous line\nCDP_LOSS {t} {:016x}\n", l.to_bits()))
            .collect();
        let got = parse_loss_bits(&stdout).unwrap();
        assert_eq!(got.len(), losses.len());
        for (t, (step, loss)) in got.into_iter().enumerate() {
            assert_eq!(step, t as u64);
            assert_eq!(loss.to_bits(), losses[t].to_bits(), "bit-exact");
        }
    }

    #[test]
    fn malformed_loss_lines_are_errors_not_garbage() {
        assert!(parse_loss_bits("CDP_LOSS").unwrap().is_empty()); // no prefix match
        assert!(parse_loss_bits("CDP_LOSS 3").is_err());
        assert!(parse_loss_bits("CDP_LOSS x 3ff0000000000000").is_err());
        assert!(parse_loss_bits("CDP_LOSS 3 nothex!").is_err());
    }

    #[test]
    fn worker_command_renders_launcher_flags_then_forwarded_args() {
        let spec = LaunchSpec {
            workers: 4,
            transport: WireKind::Uds,
            rendezvous: PathBuf::from("/tmp/rdv"),
            exe: Some(PathBuf::from("/bin/echo")),
            forward: vec!["--trainer".into(), "zero".into()],
        };
        let cmd = worker_command(&spec, 2).unwrap();
        let args: Vec<String> = cmd
            .get_args()
            .map(|a| a.to_string_lossy().into_owned())
            .collect();
        assert_eq!(
            args,
            [
                "worker",
                "--worker-id",
                "2",
                "--workers",
                "4",
                "--transport",
                "uds",
                "--rendezvous",
                "/tmp/rdv",
                "--trainer",
                "zero",
            ]
        );
    }
}
