//! Multi-process launcher: one OS process per worker, rendezvousing
//! over a wire transport (`comm::transport`) instead of sharing an
//! in-process fabric.
//!
//! The launcher (`cdp launch`) spawns N copies of its own executable
//! running `cdp worker --worker-id w ...`; each child binds its wire
//! endpoint in the shared rendezvous directory, trains, and worker 0
//! prints one `CDP_LOSS <step> <f64-bits-hex>` line per step so the
//! launcher (and tests) can compare losses *bit*-exactly across process
//! boundaries — text-formatted floats would round.

use std::path::{Path, PathBuf};
use std::process::{Command, Output, Stdio};

use anyhow::{Context, Result};

use crate::comm::WireKind;

/// Everything a launch needs to spawn its worker fleet.
pub struct LaunchSpec {
    pub workers: usize,
    pub transport: WireKind,
    /// Shared rendezvous directory (socket files / port files).
    pub rendezvous: PathBuf,
    /// Executable to run; `None` means this process's own binary.
    pub exe: Option<PathBuf>,
    /// Arguments forwarded verbatim to every `cdp worker` child after
    /// the launcher-owned flags (trainer, rule, steps, wire faults...).
    pub forward: Vec<String>,
}

/// Fresh per-launch rendezvous directory under the system temp dir,
/// unique across concurrent launches on the same machine.
pub fn default_rendezvous_dir() -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    std::env::temp_dir().join(format!("cdp-rdv-{}-{nanos}", std::process::id()))
}

/// The command line for worker `w`: launcher-owned flags first, then the
/// spec's forwarded trainer arguments.
pub fn worker_command(spec: &LaunchSpec, w: usize) -> Result<Command> {
    let exe = match &spec.exe {
        Some(p) => p.clone(),
        None => std::env::current_exe().context("locate the cdp executable")?,
    };
    let mut cmd = Command::new(exe);
    cmd.arg("worker")
        .arg("--worker-id")
        .arg(w.to_string())
        .arg("--workers")
        .arg(spec.workers.to_string())
        .arg("--transport")
        .arg(spec.transport.name())
        .arg("--rendezvous")
        .arg(&spec.rendezvous);
    cmd.args(&spec.forward);
    Ok(cmd)
}

/// Spawn the whole fleet, wait for every worker, and fail with the
/// stderr of each non-zero exit.  Outputs come back in rank order with
/// stdout/stderr captured (worker 0's stdout carries the loss lines).
pub fn launch(spec: &LaunchSpec) -> Result<Vec<Output>> {
    anyhow::ensure!(spec.workers >= 2, "a fleet needs at least 2 workers");
    let mut children = Vec::with_capacity(spec.workers);
    for w in 0..spec.workers {
        let mut cmd = worker_command(spec, w)?;
        let child = cmd
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn worker process {w}"))?;
        children.push(child);
    }
    let mut outs = Vec::with_capacity(spec.workers);
    let mut failures = Vec::new();
    for (w, child) in children.into_iter().enumerate() {
        let out = child
            .wait_with_output()
            .with_context(|| format!("wait for worker process {w}"))?;
        if !out.status.success() {
            failures.push(format!(
                "worker {w} exited with {}:\n{}",
                out.status,
                String::from_utf8_lossy(&out.stderr).trim_end()
            ));
        }
        outs.push(out);
    }
    anyhow::ensure!(failures.is_empty(), "{}", failures.join("\n---\n"));
    Ok(outs)
}

/// Extract `(step, loss)` pairs from a worker-0 stdout.  Losses travel
/// as `f64::to_bits` hex so the comparison against an in-process run is
/// exact, not printf-rounded.
pub fn parse_loss_bits(stdout: &str) -> Result<Vec<(u64, f64)>> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        if let Some(rest) = line.strip_prefix("CDP_LOSS ") {
            let mut it = rest.split_whitespace();
            let step: u64 = it
                .next()
                .context("CDP_LOSS line missing step")?
                .parse()
                .context("CDP_LOSS step")?;
            let bits = u64::from_str_radix(
                it.next().context("CDP_LOSS line missing bits")?,
                16,
            )
            .context("CDP_LOSS bits")?;
            out.push((step, f64::from_bits(bits)));
        }
    }
    Ok(out)
}

/// The per-worker trace file a `cdp worker --trace-dir DIR` child writes.
pub fn worker_trace_path(dir: &Path, w: usize) -> PathBuf {
    dir.join(format!("trace-w{w}.jsonl"))
}

/// Merge the fleet's per-process trace files (`trace-w{id}.jsonl` under
/// `dir`) into one event stream ordered by worker id, then event order.
/// Missing files are tolerated (a worker may have died before its flush;
/// the merged trace should still analyze) and each file is parsed with
/// the tolerant JSONL reader — `skipped` aggregates corrupt lines and
/// `dropped` the ring overflows across the fleet.
pub fn merge_traces(dir: &Path, workers: usize) -> Result<crate::trace::ParsedTrace> {
    let mut merged = crate::trace::ParsedTrace {
        version: Some(crate::trace::TRACE_MAGIC.to_string()),
        ..Default::default()
    };
    for w in 0..workers {
        let path = worker_trace_path(dir, w);
        if !path.exists() {
            continue;
        }
        let part = crate::trace::parse_jsonl_file(&path)
            .with_context(|| format!("parsing worker {w} trace {}", path.display()))?;
        merged.dropped += part.dropped;
        merged.skipped += part.skipped;
        merged.events.extend(part.events);
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_bits_round_trip_exactly() {
        let losses = [0.123456789f64, -1.5e-300, f64::MIN_POSITIVE, 3.0];
        let stdout: String = losses
            .iter()
            .enumerate()
            .map(|(t, l)| format!("step {t} extraneous line\nCDP_LOSS {t} {:016x}\n", l.to_bits()))
            .collect();
        let got = parse_loss_bits(&stdout).unwrap();
        assert_eq!(got.len(), losses.len());
        for (t, (step, loss)) in got.into_iter().enumerate() {
            assert_eq!(step, t as u64);
            assert_eq!(loss.to_bits(), losses[t].to_bits(), "bit-exact");
        }
    }

    #[test]
    fn malformed_loss_lines_are_errors_not_garbage() {
        assert!(parse_loss_bits("CDP_LOSS").unwrap().is_empty()); // no prefix match
        assert!(parse_loss_bits("CDP_LOSS 3").is_err());
        assert!(parse_loss_bits("CDP_LOSS x 3ff0000000000000").is_err());
        assert!(parse_loss_bits("CDP_LOSS 3 nothex!").is_err());
    }

    #[test]
    fn merge_traces_concatenates_by_worker_and_tolerates_gaps() {
        use crate::trace::{Fields, TraceEvent, TraceKind};
        let dir = std::env::temp_dir().join(format!("cdp-merge-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // worker 0: two events + one ring drop; worker 2: one event with a
        // corrupt line in the middle; worker 1: no file (died before flush)
        let ev = |w: u32, step: u64| {
            TraceEvent::new(
                TraceKind::StepBegin,
                step * 10,
                0,
                Fields { worker: w, step, ..Fields::default() },
            )
        };
        crate::trace::write_jsonl(&worker_trace_path(&dir, 0), &[ev(0, 0), ev(0, 1)], 3)
            .unwrap();
        let mut w2 = crate::trace::to_jsonl(&[ev(2, 0)], 0);
        w2.push_str("{ corrupt trailing line\n");
        std::fs::write(worker_trace_path(&dir, 2), w2).unwrap();

        let merged = merge_traces(&dir, 3).unwrap();
        assert_eq!(merged.events.len(), 3);
        assert_eq!(merged.dropped, 3);
        assert_eq!(merged.skipped, 1);
        let workers: Vec<u32> = merged.events.iter().map(|e| e.worker).collect();
        assert_eq!(workers, vec![0, 0, 2], "rank order, gaps tolerated");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn worker_command_renders_launcher_flags_then_forwarded_args() {
        let spec = LaunchSpec {
            workers: 4,
            transport: WireKind::Uds,
            rendezvous: PathBuf::from("/tmp/rdv"),
            exe: Some(PathBuf::from("/bin/echo")),
            forward: vec!["--trainer".into(), "zero".into()],
        };
        let cmd = worker_command(&spec, 2).unwrap();
        let args: Vec<String> = cmd
            .get_args()
            .map(|a| a.to_string_lossy().into_owned())
            .collect();
        assert_eq!(
            args,
            [
                "worker",
                "--worker-id",
                "2",
                "--workers",
                "4",
                "--transport",
                "uds",
                "--rendezvous",
                "/tmp/rdv",
                "--trainer",
                "zero",
            ]
        );
    }
}
