//! Simulated cluster: device memory models + worker thread helpers
//! (DESIGN.md substitution #1 — each "GPU" is an OS thread with its own
//! state, endpoint and memory ledger).

pub mod device;
pub mod launch;

pub use device::DeviceMem;

use std::thread;

/// Spawn `n` workers and join them, propagating panics.  Returns each
/// worker's result in rank order.
pub fn run_workers<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::with_capacity(n);
    for w in 0..n {
        let f = f.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || f(w))
                .expect("spawn worker"),
        );
    }
    handles
        .into_iter()
        .enumerate()
        .map(|(w, h)| h.join().unwrap_or_else(|_| panic!("worker {w} panicked")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_run_in_rank_order_results() {
        let out = run_workers(4, |w| w * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }
}
