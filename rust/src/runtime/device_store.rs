//! Device-resident model state (DESIGN-PERF.md §Device residency).
//!
//! The literal path rebuilds every stage's parameter literals from arena
//! slices once per *call* — N micro-batches × N stages of host→device
//! parameter conversion per training step, even though within a step each
//! stage runs the same one-or-two θ-versions.  [`DeviceParamStore`] keeps
//! each stage's parameters (and momentum) as persistent `PjRtBuffer`s
//! keyed by θ-version: a buffer is uploaded **once per (stage, committed
//! θ-version)** and then passed by reference execution after execution.
//! The versioning maps 1:1 onto [`crate::parallel::ParamStore`]'s
//! fresh/stale semantics — version `t` is the θ committed at step `t`,
//! and the θ_{−1} := θ_0 bootstrap means step 0's fresh and stale resolve
//! to the *same* resident buffers.
//!
//! [`Executor`] puts the literal path and the device path behind one
//! small surface, so each trainer's schedule logic is written once and
//! the equivalence tests swap the executor: same bundle + same rule +
//! either mode ⇒ bit-identical loss sequences (the device path feeds the
//! exact same f32 payloads to the exact same executables).
//!
//! Crate-API constraint, stated honestly: the `xla` crate returns an
//! execution's result as a *single tuple buffer* (see
//! [`super::execute_buffers`]), with no buffer-level detupling.  Result
//! elements therefore surface as literals; activations that continue to
//! the next stage are re-staged with `buffer_from_host_literal` (one
//! memcpy on the CPU PJRT backend, no host `Tensor` materialized), and
//! the SGD result is promoted to the resident next-version buffers — the
//! single upload that version pays.  What device residency eliminates is
//! the dominant term: per-micro-batch parameter conversion and upload.

use anyhow::Result;

use super::{anyhow_xla, BundleRuntime};
use crate::tensor::{HostTensor, IntTensor, Tensor};

pub use super::backend::ExecMode;

/// A device-resident tensor: one `PjRtBuffer` plus its logical shape.
/// The unit of inter-stage activation hand-off on the device path.
pub struct DeviceTensor {
    buf: xla::PjRtBuffer,
    pub shape: Vec<usize>,
}

impl DeviceTensor {
    pub fn new(buf: xla::PjRtBuffer, shape: Vec<usize>) -> Self {
        Self { buf, shape }
    }

    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }
}

/// Per-stage, per-θ-version cache of resident parameter buffers plus the
/// (unversioned — always current) momentum buffers.
///
/// Upload discipline: `params` uploads a version at most once and evicts
/// versions older than `version − 1`, so at any moment a stage holds at
/// most {stale, fresh, just-installed next} — the same window
/// `ParamStore` rotates through.  `param_uploads()` counts stage-level
/// upload events; the device-resident contract (asserted in the hotpath
/// bench) is ≤ 1 per stage per committed θ-version.
///
/// Drop discipline: resident buffers must not outlive the PJRT client
/// that created them — drop the store (or the trainer owning it) before
/// the `BundleRuntime` whose engine produced the buffers.
/// One resident θ-version: (version id, per-tensor buffers).
type VersionedBufs = (u64, Vec<xla::PjRtBuffer>);

pub struct DeviceParamStore {
    /// stage → resident versions, newest last, ≤ 3 entries.
    params: Vec<Vec<VersionedBufs>>,
    /// stage → momentum buffers (installed by the fused SGD, uploaded
    /// from the host mirror on first use).
    moms: Vec<Option<Vec<xla::PjRtBuffer>>>,
    param_uploads: u64,
}

impl DeviceParamStore {
    pub fn new(n_stages: usize) -> Self {
        Self {
            params: (0..n_stages).map(|_| Vec::new()).collect(),
            moms: (0..n_stages).map(|_| None).collect(),
            param_uploads: 0,
        }
    }

    /// Stage-level parameter upload events so far (the bench metric).
    pub fn param_uploads(&self) -> u64 {
        self.param_uploads
    }

    /// θ-versions currently resident for `stage` (tests/benches).
    pub fn resident_versions(&self, stage: usize) -> Vec<u64> {
        self.params[stage].iter().map(|(v, _)| *v).collect()
    }

    fn evict(&mut self, stage: usize, version: u64) {
        self.params[stage].retain(|(v, _)| *v + 1 >= version);
    }

    /// Resident buffers for (stage, θ-version), uploading from the host
    /// mirror `src` only when the version is not already resident.
    pub fn params(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        src: &[f32],
    ) -> Result<&[xla::PjRtBuffer]> {
        self.evict(stage, version);
        if let Some(pos) =
            self.params[stage].iter().position(|(v, _)| *v == version)
        {
            return Ok(&self.params[stage][pos].1);
        }
        let bufs = rt.upload_stage_run(stage, src)?;
        self.param_uploads += 1;
        rt.transfers.add_param_upload(src.len() as u64 * 4);
        self.params[stage].push((version, bufs));
        Ok(&self.params[stage].last().expect("just pushed").1)
    }

    /// Split borrow for the fused SGD: (θ-version buffers, momentum
    /// buffers), each ensured resident first.
    pub fn params_and_momentum(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        psrc: &[f32],
        msrc: &[f32],
    ) -> Result<(&[xla::PjRtBuffer], &[xla::PjRtBuffer])> {
        self.params(rt, stage, version, psrc)?;
        if self.moms[stage].is_none() {
            let bufs = rt.upload_stage_run(stage, msrc)?;
            rt.transfers.add_h2d(msrc.len() as u64 * 4);
            self.moms[stage] = Some(bufs);
        }
        let pos = self.params[stage]
            .iter()
            .position(|(v, _)| *v == version)
            .expect("ensured above");
        Ok((
            &self.params[stage][pos].1,
            self.moms[stage].as_deref().expect("ensured above"),
        ))
    }

    /// Promote an SGD result to the resident θ_{version} ("donation"):
    /// the displaced θ_{version−2} buffers are dropped, and `version`
    /// pays its single upload here instead of on first use.
    pub(crate) fn install_params(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        lits: &[xla::Literal],
    ) -> Result<()> {
        let mut bufs = Vec::with_capacity(lits.len());
        for lit in lits {
            bufs.push(
                rt.engine
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(anyhow_xla)?,
            );
        }
        self.param_uploads += 1;
        rt.transfers
            .add_param_upload(rt.manifest.stages[stage].param_bytes());
        self.evict(stage, version);
        self.params[stage].push((version, bufs));
        Ok(())
    }

    /// Replace the resident momentum with the SGD result.
    pub(crate) fn install_momentum(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        lits: &[xla::Literal],
    ) -> Result<()> {
        let mut bufs = Vec::with_capacity(lits.len());
        for lit in lits {
            bufs.push(
                rt.engine
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(anyhow_xla)?,
            );
        }
        rt.transfers
            .add_h2d(rt.manifest.stages[stage].param_bytes());
        self.moms[stage] = Some(bufs);
        Ok(())
    }
}

/// An activation as it hands off between stages: a host tensor on the
/// literal path, a resident buffer on the device path.  The two never
/// mix within one executor.
pub enum Act {
    Host(HostTensor),
    Device(DeviceTensor),
}

impl Act {
    /// Payload bytes (activation-traffic accounting in the pipeline).
    pub fn bytes(&self) -> usize {
        match self {
            Act::Host(t) => t.bytes(),
            Act::Device(t) => t.bytes(),
        }
    }

    fn host(&self) -> &HostTensor {
        match self {
            Act::Host(t) => t,
            Act::Device(_) => panic!("device activation on the host path"),
        }
    }

    fn host_f32(&self) -> &Tensor {
        self.host().as_f32().expect("activation must be f32 past stage 0")
    }

    fn device(&self) -> &DeviceTensor {
        match self {
            Act::Device(t) => t,
            Act::Host(_) => panic!("host activation on the device path"),
        }
    }
}

impl super::backend::Activation for Act {
    fn bytes(&self) -> usize {
        Act::bytes(self)
    }
}

/// Per-stage, per-θ-version cache of parameter *literals* for the host
/// path — the literal-layer mirror of [`DeviceParamStore`]'s upload
/// discipline: a (stage, θ-version) builds its literals at most once and
/// evicts versions older than `version − 1`.  Before the backend split
/// the reference trainer kept an equivalent cache per step by hand;
/// keying on the θ-version id moves it behind the [`Executor`] surface so
/// the schedule logic is version-annotated and cache-free.
pub struct LitStore {
    /// stage → resident versions, newest last, ≤ 3 entries.
    params: Vec<Vec<(u64, Vec<xla::Literal>)>>,
}

impl LitStore {
    fn new(n_stages: usize) -> Self {
        Self { params: (0..n_stages).map(|_| Vec::new()).collect() }
    }

    fn params(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        src: &[f32],
    ) -> Result<&[xla::Literal]> {
        self.params[stage].retain(|(v, _)| *v + 1 >= version);
        if let Some(pos) = self.params[stage].iter().position(|(v, _)| *v == version) {
            return Ok(&self.params[stage][pos].1);
        }
        let lits = rt.param_literals_flat(stage, src)?;
        self.params[stage].push((version, lits));
        Ok(&self.params[stage].last().expect("just pushed").1)
    }
}

/// One execution boundary for trainer schedule logic: the literal (host)
/// path or the device-resident path, selected once per trainer.  Every
/// method takes the stage's host flat run + θ-version id — both paths
/// key their per-version caches on the id and read the run only when the
/// version pays its one conversion/upload.
pub enum Executor {
    Host(LitStore),
    Device(DeviceParamStore),
}

impl Executor {
    pub fn new(mode: ExecMode, n_stages: usize) -> Self {
        match mode {
            ExecMode::HostLiteral => Executor::Host(LitStore::new(n_stages)),
            ExecMode::DeviceResident => Executor::Device(DeviceParamStore::new(n_stages)),
        }
    }

    pub fn mode(&self) -> ExecMode {
        match self {
            Executor::Host(_) => ExecMode::HostLiteral,
            Executor::Device(_) => ExecMode::DeviceResident,
        }
    }

    /// The device store, when on the device path (benches/tests).
    pub fn device_store(&self) -> Option<&DeviceParamStore> {
        match self {
            Executor::Host(_) => None,
            Executor::Device(s) => Some(s),
        }
    }

    /// Stage-0 input enters the pipeline (consumes the host tensor; the
    /// device path uploads it once per micro-batch — the batch itself is
    /// the irreducible host→device traffic).
    pub fn input(&self, rt: &BundleRuntime, x: HostTensor) -> Result<Act> {
        match self {
            Executor::Host(_) => Ok(Act::Host(x)),
            Executor::Device(_) => Ok(Act::Device(rt.upload_host(&x)?)),
        }
    }

    /// Forward of a non-loss stage.
    pub fn fwd(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Act,
    ) -> Result<Act> {
        match self {
            Executor::Host(cache) => {
                let lits = cache.params(rt, stage, version, flat)?;
                Ok(Act::Host(HostTensor::F32(rt.stage_fwd_lits(stage, lits, x.host())?)))
            }
            Executor::Device(store) => {
                let p = store.params(rt, stage, version, flat)?;
                Ok(Act::Device(rt.stage_fwd_dev(stage, p, x.device())?))
            }
        }
    }

    /// Backward of the loss stage: grads into `gdst`, returns (loss, gx).
    #[allow(clippy::too_many_arguments)]
    pub fn last_bwd(
        &mut self,
        rt: &BundleRuntime,
        version: u64,
        flat: &[f32],
        x: &Act,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Act)> {
        let last = rt.manifest.n_stages - 1;
        match self {
            Executor::Host(cache) => {
                let lits = cache.params(rt, last, version, flat)?;
                let (loss, gx) =
                    rt.last_bwd_lits_into(lits, x.host_f32(), targets, gdst)?;
                Ok((loss, Act::Host(HostTensor::F32(gx))))
            }
            Executor::Device(store) => {
                let t_dev = rt.upload_targets(targets)?;
                let p = store.params(rt, last, version, flat)?;
                let (loss, gx) = rt.last_bwd_dev(p, x.device(), &t_dev, gdst)?;
                Ok((loss, Act::Device(gx)))
            }
        }
    }

    /// Backward of a middle stage: grads into `gdst`, returns gx.
    #[allow(clippy::too_many_arguments)]
    pub fn mid_bwd(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Act,
        gy: &Act,
        gdst: &mut [f32],
    ) -> Result<Act> {
        match self {
            Executor::Host(cache) => {
                let lits = cache.params(rt, stage, version, flat)?;
                let gx =
                    rt.mid_bwd_lits_into(stage, lits, x.host_f32(), gy.host_f32(), gdst)?;
                Ok(Act::Host(HostTensor::F32(gx)))
            }
            Executor::Device(store) => {
                let p = store.params(rt, stage, version, flat)?;
                Ok(Act::Device(rt.mid_bwd_dev(stage, p, x.device(), gy.device(), gdst)?))
            }
        }
    }

    /// Backward of stage 0: grads into `gdst`.
    #[allow(clippy::too_many_arguments)]
    pub fn first_bwd(
        &mut self,
        rt: &BundleRuntime,
        version: u64,
        flat: &[f32],
        x: &Act,
        gy: &Act,
        gdst: &mut [f32],
    ) -> Result<()> {
        match self {
            Executor::Host(cache) => {
                let lits = cache.params(rt, 0, version, flat)?;
                rt.first_bwd_lits_into(lits, x.host(), gy.host_f32(), gdst)
            }
            Executor::Device(store) => {
                let p = store.params(rt, 0, version, flat)?;
                rt.first_bwd_dev(p, x.device(), gy.device(), gdst)
            }
        }
    }

    /// Fused SGD-momentum for one stage (θ_t at `version` → θ_{version+1}
    /// into `out`); the device path installs the result as the resident
    /// next version.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd(
        &mut self,
        rt: &BundleRuntime,
        stage: usize,
        version: u64,
        cur: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        match self {
            Executor::Host(_) => rt.sgd_update_flat(stage, cur, moms, grads, lr, out),
            Executor::Device(store) => {
                rt.sgd_update_dev(stage, store, version, cur, moms, grads, lr, out)
            }
        }
    }
}
