//! BundleRuntime: one compiled executable per (stage, kind) of a bundle,
//! plus typed execution helpers matching the artifact signatures emitted by
//! `python/compile/aot.py`:
//!
//! - stage 0      fwd(*p, x) -> (y,)            fwdbwd(*p, x, gy) -> (*gp,)
//! - stage mid    fwd(*p, x) -> (y,)            fwdbwd(*p, x, gy) -> (gx, *gp)
//! - stage last   fwd_loss(*p, x, t) -> (loss,) fwdbwd(*p, x, t) -> (loss, gx, *gp)
//!                predict(*p, x) -> (logits,)   [classifiers]
//! - every stage  sgd(*p, *m, *g, lr) -> (*p', *m')

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::literal::{
    host_to_literal, int_tensor_to_literal, literal_into_slice, literal_to_scalar,
    literal_to_tensor, slice_to_literal, tensor_to_literal,
};
use super::{execute_tuple, Engine};
use crate::model::Manifest;
use crate::tensor::{HostTensor, IntTensor, Tensor};
use crate::util::binio;

pub struct BundleRuntime {
    pub manifest: Manifest,
    pub engine: Engine,
    /// (stage, kind) → compiled executable
    exes: HashMap<(usize, String), xla::PjRtLoadedExecutable>,
}

impl BundleRuntime {
    /// Load a bundle directory and compile every artifact it declares.
    pub fn load(dir: &Path) -> Result<Self> {
        let engine = Engine::cpu()?;
        Self::load_with_engine(dir, engine)
    }

    pub fn load_with_engine(dir: &Path, engine: Engine) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let mut exes = HashMap::new();
        for st in &manifest.stages {
            for (kind, file) in &st.artifacts {
                let path = manifest.dir.join(file);
                let exe = engine
                    .compile_hlo_file(&path)
                    .with_context(|| format!("stage {} kind {kind}", st.index))?;
                exes.insert((st.index, kind.clone()), exe);
            }
        }
        Ok(Self { manifest, engine, exes })
    }

    fn exe(&self, stage: usize, kind: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(&(stage, kind.to_string()))
            .with_context(|| format!("no executable for stage {stage} kind {kind}"))
    }

    /// Initial parameters from params.bin, split per stage/param.
    pub fn init_params(&self) -> Result<Vec<Vec<Tensor>>> {
        let raw = binio::read_f32_file(&self.manifest.params_bin())?;
        anyhow::ensure!(
            raw.len() == self.manifest.total_param_elems,
            "params.bin has {} elems, manifest says {}",
            raw.len(),
            self.manifest.total_param_elems
        );
        let mut out = Vec::with_capacity(self.manifest.n_stages);
        let mut off = 0usize;
        for st in &self.manifest.stages {
            let mut stage = Vec::with_capacity(st.params.len());
            for p in &st.params {
                let n = p.elems();
                stage.push(Tensor::new(p.shape.clone(), raw[off..off + n].to_vec()));
                off += n;
            }
            out.push(stage);
        }
        Ok(out)
    }

    /// Zero-initialized momentum buffers matching the parameter layout.
    pub fn zero_like_params(&self) -> Vec<Vec<Tensor>> {
        self.manifest
            .stages
            .iter()
            .map(|st| {
                st.params
                    .iter()
                    .map(|p| Tensor::zeros(p.shape.clone()))
                    .collect()
            })
            .collect()
    }

    /// Initial parameters as one model-wide flat vector (the arena fast
    /// path of [`Self::init_params`] — `params.bin` already *is* the
    /// stage-major flat layout).
    pub fn init_params_flat(&self) -> Result<Vec<f32>> {
        let raw = binio::read_f32_file(&self.manifest.params_bin())?;
        anyhow::ensure!(
            raw.len() == self.manifest.total_param_elems,
            "params.bin has {} elems, manifest says {}",
            raw.len(),
            self.manifest.total_param_elems
        );
        Ok(raw)
    }

    /// Upload one stage's parameters once; reuse across micro-batches
    /// (DESIGN.md §Perf-L3: within a training step the same θ̂ version is
    /// executed N times — caching the literals removes N−1 of the N
    /// host→device conversions per stage).
    pub fn param_literals(&self, params: &[Tensor]) -> Result<Vec<xla::Literal>> {
        params.iter().map(tensor_to_literal).collect()
    }

    /// Literals for one stage straight from its flat arena run: the run is
    /// split by the manifest's parameter views, no `Tensor` materialized.
    pub fn param_literals_flat(&self, stage: usize, flat: &[f32]) -> Result<Vec<xla::Literal>> {
        let specs = &self.manifest.stages[stage].params;
        let mut out = Vec::with_capacity(specs.len());
        let mut off = 0usize;
        for p in specs {
            let n = p.elems();
            out.push(slice_to_literal(&p.shape, &flat[off..off + n])?);
            off += n;
        }
        anyhow::ensure!(
            off == flat.len(),
            "stage {stage}: flat run has {} elems, manifest says {off}",
            flat.len()
        );
        Ok(out)
    }

    // ---- cached-literal execution variants -------------------------------
    pub fn stage_fwd_lits(
        &self,
        stage: usize,
        params: &[xla::Literal],
        x: &HostTensor,
    ) -> Result<Tensor> {
        let x_lit = host_to_literal(x)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        let out = execute_tuple(self.exe(stage, "fwd")?, &args)?;
        let spec = self.manifest.stages[stage].output.as_ref().unwrap();
        literal_to_tensor(&out[0], &spec.shape)
    }

    pub fn first_bwd_lits(
        &self,
        params: &[xla::Literal],
        x: &HostTensor,
        gy: &Tensor,
    ) -> Result<Vec<Tensor>> {
        let x_lit = host_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(0, "fwdbwd")?, &args)?;
        self.unpack_grads(0, &out, 0)
    }

    pub fn mid_bwd_lits(
        &self,
        stage: usize,
        params: &[xla::Literal],
        x: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let x_lit = tensor_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(stage, "fwdbwd")?, &args)?;
        let gx = literal_to_tensor(&out[0], &self.manifest.stages[stage].input.shape)?;
        Ok((gx, self.unpack_grads(stage, &out, 1)?))
    }

    pub fn last_bwd_lits(
        &self,
        params: &[xla::Literal],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<(f32, Tensor, Vec<Tensor>)> {
        let last = self.manifest.n_stages - 1;
        let x_lit = tensor_to_literal(x)?;
        let t_lit = int_tensor_to_literal(targets)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&t_lit);
        let out = execute_tuple(self.exe(last, "fwdbwd")?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        let gx = literal_to_tensor(&out[1], &self.manifest.stages[last].input.shape)?;
        Ok((loss, gx, self.unpack_grads(last, &out, 2)?))
    }

    // ---- flat-arena execution (DESIGN-PERF.md) ---------------------------
    // Parameters arrive as one contiguous stage run; gradients leave by
    // being written straight into the caller's arena slice.  These are the
    // trainers' hot-path entry points — the per-tensor APIs below remain
    // for edges (benches, tools, tests).

    /// Forward of a non-loss stage from a flat parameter run.
    pub fn stage_fwd_flat(
        &self,
        stage: usize,
        flat: &[f32],
        x: &HostTensor,
    ) -> Result<Tensor> {
        let lits = self.param_literals_flat(stage, flat)?;
        self.stage_fwd_lits(stage, &lits, x)
    }

    /// Loss-stage forward from a flat parameter run: scalar loss.
    pub fn last_fwd_loss_flat(
        &self,
        flat: &[f32],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<f32> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals_flat(last, flat)?;
        args.push(tensor_to_literal(x)?);
        args.push(int_tensor_to_literal(targets)?);
        let out = execute_tuple(self.exe(last, "fwd_loss")?, &args)?;
        literal_to_scalar(&out[0])
    }

    /// Classifier logits from a flat parameter run.
    pub fn predict_flat(&self, flat: &[f32], x: &Tensor) -> Result<Tensor> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals_flat(last, flat)?;
        args.push(tensor_to_literal(x)?);
        let out = execute_tuple(self.exe(last, "predict")?, &args)?;
        let elems = out[0].element_count();
        let batch = self.manifest.target.shape[0];
        literal_to_tensor(&out[0], &[batch, elems / batch])
    }

    /// Backward of stage 0: parameter grads written into `gdst`.
    pub fn first_bwd_flat(
        &self,
        flat: &[f32],
        x: &HostTensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<()> {
        let lits = self.param_literals_flat(0, flat)?;
        self.first_bwd_lits_into(&lits, x, gy, gdst)
    }

    /// Backward of a middle stage: grads into `gdst`, returns gx.
    pub fn mid_bwd_flat(
        &self,
        stage: usize,
        flat: &[f32],
        x: &Tensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<Tensor> {
        let lits = self.param_literals_flat(stage, flat)?;
        self.mid_bwd_lits_into(stage, &lits, x, gy, gdst)
    }

    /// Backward of the loss stage: grads into `gdst`, returns (loss, gx).
    pub fn last_bwd_flat(
        &self,
        flat: &[f32],
        x: &Tensor,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Tensor)> {
        let last = self.manifest.n_stages - 1;
        let lits = self.param_literals_flat(last, flat)?;
        self.last_bwd_lits_into(&lits, x, targets, gdst)
    }

    /// Cached-literal variant of [`Self::first_bwd_flat`].
    pub fn first_bwd_lits_into(
        &self,
        params: &[xla::Literal],
        x: &HostTensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<()> {
        let x_lit = host_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(0, "fwdbwd")?, &args)?;
        self.unpack_grads_into(0, &out, 0, gdst)
    }

    /// Cached-literal variant of [`Self::mid_bwd_flat`].
    pub fn mid_bwd_lits_into(
        &self,
        stage: usize,
        params: &[xla::Literal],
        x: &Tensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<Tensor> {
        let x_lit = tensor_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(stage, "fwdbwd")?, &args)?;
        let gx = literal_to_tensor(&out[0], &self.manifest.stages[stage].input.shape)?;
        self.unpack_grads_into(stage, &out, 1, gdst)?;
        Ok(gx)
    }

    /// Cached-literal variant of [`Self::last_bwd_flat`].
    pub fn last_bwd_lits_into(
        &self,
        params: &[xla::Literal],
        x: &Tensor,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Tensor)> {
        let last = self.manifest.n_stages - 1;
        let x_lit = tensor_to_literal(x)?;
        let t_lit = int_tensor_to_literal(targets)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&t_lit);
        let out = execute_tuple(self.exe(last, "fwdbwd")?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        let gx = literal_to_tensor(&out[1], &self.manifest.stages[last].input.shape)?;
        self.unpack_grads_into(last, &out, 2, gdst)?;
        Ok((loss, gx))
    }

    /// Fused SGD-momentum over flat stage runs: reads θ_t from `params`,
    /// updates `moms` in place, writes θ_{t+1} into `out` (which may be a
    /// [`crate::parallel::ParamStore`] next-slot — see `update_parts`).
    pub fn sgd_update_flat(
        &self,
        stage: usize,
        params: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let specs = &self.manifest.stages[stage].params;
        let k = specs.len();
        anyhow::ensure!(
            params.len() == moms.len()
                && params.len() == grads.len()
                && params.len() == out.len(),
            "stage {stage}: flat run length mismatch"
        );
        let mut args = Vec::with_capacity(3 * k + 1);
        for src in [params, &*moms, grads] {
            let mut off = 0usize;
            for p in specs {
                let n = p.elems();
                args.push(slice_to_literal(&p.shape, &src[off..off + n])?);
                off += n;
            }
            anyhow::ensure!(off == src.len(), "stage {stage}: run/manifest mismatch");
        }
        args.push(tensor_to_literal(&Tensor::scalar(lr))?);
        let res = execute_tuple(self.exe(stage, "sgd")?, &args)?;
        anyhow::ensure!(res.len() == 2 * k, "sgd returned {} outputs", res.len());
        let mut off = 0usize;
        for (i, p) in specs.iter().enumerate() {
            let n = p.elems();
            literal_into_slice(&res[i], &mut out[off..off + n])?;
            literal_into_slice(&res[k + i], &mut moms[off..off + n])?;
            off += n;
        }
        Ok(())
    }

    /// Unpack per-parameter gradient literals straight into a contiguous
    /// stage run (skipping `skip` leading non-grad outputs).
    fn unpack_grads_into(
        &self,
        stage: usize,
        out: &[xla::Literal],
        skip: usize,
        dst: &mut [f32],
    ) -> Result<()> {
        let specs = &self.manifest.stages[stage].params;
        anyhow::ensure!(
            out.len() == skip + specs.len(),
            "stage {stage}: expected {} outputs, got {}",
            skip + specs.len(),
            out.len()
        );
        let mut off = 0usize;
        for (i, p) in specs.iter().enumerate() {
            let n = p.elems();
            literal_into_slice(&out[skip + i], &mut dst[off..off + n])?;
            off += n;
        }
        anyhow::ensure!(
            off == dst.len(),
            "stage {stage}: grad run has {} elems, manifest says {off}",
            dst.len()
        );
        Ok(())
    }

    // ---- forward ---------------------------------------------------------
    /// Forward of a non-loss stage.
    pub fn stage_fwd(
        &self,
        stage: usize,
        params: &[Tensor],
        x: &HostTensor,
    ) -> Result<Tensor> {
        let mut args = self.param_literals(params)?;
        args.push(host_to_literal(x)?);
        let out = execute_tuple(self.exe(stage, "fwd")?, &args)?;
        let spec = self.manifest.stages[stage].output.as_ref().unwrap();
        literal_to_tensor(&out[0], &spec.shape)
    }

    /// Loss-stage forward: returns the scalar loss.
    pub fn last_fwd_loss(
        &self,
        params: &[Tensor],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<f32> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        args.push(int_tensor_to_literal(targets)?);
        let out = execute_tuple(self.exe(last, "fwd_loss")?, &args)?;
        literal_to_scalar(&out[0])
    }

    /// Classifier logits (loss stage without the loss).
    pub fn predict(&self, params: &[Tensor], x: &Tensor) -> Result<Tensor> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        let out = execute_tuple(self.exe(last, "predict")?, &args)?;
        let elems = out[0].element_count();
        let batch = self.manifest.target.shape[0];
        literal_to_tensor(&out[0], &[batch, elems / batch])
    }

    // ---- backward --------------------------------------------------------
    /// Backward of stage 0: gradient w.r.t. params only.
    pub fn first_bwd(
        &self,
        params: &[Tensor],
        x: &HostTensor,
        gy: &Tensor,
    ) -> Result<Vec<Tensor>> {
        let mut args = self.param_literals(params)?;
        args.push(host_to_literal(x)?);
        args.push(tensor_to_literal(gy)?);
        let out = execute_tuple(self.exe(0, "fwdbwd")?, &args)?;
        self.unpack_grads(0, &out, 0)
    }

    /// Backward of a middle stage: (gx, grads).
    pub fn mid_bwd(
        &self,
        stage: usize,
        params: &[Tensor],
        x: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        args.push(tensor_to_literal(gy)?);
        let out = execute_tuple(self.exe(stage, "fwdbwd")?, &args)?;
        let gx = literal_to_tensor(&out[0], &self.manifest.stages[stage].input.shape)?;
        Ok((gx, self.unpack_grads(stage, &out, 1)?))
    }

    /// Backward of the loss stage: (loss, gx, grads).
    pub fn last_bwd(
        &self,
        params: &[Tensor],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<(f32, Tensor, Vec<Tensor>)> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        args.push(int_tensor_to_literal(targets)?);
        let out = execute_tuple(self.exe(last, "fwdbwd")?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        let gx = literal_to_tensor(&out[1], &self.manifest.stages[last].input.shape)?;
        Ok((loss, gx, self.unpack_grads(last, &out, 2)?))
    }

    fn unpack_grads(
        &self,
        stage: usize,
        out: &[xla::Literal],
        skip: usize,
    ) -> Result<Vec<Tensor>> {
        let specs = &self.manifest.stages[stage].params;
        anyhow::ensure!(
            out.len() == skip + specs.len(),
            "stage {stage}: expected {} outputs, got {}",
            skip + specs.len(),
            out.len()
        );
        specs
            .iter()
            .enumerate()
            .map(|(i, p)| literal_to_tensor(&out[skip + i], &p.shape))
            .collect()
    }

    // ---- optimizer -------------------------------------------------------
    /// Fused SGD-momentum for one stage: updates params and moms in place.
    pub fn sgd_update(
        &self,
        stage: usize,
        params: &mut [Tensor],
        moms: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
    ) -> Result<()> {
        let k = params.len();
        anyhow::ensure!(moms.len() == k && grads.len() == k);
        let mut args = Vec::with_capacity(3 * k + 1);
        for p in params.iter() {
            args.push(tensor_to_literal(p)?);
        }
        for m in moms.iter() {
            args.push(tensor_to_literal(m)?);
        }
        for g in grads.iter() {
            args.push(tensor_to_literal(g)?);
        }
        args.push(tensor_to_literal(&Tensor::scalar(lr))?);
        let out = execute_tuple(self.exe(stage, "sgd")?, &args)?;
        anyhow::ensure!(out.len() == 2 * k, "sgd returned {} outputs", out.len());
        for i in 0..k {
            params[i] = literal_to_tensor(&out[i], &params[i].shape.clone())?;
            moms[i] = literal_to_tensor(&out[k + i], &moms[i].shape.clone())?;
        }
        Ok(())
    }
}
