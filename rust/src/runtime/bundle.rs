//! BundleRuntime: one compiled executable per (stage, kind) of a bundle,
//! plus typed execution helpers matching the artifact signatures emitted by
//! `python/compile/aot.py`:
//!
//! - stage 0      fwd(*p, x) -> (y,)            fwdbwd(*p, x, gy) -> (*gp,)
//! - stage mid    fwd(*p, x) -> (y,)            fwdbwd(*p, x, gy) -> (gx, *gp)
//! - stage last   fwd_loss(*p, x, t) -> (loss,) fwdbwd(*p, x, t) -> (loss, gx, *gp)
//!                predict(*p, x) -> (logits,)   [classifiers]
//! - every stage  sgd(*p, *m, *g, lr) -> (*p', *m')

use std::path::Path;

use anyhow::{Context, Result};

use super::backend::{Backend, ExecMode};
use super::device_store::{Act, DeviceParamStore, DeviceTensor, Executor};
use super::literal::{
    host_to_literal, int_tensor_to_literal, literal_into_slice, literal_to_scalar,
    literal_to_tensor, slice_to_literal, tensor_to_literal,
};
use super::{anyhow_xla, execute_buffers, execute_tuple, Engine, TransferStats};
use crate::model::Manifest;
use crate::tensor::{HostTensor, IntTensor, Tensor};
use crate::util::binio;

/// Artifact kinds a bundle can declare, as a closed enum so the per-call
/// executable lookup is a pair of array indexes — the former
/// `HashMap<(usize, String), _>` key allocated a `String` per lookup on
/// the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    Fwd,
    FwdBwd,
    FwdLoss,
    Predict,
    Sgd,
}

impl Kind {
    pub const COUNT: usize = 5;

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fwd" => Some(Kind::Fwd),
            "fwdbwd" => Some(Kind::FwdBwd),
            "fwd_loss" => Some(Kind::FwdLoss),
            "predict" => Some(Kind::Predict),
            "sgd" => Some(Kind::Sgd),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Fwd => "fwd",
            Kind::FwdBwd => "fwdbwd",
            Kind::FwdLoss => "fwd_loss",
            Kind::Predict => "predict",
            Kind::Sgd => "sgd",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

pub struct BundleRuntime {
    pub manifest: Manifest,
    pub engine: Engine,
    /// Host↔device transfer accounting across both execution paths.
    pub transfers: TransferStats,
    /// Per stage, per [`Kind`] — allocation-free lookup.
    exes: Vec<[Option<xla::PjRtLoadedExecutable>; Kind::COUNT]>,
}

impl BundleRuntime {
    /// Load a bundle directory and compile every artifact it declares.
    pub fn load(dir: &Path) -> Result<Self> {
        let engine = Engine::cpu()?;
        Self::load_with_engine(dir, engine)
    }

    pub fn load_with_engine(dir: &Path, engine: Engine) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let mut exes: Vec<[Option<xla::PjRtLoadedExecutable>; Kind::COUNT]> =
            (0..manifest.n_stages).map(|_| Default::default()).collect();
        for st in &manifest.stages {
            for (kind, file) in &st.artifacts {
                // tolerate kinds this build does not know (a newer
                // exporter may ship extra artifacts) — the seed behavior;
                // only the five Kind entries are ever dispatched to
                let Some(k) = Kind::parse(kind) else {
                    eprintln!(
                        "bundle {}: stage {} skipping unknown artifact kind `{kind}` \
                         (known: fwd, fwdbwd, fwd_loss, predict, sgd)",
                        manifest.name, st.index
                    );
                    continue;
                };
                let path = manifest.dir.join(file);
                let exe = engine
                    .compile_hlo_file(&path)
                    .with_context(|| format!("stage {} kind {kind}", st.index))?;
                exes[st.index][k.index()] = Some(exe);
            }
        }
        Ok(Self { manifest, engine, transfers: TransferStats::default(), exes })
    }

    fn exe(&self, stage: usize, kind: Kind) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(stage)
            .and_then(|per_stage| per_stage[kind.index()].as_ref())
            .with_context(|| {
                format!("no executable for stage {stage} kind {}", kind.as_str())
            })
    }

    /// Initial parameters from params.bin, split per stage/param.
    pub fn init_params(&self) -> Result<Vec<Vec<Tensor>>> {
        let raw = binio::read_f32_file(&self.manifest.params_bin())?;
        anyhow::ensure!(
            raw.len() == self.manifest.total_param_elems,
            "params.bin has {} elems, manifest says {}",
            raw.len(),
            self.manifest.total_param_elems
        );
        let mut out = Vec::with_capacity(self.manifest.n_stages);
        let mut off = 0usize;
        for st in &self.manifest.stages {
            let mut stage = Vec::with_capacity(st.params.len());
            for p in &st.params {
                let n = p.elems();
                stage.push(Tensor::new(p.shape.clone(), raw[off..off + n].to_vec()));
                off += n;
            }
            out.push(stage);
        }
        Ok(out)
    }

    /// Zero-initialized momentum buffers matching the parameter layout.
    pub fn zero_like_params(&self) -> Vec<Vec<Tensor>> {
        self.manifest
            .stages
            .iter()
            .map(|st| {
                st.params
                    .iter()
                    .map(|p| Tensor::zeros(p.shape.clone()))
                    .collect()
            })
            .collect()
    }

    /// Initial parameters as one model-wide flat vector (the arena fast
    /// path of [`Self::init_params`] — `params.bin` already *is* the
    /// stage-major flat layout).
    pub fn init_params_flat(&self) -> Result<Vec<f32>> {
        let raw = binio::read_f32_file(&self.manifest.params_bin())?;
        anyhow::ensure!(
            raw.len() == self.manifest.total_param_elems,
            "params.bin has {} elems, manifest says {}",
            raw.len(),
            self.manifest.total_param_elems
        );
        Ok(raw)
    }

    /// Upload one stage's parameters once; reuse across micro-batches
    /// (DESIGN.md §Perf-L3: within a training step the same θ̂ version is
    /// executed N times — caching the literals removes N−1 of the N
    /// host→device conversions per stage).
    pub fn param_literals(&self, params: &[Tensor]) -> Result<Vec<xla::Literal>> {
        self.transfers
            .add_param_upload(params.iter().map(|t| t.bytes() as u64).sum());
        params.iter().map(tensor_to_literal).collect()
    }

    /// Literals for one stage straight from its flat arena run: the run is
    /// split by the manifest's parameter views, no `Tensor` materialized.
    pub fn param_literals_flat(&self, stage: usize, flat: &[f32]) -> Result<Vec<xla::Literal>> {
        self.transfers.add_param_upload(flat.len() as u64 * 4);
        let specs = &self.manifest.stages[stage].params;
        let mut out = Vec::with_capacity(specs.len());
        let mut off = 0usize;
        for p in specs {
            let n = p.elems();
            out.push(slice_to_literal(&p.shape, &flat[off..off + n])?);
            off += n;
        }
        anyhow::ensure!(
            off == flat.len(),
            "stage {stage}: flat run has {} elems, manifest says {off}",
            flat.len()
        );
        Ok(out)
    }

    // ---- cached-literal execution variants -------------------------------
    pub fn stage_fwd_lits(
        &self,
        stage: usize,
        params: &[xla::Literal],
        x: &HostTensor,
    ) -> Result<Tensor> {
        let x_lit = host_to_literal(x)?;
        self.transfers.add_h2d(x.bytes() as u64);
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        let out = execute_tuple(self.exe(stage, Kind::Fwd)?, &args)?;
        let spec = self.manifest.stages[stage].output.as_ref().unwrap();
        self.transfers.add_d2h(spec.bytes() as u64);
        literal_to_tensor(&out[0], &spec.shape)
    }

    pub fn first_bwd_lits(
        &self,
        params: &[xla::Literal],
        x: &HostTensor,
        gy: &Tensor,
    ) -> Result<Vec<Tensor>> {
        let x_lit = host_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(0, Kind::FwdBwd)?, &args)?;
        self.unpack_grads(0, &out, 0)
    }

    pub fn mid_bwd_lits(
        &self,
        stage: usize,
        params: &[xla::Literal],
        x: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let x_lit = tensor_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(stage, Kind::FwdBwd)?, &args)?;
        let gx = literal_to_tensor(&out[0], &self.manifest.stages[stage].input.shape)?;
        Ok((gx, self.unpack_grads(stage, &out, 1)?))
    }

    pub fn last_bwd_lits(
        &self,
        params: &[xla::Literal],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<(f32, Tensor, Vec<Tensor>)> {
        let last = self.manifest.n_stages - 1;
        let x_lit = tensor_to_literal(x)?;
        let t_lit = int_tensor_to_literal(targets)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&t_lit);
        let out = execute_tuple(self.exe(last, Kind::FwdBwd)?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        let gx = literal_to_tensor(&out[1], &self.manifest.stages[last].input.shape)?;
        Ok((loss, gx, self.unpack_grads(last, &out, 2)?))
    }

    // ---- flat-arena execution (DESIGN-PERF.md) ---------------------------
    // Parameters arrive as one contiguous stage run; gradients leave by
    // being written straight into the caller's arena slice.  These are the
    // trainers' hot-path entry points — the per-tensor APIs below remain
    // for edges (benches, tools, tests).

    /// Forward of a non-loss stage from a flat parameter run.
    pub fn stage_fwd_flat(
        &self,
        stage: usize,
        flat: &[f32],
        x: &HostTensor,
    ) -> Result<Tensor> {
        let lits = self.param_literals_flat(stage, flat)?;
        self.stage_fwd_lits(stage, &lits, x)
    }

    /// Loss-stage forward from a flat parameter run: scalar loss.
    pub fn last_fwd_loss_flat(
        &self,
        flat: &[f32],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<f32> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals_flat(last, flat)?;
        args.push(tensor_to_literal(x)?);
        args.push(int_tensor_to_literal(targets)?);
        let out = execute_tuple(self.exe(last, Kind::FwdLoss)?, &args)?;
        literal_to_scalar(&out[0])
    }

    /// Classifier logits from a flat parameter run.
    pub fn predict_flat(&self, flat: &[f32], x: &Tensor) -> Result<Tensor> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals_flat(last, flat)?;
        args.push(tensor_to_literal(x)?);
        let out = execute_tuple(self.exe(last, Kind::Predict)?, &args)?;
        let elems = out[0].element_count();
        let batch = self.manifest.target.shape[0];
        literal_to_tensor(&out[0], &[batch, elems / batch])
    }

    /// Backward of stage 0: parameter grads written into `gdst`.
    pub fn first_bwd_flat(
        &self,
        flat: &[f32],
        x: &HostTensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<()> {
        let lits = self.param_literals_flat(0, flat)?;
        self.first_bwd_lits_into(&lits, x, gy, gdst)
    }

    /// Backward of a middle stage: grads into `gdst`, returns gx.
    pub fn mid_bwd_flat(
        &self,
        stage: usize,
        flat: &[f32],
        x: &Tensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<Tensor> {
        let lits = self.param_literals_flat(stage, flat)?;
        self.mid_bwd_lits_into(stage, &lits, x, gy, gdst)
    }

    /// Backward of the loss stage: grads into `gdst`, returns (loss, gx).
    pub fn last_bwd_flat(
        &self,
        flat: &[f32],
        x: &Tensor,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Tensor)> {
        let last = self.manifest.n_stages - 1;
        let lits = self.param_literals_flat(last, flat)?;
        self.last_bwd_lits_into(&lits, x, targets, gdst)
    }

    /// Cached-literal variant of [`Self::first_bwd_flat`].
    pub fn first_bwd_lits_into(
        &self,
        params: &[xla::Literal],
        x: &HostTensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<()> {
        let x_lit = host_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        self.transfers.add_h2d((x.bytes() + gy.bytes()) as u64);
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(0, Kind::FwdBwd)?, &args)?;
        self.transfers.add_d2h(gdst.len() as u64 * 4);
        self.unpack_grads_into(0, &out, 0, gdst)
    }

    /// Cached-literal variant of [`Self::mid_bwd_flat`].
    pub fn mid_bwd_lits_into(
        &self,
        stage: usize,
        params: &[xla::Literal],
        x: &Tensor,
        gy: &Tensor,
        gdst: &mut [f32],
    ) -> Result<Tensor> {
        let x_lit = tensor_to_literal(x)?;
        let gy_lit = tensor_to_literal(gy)?;
        self.transfers.add_h2d((x.bytes() + gy.bytes()) as u64);
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&gy_lit);
        let out = execute_tuple(self.exe(stage, Kind::FwdBwd)?, &args)?;
        let gx = literal_to_tensor(&out[0], &self.manifest.stages[stage].input.shape)?;
        self.transfers.add_d2h((gx.bytes() + gdst.len() * 4) as u64);
        self.unpack_grads_into(stage, &out, 1, gdst)?;
        Ok(gx)
    }

    /// Cached-literal variant of [`Self::last_bwd_flat`].
    pub fn last_bwd_lits_into(
        &self,
        params: &[xla::Literal],
        x: &Tensor,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Tensor)> {
        let last = self.manifest.n_stages - 1;
        let x_lit = tensor_to_literal(x)?;
        let t_lit = int_tensor_to_literal(targets)?;
        self.transfers
            .add_h2d((x.bytes() + targets.data.len() * 4) as u64);
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_lit);
        args.push(&t_lit);
        let out = execute_tuple(self.exe(last, Kind::FwdBwd)?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        let gx = literal_to_tensor(&out[1], &self.manifest.stages[last].input.shape)?;
        self.transfers
            .add_d2h((4 + gx.bytes() + gdst.len() * 4) as u64);
        self.unpack_grads_into(last, &out, 2, gdst)?;
        Ok((loss, gx))
    }

    /// Fused SGD-momentum over flat stage runs: reads θ_t from `params`,
    /// updates `moms` in place, writes θ_{t+1} into `out` (which may be a
    /// [`crate::parallel::ParamStore`] next-slot — see `update_parts`).
    pub fn sgd_update_flat(
        &self,
        stage: usize,
        params: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let specs = &self.manifest.stages[stage].params;
        let k = specs.len();
        anyhow::ensure!(
            params.len() == moms.len()
                && params.len() == grads.len()
                && params.len() == out.len(),
            "stage {stage}: flat run length mismatch"
        );
        let mut args = Vec::with_capacity(3 * k + 1);
        for src in [params, &*moms, grads] {
            let mut off = 0usize;
            for p in specs {
                let n = p.elems();
                args.push(slice_to_literal(&p.shape, &src[off..off + n])?);
                off += n;
            }
            anyhow::ensure!(off == src.len(), "stage {stage}: run/manifest mismatch");
        }
        args.push(tensor_to_literal(&Tensor::scalar(lr))?);
        self.transfers.add_h2d(3 * params.len() as u64 * 4 + 4);
        self.transfers.add_d2h(2 * params.len() as u64 * 4);
        let res = execute_tuple(self.exe(stage, Kind::Sgd)?, &args)?;
        anyhow::ensure!(res.len() == 2 * k, "sgd returned {} outputs", res.len());
        let mut off = 0usize;
        for (i, p) in specs.iter().enumerate() {
            let n = p.elems();
            literal_into_slice(&res[i], &mut out[off..off + n])?;
            literal_into_slice(&res[k + i], &mut moms[off..off + n])?;
            off += n;
        }
        Ok(())
    }

    /// Unpack per-parameter gradient literals straight into a contiguous
    /// stage run (skipping `skip` leading non-grad outputs).
    fn unpack_grads_into(
        &self,
        stage: usize,
        out: &[xla::Literal],
        skip: usize,
        dst: &mut [f32],
    ) -> Result<()> {
        let specs = &self.manifest.stages[stage].params;
        anyhow::ensure!(
            out.len() == skip + specs.len(),
            "stage {stage}: expected {} outputs, got {}",
            skip + specs.len(),
            out.len()
        );
        let mut off = 0usize;
        for (i, p) in specs.iter().enumerate() {
            let n = p.elems();
            literal_into_slice(&out[skip + i], &mut dst[off..off + n])?;
            off += n;
        }
        anyhow::ensure!(
            off == dst.len(),
            "stage {stage}: grad run has {} elems, manifest says {off}",
            dst.len()
        );
        Ok(())
    }

    // ---- forward ---------------------------------------------------------
    /// Forward of a non-loss stage.
    pub fn stage_fwd(
        &self,
        stage: usize,
        params: &[Tensor],
        x: &HostTensor,
    ) -> Result<Tensor> {
        let mut args = self.param_literals(params)?;
        args.push(host_to_literal(x)?);
        let out = execute_tuple(self.exe(stage, Kind::Fwd)?, &args)?;
        let spec = self.manifest.stages[stage].output.as_ref().unwrap();
        literal_to_tensor(&out[0], &spec.shape)
    }

    /// Loss-stage forward: returns the scalar loss.
    pub fn last_fwd_loss(
        &self,
        params: &[Tensor],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<f32> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        args.push(int_tensor_to_literal(targets)?);
        let out = execute_tuple(self.exe(last, Kind::FwdLoss)?, &args)?;
        literal_to_scalar(&out[0])
    }

    /// Classifier logits (loss stage without the loss).
    pub fn predict(&self, params: &[Tensor], x: &Tensor) -> Result<Tensor> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        let out = execute_tuple(self.exe(last, Kind::Predict)?, &args)?;
        let elems = out[0].element_count();
        let batch = self.manifest.target.shape[0];
        literal_to_tensor(&out[0], &[batch, elems / batch])
    }

    // ---- backward --------------------------------------------------------
    /// Backward of stage 0: gradient w.r.t. params only.
    pub fn first_bwd(
        &self,
        params: &[Tensor],
        x: &HostTensor,
        gy: &Tensor,
    ) -> Result<Vec<Tensor>> {
        let mut args = self.param_literals(params)?;
        args.push(host_to_literal(x)?);
        args.push(tensor_to_literal(gy)?);
        let out = execute_tuple(self.exe(0, Kind::FwdBwd)?, &args)?;
        self.unpack_grads(0, &out, 0)
    }

    /// Backward of a middle stage: (gx, grads).
    pub fn mid_bwd(
        &self,
        stage: usize,
        params: &[Tensor],
        x: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        args.push(tensor_to_literal(gy)?);
        let out = execute_tuple(self.exe(stage, Kind::FwdBwd)?, &args)?;
        let gx = literal_to_tensor(&out[0], &self.manifest.stages[stage].input.shape)?;
        Ok((gx, self.unpack_grads(stage, &out, 1)?))
    }

    /// Backward of the loss stage: (loss, gx, grads).
    pub fn last_bwd(
        &self,
        params: &[Tensor],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<(f32, Tensor, Vec<Tensor>)> {
        let last = self.manifest.n_stages - 1;
        let mut args = self.param_literals(params)?;
        args.push(tensor_to_literal(x)?);
        args.push(int_tensor_to_literal(targets)?);
        let out = execute_tuple(self.exe(last, Kind::FwdBwd)?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        let gx = literal_to_tensor(&out[1], &self.manifest.stages[last].input.shape)?;
        Ok((loss, gx, self.unpack_grads(last, &out, 2)?))
    }

    fn unpack_grads(
        &self,
        stage: usize,
        out: &[xla::Literal],
        skip: usize,
    ) -> Result<Vec<Tensor>> {
        let specs = &self.manifest.stages[stage].params;
        anyhow::ensure!(
            out.len() == skip + specs.len(),
            "stage {stage}: expected {} outputs, got {}",
            skip + specs.len(),
            out.len()
        );
        specs
            .iter()
            .enumerate()
            .map(|(i, p)| literal_to_tensor(&out[skip + i], &p.shape))
            .collect()
    }

    // ---- optimizer -------------------------------------------------------
    /// Fused SGD-momentum for one stage: updates params and moms in place.
    pub fn sgd_update(
        &self,
        stage: usize,
        params: &mut [Tensor],
        moms: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
    ) -> Result<()> {
        let k = params.len();
        anyhow::ensure!(moms.len() == k && grads.len() == k);
        let mut args = Vec::with_capacity(3 * k + 1);
        for p in params.iter() {
            args.push(tensor_to_literal(p)?);
        }
        for m in moms.iter() {
            args.push(tensor_to_literal(m)?);
        }
        for g in grads.iter() {
            args.push(tensor_to_literal(g)?);
        }
        args.push(tensor_to_literal(&Tensor::scalar(lr))?);
        let out = execute_tuple(self.exe(stage, Kind::Sgd)?, &args)?;
        anyhow::ensure!(out.len() == 2 * k, "sgd returned {} outputs", out.len());
        // write through the existing allocations — no shape clone, no
        // fresh Tensor per parameter per call
        for i in 0..k {
            literal_into_slice(&out[i], &mut params[i].data)?;
            literal_into_slice(&out[k + i], &mut moms[i].data)?;
        }
        Ok(())
    }

    // ---- device-resident execution (DESIGN-PERF.md §Device residency) ----
    // Parameters and momentum live as persistent `PjRtBuffer`s in a
    // [`DeviceParamStore`]; inter-stage activations hand off as
    // [`DeviceTensor`]s.  Micro-batches move no parameter bytes at all —
    // buffers are passed by reference execution after execution, and a
    // (stage, θ-version) uploads at most once.  Gradients still come
    // back to the host each micro-batch (they feed the comm fabric and
    // the `GradBuffer` determinism contract).

    /// Upload a host input (stage-0 batch) to the device.
    pub fn upload_host(&self, x: &HostTensor) -> Result<DeviceTensor> {
        let buf = match x {
            HostTensor::F32(t) => self
                .engine
                .client
                .buffer_from_host_buffer(&t.data, &t.shape, None)
                .map_err(anyhow_xla)?,
            HostTensor::I32(t) => self
                .engine
                .client
                .buffer_from_host_buffer(&t.data, &t.shape, None)
                .map_err(anyhow_xla)?,
        };
        self.transfers.add_h2d(x.bytes() as u64);
        Ok(DeviceTensor::new(buf, x.shape().to_vec()))
    }

    /// Upload loss-stage targets to the device.
    pub fn upload_targets(&self, t: &IntTensor) -> Result<DeviceTensor> {
        let buf = self
            .engine
            .client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(anyhow_xla)?;
        self.transfers.add_h2d(t.data.len() as u64 * 4);
        Ok(DeviceTensor::new(buf, t.shape.clone()))
    }

    /// Re-stage a result-tuple element as a device buffer for the next
    /// stage.  The crate's execute returns one tuple buffer (see
    /// [`execute_buffers`]), so elements surface as literals; promoting
    /// one back to a buffer is a single memcpy on the CPU PJRT backend
    /// and materializes no host `Tensor`.
    fn restage(&self, lit: &xla::Literal, shape: &[usize]) -> Result<DeviceTensor> {
        let buf = self
            .engine
            .client
            .buffer_from_host_literal(None, lit)
            .map_err(anyhow_xla)?;
        let bytes = shape.iter().product::<usize>() as u64 * 4;
        self.transfers.add_d2h(bytes);
        self.transfers.add_h2d(bytes);
        Ok(DeviceTensor::new(buf, shape.to_vec()))
    }

    /// Forward of a non-loss stage, fully on device: resident parameter
    /// buffers + device activation in, device activation out.
    pub fn stage_fwd_dev(
        &self,
        stage: usize,
        params: &[xla::PjRtBuffer],
        x: &DeviceTensor,
    ) -> Result<DeviceTensor> {
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(x.buffer());
        let out = execute_buffers(self.exe(stage, Kind::Fwd)?, &args)?;
        let spec = self.manifest.stages[stage].output.as_ref().unwrap();
        self.restage(&out[0], &spec.shape)
    }

    /// Backward of stage 0 on device: parameter grads land in `gdst`.
    pub fn first_bwd_dev(
        &self,
        params: &[xla::PjRtBuffer],
        x: &DeviceTensor,
        gy: &DeviceTensor,
        gdst: &mut [f32],
    ) -> Result<()> {
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(x.buffer());
        args.push(gy.buffer());
        let out = execute_buffers(self.exe(0, Kind::FwdBwd)?, &args)?;
        self.transfers.add_d2h(gdst.len() as u64 * 4);
        self.unpack_grads_into(0, &out, 0, gdst)
    }

    /// Backward of a middle stage on device: grads into `gdst`, the
    /// input cotangent stays on device.
    pub fn mid_bwd_dev(
        &self,
        stage: usize,
        params: &[xla::PjRtBuffer],
        x: &DeviceTensor,
        gy: &DeviceTensor,
        gdst: &mut [f32],
    ) -> Result<DeviceTensor> {
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(x.buffer());
        args.push(gy.buffer());
        let out = execute_buffers(self.exe(stage, Kind::FwdBwd)?, &args)?;
        self.transfers.add_d2h(gdst.len() as u64 * 4);
        let gx = self.restage(&out[0], &self.manifest.stages[stage].input.shape)?;
        self.unpack_grads_into(stage, &out, 1, gdst)?;
        Ok(gx)
    }

    /// Backward of the loss stage on device: grads into `gdst`, returns
    /// (loss, device cotangent).
    pub fn last_bwd_dev(
        &self,
        params: &[xla::PjRtBuffer],
        x: &DeviceTensor,
        targets: &DeviceTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, DeviceTensor)> {
        let last = self.manifest.n_stages - 1;
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(x.buffer());
        args.push(targets.buffer());
        let out = execute_buffers(self.exe(last, Kind::FwdBwd)?, &args)?;
        let loss = literal_to_scalar(&out[0])?;
        self.transfers.add_d2h(4 + gdst.len() as u64 * 4);
        let gx = self.restage(&out[1], &self.manifest.stages[last].input.shape)?;
        self.unpack_grads_into(last, &out, 2, gdst)?;
        Ok((loss, gx))
    }

    /// Fused SGD-momentum over resident device state, with version
    /// hand-over ("donation", DESIGN-PERF.md): reads θ_t and momentum
    /// from the store's buffers for `version`, uploads only the averaged
    /// gradients + lr, and promotes the result to the resident
    /// θ_{version+1} / momentum — exactly one parameter upload per stage
    /// per committed θ-version.  Host mirrors stay authoritative:
    /// θ_{t+1} is written into `out` (the `ParamStore` next slot, which
    /// the comm fabric serves from) and momentum into `moms`.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd_update_dev(
        &self,
        stage: usize,
        dstore: &mut DeviceParamStore,
        version: u64,
        cur: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let specs = &self.manifest.stages[stage].params;
        let k = specs.len();
        anyhow::ensure!(
            cur.len() == moms.len() && cur.len() == grads.len() && cur.len() == out.len(),
            "stage {stage}: flat run length mismatch"
        );
        let mut gbufs = Vec::with_capacity(k);
        let mut off = 0usize;
        for p in specs {
            let n = p.elems();
            gbufs.push(
                self.engine
                    .client
                    .buffer_from_host_buffer(&grads[off..off + n], &p.shape, None)
                    .map_err(anyhow_xla)?,
            );
            off += n;
        }
        anyhow::ensure!(off == grads.len(), "stage {stage}: run/manifest mismatch");
        let lr_buf = self
            .engine
            .client
            .buffer_from_host_buffer(&[lr], &[1], None)
            .map_err(anyhow_xla)?;
        self.transfers.add_h2d(grads.len() as u64 * 4 + 4);

        let res = {
            let (pbufs, mbufs) =
                dstore.params_and_momentum(self, stage, version, cur, moms)?;
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(3 * k + 1);
            args.extend(pbufs.iter());
            args.extend(mbufs.iter());
            args.extend(gbufs.iter());
            args.push(&lr_buf);
            execute_buffers(self.exe(stage, Kind::Sgd)?, &args)?
        };
        anyhow::ensure!(res.len() == 2 * k, "sgd returned {} outputs", res.len());

        // host mirrors: θ_{t+1} into the next slot, momentum in place
        let mut off = 0usize;
        for (i, p) in specs.iter().enumerate() {
            let n = p.elems();
            literal_into_slice(&res[i], &mut out[off..off + n])?;
            literal_into_slice(&res[k + i], &mut moms[off..off + n])?;
            off += n;
        }
        self.transfers.add_d2h(2 * cur.len() as u64 * 4);

        // donation: the update's result becomes the resident
        // θ_{version+1}/momentum; the θ_{version−1} buffers it displaces
        // drop at the store's next eviction
        dstore.install_params(self, stage, version + 1, &res[..k])?;
        dstore.install_momentum(self, stage, &res[k..])?;
        Ok(())
    }

    /// Upload one stage's parameter run as per-tensor device buffers
    /// (split by the manifest views).  Used by [`DeviceParamStore`]; the
    /// store does the per-version caching and upload accounting.
    pub(crate) fn upload_stage_run(
        &self,
        stage: usize,
        flat: &[f32],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let specs = &self.manifest.stages[stage].params;
        let mut bufs = Vec::with_capacity(specs.len());
        let mut off = 0usize;
        for p in specs {
            let n = p.elems();
            bufs.push(
                self.engine
                    .client
                    .buffer_from_host_buffer(&flat[off..off + n], &p.shape, None)
                    .map_err(anyhow_xla)?,
            );
            off += n;
        }
        anyhow::ensure!(
            off == flat.len(),
            "stage {stage}: flat run has {} elems, manifest says {off}",
            flat.len()
        );
        Ok(bufs)
    }
}

/// The XLA execution path behind the coordinator-facing [`Backend`]
/// boundary: per-trainer state is an [`Executor`] (literal cache on the
/// host path, [`DeviceParamStore`] on the device path), activations hand
/// off as [`Act`], and every call delegates to the typed entry points
/// above.  `BundleRuntime` *is* the `xla` backend — the alias
/// [`XlaBackend`] names it at selection sites.
#[allow(clippy::too_many_arguments)]
impl Backend for BundleRuntime {
    type Act = Act;
    type Exec = Executor;

    fn name(&self) -> &'static str {
        "xla"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init_params_flat(&self) -> Result<Vec<f32>> {
        BundleRuntime::init_params_flat(self)
    }

    fn executor(&self, mode: ExecMode) -> Executor {
        Executor::new(mode, self.manifest.n_stages)
    }

    fn exec_mode(&self, exec: &Executor) -> ExecMode {
        exec.mode()
    }

    fn param_uploads(&self, exec: &Executor) -> Option<u64> {
        exec.device_store().map(|s| s.param_uploads())
    }

    fn input(&self, exec: &mut Executor, x: HostTensor) -> Result<Act> {
        exec.input(self, x)
    }

    fn fwd(
        &self,
        exec: &mut Executor,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Act,
    ) -> Result<Act> {
        exec.fwd(self, stage, version, flat, x)
    }

    fn last_bwd(
        &self,
        exec: &mut Executor,
        version: u64,
        flat: &[f32],
        x: &Act,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Act)> {
        exec.last_bwd(self, version, flat, x, targets, gdst)
    }

    fn mid_bwd(
        &self,
        exec: &mut Executor,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Act,
        gy: &Act,
        gdst: &mut [f32],
    ) -> Result<Act> {
        exec.mid_bwd(self, stage, version, flat, x, gy, gdst)
    }

    fn first_bwd(
        &self,
        exec: &mut Executor,
        version: u64,
        flat: &[f32],
        x: &Act,
        gy: &Act,
        gdst: &mut [f32],
    ) -> Result<()> {
        exec.first_bwd(self, version, flat, x, gy, gdst)
    }

    fn sgd(
        &self,
        exec: &mut Executor,
        stage: usize,
        version: u64,
        cur: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        exec.sgd(self, stage, version, cur, moms, grads, lr, out)
    }

    fn stage_fwd_flat(&self, stage: usize, flat: &[f32], x: &HostTensor) -> Result<Tensor> {
        BundleRuntime::stage_fwd_flat(self, stage, flat, x)
    }

    fn last_fwd_loss_flat(
        &self,
        flat: &[f32],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<f32> {
        BundleRuntime::last_fwd_loss_flat(self, flat, x, targets)
    }

    fn predict_flat(&self, flat: &[f32], x: &Tensor) -> Result<Tensor> {
        BundleRuntime::predict_flat(self, flat, x)
    }

    fn sgd_update_flat(
        &self,
        stage: usize,
        params: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        BundleRuntime::sgd_update_flat(self, stage, params, moms, grads, lr, out)
    }
}

/// Name alias for backend-selection sites: the `xla` backend is the
/// compiled-bundle runtime itself.
pub type XlaBackend = BundleRuntime;

// SAFETY: the `xla` crate's wrappers hold raw pointers without
// Send/Sync, but the underlying PJRT C++ objects are documented
// thread-safe for compilation-free use: `PjRtLoadedExecutable::Execute`
// may be called concurrently, and each call here constructs its own
// `Literal`s.  We never share a Literal across threads, never mutate an
// executable, and compile everything before the trainers spawn workers.
// The same contract covers the device-resident path: `PjRtClient`
// buffer creation and `execute_b` are thread-safe, and every
// `PjRtBuffer`/`DeviceTensor` is created, used and dropped by exactly
// one worker thread (each worker owns its executor state; buffers never
// cross threads).
unsafe impl Send for BundleRuntime {}
unsafe impl Sync for BundleRuntime {}
