//! The execution-backend boundary (DESIGN-PERF.md §Backend boundary).
//!
//! The paper's claims — constant activation memory, balanced
//! point-to-point gradient communication, bit-identical losses under the
//! cyclic delay — are properties of the *schedule*, not of XLA.  The
//! [`Backend`] trait captures the narrow surface the four coordinators
//! actually drive (stage forward, first/mid/last backward into arena
//! slices, fused SGD, predict + loss), so the schedule logic in
//! `coordinator/` is written once and executes against either:
//!
//! - [`crate::runtime::NativeBackend`] — pure Rust, the `tensor::ops`
//!   dense kernels, zero external dependencies (the default build and the
//!   required CI lane), or
//! - `BundleRuntime` (the XLA/PJRT path, behind the `xla` cargo feature) —
//!   AOT HLO artifacts, literal or device-resident execution.
//!
//! The determinism contract is backend-uniform: a backend's stage
//! functions are pure deterministic functions of (parameters, inputs), so
//! with the trainers' fixed micro-batch reduction order the loss
//! sequences of all four trainers are bit-identical *within* a backend.
//! Across backends the schedules agree exactly; the floating-point values
//! agree to kernel-accumulation-order tolerance (tested when both
//! backends are built).
#![deny(missing_docs)]

use anyhow::Result;

use crate::model::Manifest;
use crate::tensor::{HostTensor, IntTensor, Tensor};

/// Which execution path a trainer drives (`CDP_EXEC_MODE=host|device`
/// overrides the per-trainer default).  The native backend has a single
/// (host) execution path and treats the two modes identically; on the
/// XLA backend `DeviceResident` selects persistent parameter buffers and
/// device-side activation hand-off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Host boundary — the reference oracle path.
    HostLiteral,
    /// Persistent device buffers for parameters/momentum, device-side
    /// activation hand-off (XLA backend only; native ignores it).
    DeviceResident,
}

impl ExecMode {
    /// Resolve the mode, letting `CDP_EXEC_MODE` override the default
    /// (case-insensitive; an unrecognized value warns loudly instead of
    /// silently running the wrong path — these A/B measurements are the
    /// point of the knob).
    pub fn from_env(default: Self) -> Self {
        match std::env::var("CDP_EXEC_MODE") {
            Ok(v) => match v.to_ascii_lowercase().as_str() {
                "host" | "literal" => ExecMode::HostLiteral,
                "device" => ExecMode::DeviceResident,
                other => {
                    eprintln!(
                        "CDP_EXEC_MODE=`{other}` not recognized \
                         (use host|device); keeping {default:?}"
                    );
                    default
                }
            },
            Err(_) => default,
        }
    }
}

/// Numeric storage precision for the compute path (DESIGN-PERF.md
/// §Kernel architecture, "Precision model").
///
/// - [`Precision::F32`] (default) is the bit-identical oracle: every
///   kernel accumulates in f32 in the documented canonical order, and the
///   four trainers produce bit-identical loss sequences.
/// - [`Precision::Bf16`] rounds parameters and stage-boundary activations
///   to bfloat16 storage (round-to-nearest-even) before each stage
///   computes; accumulation stays in f32.  Master parameters and the
///   optimizer state remain f32, so the update itself is full-precision.
///   The rounding points are fixed and schedule-independent, so bf16 runs
///   are still deterministic and bit-identical *across trainers* — they
///   are just not bit-comparable to f32 runs (tolerance ≤ 2⁻⁸ relative
///   per rounding, tested in `tensor::bf16`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 storage + compute — the bit-identical reference (default).
    #[default]
    F32,
    /// bf16 storage for parameters/activations at stage boundaries; f32
    /// master copies and f32 accumulation (mixed precision).
    Bf16,
}

impl Precision {
    /// Short name for logs/reports ("f32", "bf16").
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }

    /// Parse a CLI/env value (case-insensitive).
    pub fn parse(v: &str) -> Result<Self> {
        match v.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(Precision::F32),
            "bf16" | "bfloat16" => Ok(Precision::Bf16),
            other => anyhow::bail!("unknown precision `{other}` (f32|bf16)"),
        }
    }

    /// Resolve the precision, letting `CDP_PRECISION` override the
    /// default (mirrors [`ExecMode::from_env`]; an unrecognized value
    /// warns and keeps the default rather than silently switching the
    /// numeric contract).
    pub fn from_env(default: Self) -> Self {
        match std::env::var("CDP_PRECISION") {
            Ok(v) => match Self::parse(&v) {
                Ok(p) => p,
                Err(_) => {
                    eprintln!(
                        "CDP_PRECISION=`{v}` not recognized (use f32|bf16); \
                         keeping {default:?}"
                    );
                    default
                }
            },
            Err(_) => default,
        }
    }
}

/// An activation as it hands off between stages: whatever representation
/// the backend keeps it in (a host tensor natively, a host tensor *or* a
/// resident device buffer on XLA).  The coordinators only ever move it
/// and account its size.
pub trait Activation {
    /// Payload bytes (activation-traffic accounting in the pipeline).
    fn bytes(&self) -> usize;
}

impl Activation for HostTensor {
    fn bytes(&self) -> usize {
        HostTensor::bytes(self)
    }
}

/// One execution backend: the narrow compute surface the coordinators
/// drive a bundle through.
///
/// Conventions shared by all implementations (they mirror the artifact
/// signatures in `python/compile/aot.py`):
///
/// - parameters arrive as one contiguous flat stage run (arena order);
/// - backward calls write the stage's parameter gradients straight into
///   the caller's arena slice `gdst` (every element, exactly once);
/// - `version` is the θ-version id of the run (commit step that produced
///   it, see `coordinator::version_id`) — backends with per-version
///   caches key on it, stateless backends ignore it;
/// - `exec` is per-trainer execution state created by [`Self::executor`]
///   (device-resident buffer caches on XLA; nothing natively).  It never
///   crosses threads: each worker builds its own.
pub trait Backend: Sized {
    /// Inter-stage activation hand-off unit.
    type Act: Activation;
    /// Per-trainer execution state.
    type Exec;

    /// Short backend name for logs/reports ("native", "xla").
    fn name(&self) -> &'static str;

    /// The bundle manifest (stage shapes, data distribution, hyperparams).
    fn manifest(&self) -> &Manifest;

    /// θ_0 as one model-wide stage-major flat vector.
    fn init_params_flat(&self) -> Result<Vec<f32>>;

    /// Fresh per-trainer execution state.
    fn executor(&self, mode: ExecMode) -> Self::Exec;

    /// The mode `exec` actually runs (backends may coerce).
    fn exec_mode(&self, exec: &Self::Exec) -> ExecMode;

    /// Stage-level parameter uploads performed by `exec`'s device store
    /// (`None` on paths without one) — the ≤1-per-θ-version bench metric.
    fn param_uploads(&self, _exec: &Self::Exec) -> Option<u64> {
        None
    }

    /// Stage-0 input enters the pipeline (consumes the host tensor).
    fn input(&self, exec: &mut Self::Exec, x: HostTensor) -> Result<Self::Act>;

    /// Forward of a non-loss stage.
    fn fwd(
        &self,
        exec: &mut Self::Exec,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
    ) -> Result<Self::Act>;

    /// Backward of the loss stage: grads into `gdst`, returns (loss, gx).
    fn last_bwd(
        &self,
        exec: &mut Self::Exec,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, Self::Act)>;

    /// Backward of a middle stage: grads into `gdst`, returns gx.
    #[allow(clippy::too_many_arguments)]
    fn mid_bwd(
        &self,
        exec: &mut Self::Exec,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
        gy: &Self::Act,
        gdst: &mut [f32],
    ) -> Result<Self::Act>;

    /// Backward of stage 0: grads into `gdst` (no input cotangent).
    fn first_bwd(
        &self,
        exec: &mut Self::Exec,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
        gy: &Self::Act,
        gdst: &mut [f32],
    ) -> Result<()>;

    /// Fused SGD-momentum for one stage: reads θ_t from `cur` (committed
    /// as θ-version `version`), updates `moms` in place, writes θ_{t+1}
    /// into `out`.
    #[allow(clippy::too_many_arguments)]
    fn sgd(
        &self,
        exec: &mut Self::Exec,
        stage: usize,
        version: u64,
        cur: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()>;

    // ---- stateless inference surface (eval/accuracy/tools) ---------------

    /// Forward of a non-loss stage from a flat run (no executor state).
    fn stage_fwd_flat(&self, stage: usize, flat: &[f32], x: &HostTensor) -> Result<Tensor>;

    /// Loss-stage forward from a flat run: scalar loss.
    fn last_fwd_loss_flat(&self, flat: &[f32], x: &Tensor, targets: &IntTensor)
        -> Result<f32>;

    /// Classifier logits from a flat run.
    fn predict_flat(&self, flat: &[f32], x: &Tensor) -> Result<Tensor>;

    /// Fused SGD over flat runs without executor state (tools/benches).
    fn sgd_update_flat(
        &self,
        stage: usize,
        params: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()>;
}

/// Which backend a binary should construct.  Resolution order: explicit
/// CLI value, then `CDP_BACKEND`, then the build's default (xla when the
/// feature is compiled in — preserving pre-split behavior — else native).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Pure-Rust `tensor::ops` kernels — no external dependencies; the
    /// default build and the required CI lane.
    Native,
    /// AOT-compiled HLO executed through PJRT (`xla` cargo feature).
    Xla,
}

impl BackendChoice {
    /// Canonical lowercase name ("native", "xla") for CLI echo and logs.
    pub fn as_str(self) -> &'static str {
        match self {
            BackendChoice::Native => "native",
            BackendChoice::Xla => "xla",
        }
    }
}

/// Resolve the backend choice from an optional CLI value + `CDP_BACKEND`.
/// Selecting `xla` in a build without the feature is an error with a
/// build hint, not a silent fallback.
pub fn backend_choice(cli: Option<&str>) -> Result<BackendChoice> {
    let env = std::env::var("CDP_BACKEND").ok();
    let raw = cli.map(str::to_string).or(env);
    let choice = match raw.as_deref().map(str::to_ascii_lowercase).as_deref() {
        Some("native") => BackendChoice::Native,
        Some("xla") | Some("pjrt") => BackendChoice::Xla,
        Some(other) => anyhow::bail!("unknown backend `{other}` (native|xla)"),
        None => {
            if cfg!(feature = "xla") {
                BackendChoice::Xla
            } else {
                BackendChoice::Native
            }
        }
    };
    if choice == BackendChoice::Xla && !cfg!(feature = "xla") {
        anyhow::bail!(
            "backend `xla` requested but this binary was built without the \
             `xla` feature — rebuild with `cargo build --features xla` \
             (needs the xla_extension toolchain, see DESIGN-PERF.md \
             §Toolchain) or use `--backend native`"
        );
    }
    Ok(choice)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_explicit_values() {
        assert_eq!(backend_choice(Some("native")).unwrap(), BackendChoice::Native);
        assert!(backend_choice(Some("bogus")).is_err());
        #[cfg(not(feature = "xla"))]
        {
            assert!(backend_choice(Some("xla")).is_err(), "xla without the feature");
        }
        #[cfg(feature = "xla")]
        {
            assert_eq!(backend_choice(Some("xla")).unwrap(), BackendChoice::Xla);
        }
    }

    #[test]
    fn precision_parse_and_names() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("BF16").unwrap(), Precision::Bf16);
        assert_eq!(Precision::parse("bfloat16").unwrap(), Precision::Bf16);
        assert!(Precision::parse("f64").is_err());
        assert_eq!(Precision::default().name(), "f32");
        assert_eq!(Precision::Bf16.name(), "bf16");
    }

    #[test]
    fn backend_choice_default_matches_build() {
        // unless the environment overrides it, the default follows the
        // compiled feature set
        if std::env::var("CDP_BACKEND").is_err() {
            let want = if cfg!(feature = "xla") {
                BackendChoice::Xla
            } else {
                BackendChoice::Native
            };
            assert_eq!(backend_choice(None).unwrap(), want);
        }
    }
}
