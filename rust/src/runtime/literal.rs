//! Host tensor ⇄ XLA literal conversion.

use anyhow::Result;

use super::anyhow_xla;
use crate::tensor::{HostTensor, IntTensor, Tensor};

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(&t.data).reshape(&dims).map_err(anyhow_xla)
}

pub fn int_tensor_to_literal(t: &IntTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(&t.data).reshape(&dims).map_err(anyhow_xla)
}

pub fn host_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    match t {
        HostTensor::F32(t) => tensor_to_literal(t),
        HostTensor::I32(t) => int_tensor_to_literal(t),
    }
}

/// Convert an f32 literal back to a host tensor with the given shape
/// (shape comes from the manifest; the literal's own shape must agree in
/// element count).
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>().map_err(anyhow_xla)?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "literal has {} elems, manifest shape {shape:?}",
        data.len()
    );
    Ok(Tensor::new(shape.to_vec(), data))
}

/// Scalar (rank-0 or single-element) f32 literal.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(anyhow_xla)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}
