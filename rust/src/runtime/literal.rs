//! Host tensor ⇄ XLA literal conversion.

use anyhow::Result;

use super::anyhow_xla;
use crate::tensor::{HostTensor, IntTensor, Tensor};

/// Literal straight from a flat slice + shape — the arena fast path: no
/// intermediate [`Tensor`] is materialized.
pub fn slice_to_literal(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(anyhow_xla)
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    slice_to_literal(&t.shape, &t.data)
}

pub fn int_tensor_to_literal(t: &IntTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(&t.data).reshape(&dims).map_err(anyhow_xla)
}

pub fn host_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    match t {
        HostTensor::F32(t) => tensor_to_literal(t),
        HostTensor::I32(t) => int_tensor_to_literal(t),
    }
}

/// Convert an f32 literal back to a host tensor with the given shape
/// (shape comes from the manifest; the literal's own shape must agree in
/// element count).
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>().map_err(anyhow_xla)?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "literal has {} elems, manifest shape {shape:?}",
        data.len()
    );
    Ok(Tensor::new(shape.to_vec(), data))
}

/// Copy an f32 literal into an existing arena slice (no `Tensor`
/// round-trip; the literal's element count must match the slice).
pub fn literal_into_slice(lit: &xla::Literal, dst: &mut [f32]) -> Result<()> {
    let data = lit.to_vec::<f32>().map_err(anyhow_xla)?;
    anyhow::ensure!(
        data.len() == dst.len(),
        "literal has {} elems, destination slice {}",
        data.len(),
        dst.len()
    );
    dst.copy_from_slice(&data);
    Ok(())
}

/// Scalar (rank-0 or single-element) f32 literal.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(anyhow_xla)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}
