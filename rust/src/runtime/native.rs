//! Pure-Rust execution backend: the mlp family's stage graphs (the fast
//! numeric model of `python/compile/model.py`) executed with the
//! [`crate::tensor::ops`] dense kernels — matmul, relu, bias, softmax-CE
//! and the fused SGD — with no XLA, no artifacts, no network.
//!
//! This is the backend the required CI lane builds and tests: every
//! schedule/update-rule/communication property of the paper is exercised
//! end-to-end on it.  Two construction paths:
//!
//! - [`NativeBackend::load`] — a bundle directory's `manifest.json` +
//!   `params.bin` (the same files the XLA path uses; HLO artifacts are
//!   ignored), so a `make artifacts` mlp bundle runs on either backend
//!   from identical θ_0;
//! - [`NativeBackend::synthetic`] — a fully in-memory bundle (manifest +
//!   deterministic θ_0 from the crate's own RNG), requiring zero files.
//!
//! Math, mirroring `Mlp.stage_apply` / `loss_apply`:
//!
//! ```text
//! stage 0 prologue:  h ← relu(x·W_in + b_in)
//! residual layer:    h ← h + 0.3·relu(h·W_l + b_l)      (×L per stage)
//! loss head:         logits ← h·W_out + b_out;  CE = mean_b(logsumexp − logit_t)
//! sgd:               m' ← µ·m + g;  p' ← p − lr·m'
//! ```
//!
//! The backward recomputes the stage forward from the stage input
//! (stage-granularity rematerialization — the same contract as the AOT
//! `fwdbwd` artifacts), and writes parameter gradients straight into the
//! caller's arena slice in manifest view order.  Everything is a pure
//! deterministic function of its inputs, so the trainers' bit-identity
//! invariants hold natively exactly as they do on XLA.
//!
//! Precision ([`Precision`], DESIGN-PERF.md §Kernel architecture): in
//! `Bf16` mode parameters and stage-boundary activations are rounded to
//! bfloat16 storage before each stage computes (round-to-nearest-even,
//! idempotent — re-rounding an already-rounded value is a no-op, so the
//! hand-off direction never matters); accumulation, gradients and the
//! master parameters stay f32.  `F32` (the default) is the bit-identical
//! oracle and allocates nothing for precision handling.
#![deny(missing_docs)]

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::backend::{Backend, ExecMode, Precision};
use crate::model::{DataSpec, DType, IoSpec, Manifest, ParamSpec, StageSpec};
use crate::parallel::arena::{ArenaLayout, ViewSpec};
use crate::tensor::bf16;
use crate::tensor::ops;
use crate::tensor::{HostTensor, IntTensor, Tensor};
use crate::util::binio;
use crate::util::par;
use crate::util::rng::{splitmix64, XorShift64Star};

/// Residual-branch scale, fixed by the python model (`Mlp.RES_SCALE`).
pub const RES_SCALE: f32 = 0.3;

/// The mlp family's global dimensions, validated against the manifest.
#[derive(Clone, Copy, Debug)]
struct MlpShape {
    input_dim: usize,
    hidden: usize,
    classes: usize,
}

/// Configuration for [`NativeBackend::synthetic`].  The default mirrors
/// the `mlp` bundle of `python/compile/configs.py` (hidden 128, 4 stages
/// × 2 residual layers, micro-batch 8, lr 0.01, µ 0.9) — θ_0 differs (the
/// crate's deterministic RNG instead of numpy's), which is irrelevant to
/// every schedule property and keeps the bundle self-consistent.
#[derive(Clone, Copy, Debug)]
pub struct NativeMlpConfig {
    /// Classifier output classes C.
    pub classes: usize,
    /// Input feature dimension D.
    pub input_dim: usize,
    /// Hidden width H (every residual layer is [H,H]+[H]).
    pub hidden: usize,
    /// Residual layers per stage L.
    pub layers_per_stage: usize,
    /// Micro-batch size b.
    pub microbatch: usize,
    /// Pipeline stage count N.
    pub n_stages: usize,
    /// Number of data microbatches N.  0 (the default) means "follow
    /// `n_stages`" — the paper's square N×N cyclic schedule.  Setting it
    /// explicitly lets fault-tolerance tests build a reference backend
    /// that matches a degraded N−1 ring (DESIGN-ROBUSTNESS.md).
    pub n_microbatches: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// SGD momentum coefficient µ.
    pub momentum: f32,
    /// Synthetic-data label-noise level.
    pub noise: f32,
    /// Seed of the deterministic data stream.
    pub data_seed: u64,
    /// Seed of the deterministic θ_0 draw.
    pub param_seed: u64,
}

impl Default for NativeMlpConfig {
    fn default() -> Self {
        Self {
            classes: 10,
            input_dim: 64,
            hidden: 128,
            layers_per_stage: 2,
            microbatch: 8,
            n_stages: 4,
            n_microbatches: 0,
            lr: 0.01,
            momentum: 0.9,
            noise: 0.3,
            data_seed: 99,
            param_seed: 7,
        }
    }
}

impl NativeMlpConfig {
    /// A deliberately tiny model for property tests / gradient checks.
    pub fn tiny() -> Self {
        Self {
            classes: 3,
            input_dim: 5,
            hidden: 6,
            layers_per_stage: 1,
            microbatch: 2,
            n_stages: 2,
            ..Self::default()
        }
    }

    /// Planner-bench shape: deep and narrow (16 residual layers of width
    /// 32) — per-layer compute is small relative to the stage hand-offs,
    /// so partition/schedule choice dominates.
    pub fn deep_narrow() -> Self {
        Self {
            hidden: 32,
            layers_per_stage: 4,
            n_stages: 4,
            microbatch: 4,
            ..Self::default()
        }
    }

    /// Planner-bench shape: shallow and wide (2 residual layers of width
    /// 256, fat micro-batches) — compute-dominated, few useful cuts.
    pub fn shallow_wide() -> Self {
        Self {
            hidden: 256,
            layers_per_stage: 1,
            n_stages: 2,
            microbatch: 16,
            ..Self::default()
        }
    }
}

/// Per-trainer execution state of the native backend.  The native path
/// has no device, so there is nothing to cache — the struct only records
/// that a requested `DeviceResident` mode was coerced to the single
/// (host) path.
pub struct NativeExec {
    _requested: ExecMode,
}

/// The pure-Rust execution backend: mlp stage graphs on the
/// `tensor::ops` kernels.  Construct with [`NativeBackend::load`] (bundle
/// directory) or [`NativeBackend::synthetic`] (fully in-memory); see the
/// module docs for the math and the determinism/precision contracts.
pub struct NativeBackend {
    /// The bundle manifest (stage shapes, data distribution, hyperparams).
    pub manifest: Manifest,
    layout: Arc<ArenaLayout>,
    shape: MlpShape,
    /// θ_0, model-wide stage-major flat (arena order).
    init: Vec<f32>,
    /// Storage precision of the compute path (f32 master state either way).
    precision: Precision,
    /// The synthetic config this bundle was built from (`None` for
    /// on-disk bundles, whose stage graphs cannot be re-cut).
    cfg: Option<NativeMlpConfig>,
}

impl NativeBackend {
    /// Load a bundle directory (`manifest.json` + `params.bin`); the HLO
    /// artifacts, if present, are ignored.  Only the mlp family executes
    /// natively — other families need the `xla` feature.
    pub fn load(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let shape = validate_mlp(&manifest)?;
        let layout = ArenaLayout::from_manifest(&manifest);
        let init = binio::read_f32_file(&manifest.params_bin())
            .with_context(|| format!("read {:?}", manifest.params_bin()))?;
        anyhow::ensure!(
            init.len() == manifest.total_param_elems,
            "params.bin has {} elems, manifest says {}",
            init.len(),
            manifest.total_param_elems
        );
        Ok(Self { manifest, layout, shape, init, precision: Precision::default(), cfg: None })
    }

    /// Build a fully in-memory mlp bundle: manifest synthesized from
    /// `cfg`, θ_0 drawn from the crate's deterministic RNG.  No files.
    pub fn synthetic(cfg: NativeMlpConfig) -> Self {
        let manifest = synthetic_manifest(&cfg);
        let layout = ArenaLayout::from_manifest(&manifest);
        let shape = MlpShape {
            input_dim: cfg.input_dim,
            hidden: cfg.hidden,
            classes: cfg.classes,
        };
        let init = init_params(&manifest, cfg.param_seed);
        Self { manifest, layout, shape, init, precision: Precision::default(), cfg: Some(cfg) }
    }

    /// The synthetic config this backend was built from, when it has one.
    pub fn synthetic_config(&self) -> Option<NativeMlpConfig> {
        self.cfg
    }

    /// Rebuild this synthetic bundle cut into `k` stages, preserving the
    /// total residual layer count (`k` must divide it) and the precision.
    /// The planner's partition dimension executes through here.  On-disk
    /// bundles cannot be re-cut — their stage graphs are baked into the
    /// compiled artifacts — so they error.
    pub fn repartitioned(&self, k: usize) -> Result<Self> {
        let cfg = self.cfg.ok_or_else(|| {
            anyhow::anyhow!(
                "cannot repartition bundle `{}`: its stage graph is baked into \
                 on-disk artifacts; only synthetic native bundles support plan \
                 repartitioning",
                self.manifest.name
            )
        })?;
        let total = cfg.n_stages * cfg.layers_per_stage;
        anyhow::ensure!(
            k >= 1 && total % k == 0,
            "stage count {k} does not divide the {total} residual layers"
        );
        let recut = NativeMlpConfig {
            n_stages: k,
            layers_per_stage: total / k,
            n_microbatches: 0, // follow k: the square schedule
            ..cfg
        };
        Ok(Self::synthetic(recut).with_precision(self.precision))
    }

    /// The default synthetic bundle (`native_mlp`).
    pub fn default_mlp() -> Self {
        Self::synthetic(NativeMlpConfig::default())
    }

    /// Load `name` from the artifacts root when present, else fall back
    /// to the synthetic bundle for the names that have one.
    pub fn load_or_synthetic(name: &str) -> Result<Self> {
        let dir = crate::model::artifacts_root().join(name);
        if dir.join("manifest.json").exists() {
            return Self::load(&dir);
        }
        match name {
            "mlp" | "native_mlp" => Ok(Self::default_mlp()),
            "deep_narrow" => Ok(Self::synthetic(NativeMlpConfig::deep_narrow())),
            "shallow_wide" => Ok(Self::synthetic(NativeMlpConfig::shallow_wide())),
            other => anyhow::bail!(
                "bundle `{other}` not found under {:?} and has no synthetic \
                 fallback — the native backend executes the mlp family only \
                 (`mlp`, `native_mlp`, `deep_narrow`, `shallow_wide`); \
                 transformer/convnet bundles need `--features xla` + \
                 `make artifacts`",
                crate::model::artifacts_root()
            ),
        }
    }

    /// The flat-arena layout derived from the manifest.
    pub fn layout(&self) -> &Arc<ArenaLayout> {
        &self.layout
    }

    /// The active storage precision of the compute path.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Select the storage precision (builder style).  `--precision bf16`
    /// / `CDP_PRECISION=bf16` route here; the default is f32.
    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// Select the storage precision in place.
    pub fn set_precision(&mut self, p: Precision) {
        self.precision = p;
    }

    /// Parameters as the compute path sees them: the borrow itself in f32
    /// mode (zero-cost — the hot path stays allocation-free), a
    /// bf16-rounded local copy in bf16 mode (one allocation per stage
    /// call, the documented cost of the mixed-precision knob; the f32
    /// master copy is never mutated).
    fn q_params<'a>(&self, flat: &'a [f32]) -> Cow<'a, [f32]> {
        match self.precision {
            Precision::F32 => Cow::Borrowed(flat),
            Precision::Bf16 => {
                let mut v = flat.to_vec();
                bf16::round_slice(&mut v);
                Cow::Owned(v)
            }
        }
    }

    /// Stage-boundary activation as the compute path sees it (same
    /// contract as [`Self::q_params`]).  Rounding is idempotent, so it is
    /// harmless that both the producing and the consuming stage round.
    fn q_act<'a>(&self, x: &'a Tensor) -> Cow<'a, Tensor> {
        match self.precision {
            Precision::F32 => Cow::Borrowed(x),
            Precision::Bf16 => {
                let mut t = x.clone();
                bf16::round_slice(&mut t.data);
                Cow::Owned(t)
            }
        }
    }

    /// (has input prologue, residual layer count, has loss head) of stage j.
    fn stage_shape(&self, j: usize) -> (bool, usize, bool) {
        let n = self.manifest.n_stages;
        let views = self.layout.stages[j].views.len();
        let extras = usize::from(j == 0) * 2 + usize::from(j == n - 1) * 2;
        (j == 0, (views - extras) / 2, j == n - 1)
    }

    /// Stage-relative view slice of a flat run.
    fn view<'a>(run: &'a [f32], v: &ViewSpec) -> &'a [f32] {
        &run[v.offset..v.offset + v.len]
    }

    fn view_mut<'a>(run: &'a mut [f32], v: &ViewSpec) -> &'a mut [f32] {
        &mut run[v.offset..v.offset + v.len]
    }

    /// Forward through stage j's prologue + residual body (everything
    /// except the loss head), stashing pre-activations when `stash` asks
    /// for them (the backward's rematerialization).  Returns h [b, H]
    /// flat; stashes are (u_in, per-layer (h_l, u_l)).
    #[allow(clippy::type_complexity)]
    fn body_fwd(
        &self,
        j: usize,
        flat: &[f32],
        x: &Tensor,
        stash: bool,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let (has_input, n_layers, _) = self.stage_shape(j);
        let views = &self.layout.stages[j].views;
        let h_dim = self.shape.hidden;
        anyhow::ensure!(x.shape.len() == 2, "stage {j}: input must be [b, d]");
        let b = x.shape[0];
        let d_in = x.shape[1];

        let mut u_in = None;
        let mut vi = 0usize;
        let mut h: Vec<f32> = if has_input {
            anyhow::ensure!(
                d_in == self.shape.input_dim,
                "stage 0: input dim {d_in} != manifest {}",
                self.shape.input_dim
            );
            let w = Self::view(flat, &views[0]);
            let bias = Self::view(flat, &views[1]);
            vi = 2;
            let mut u = vec![0.0f32; b * h_dim];
            ops::matmul(&mut u, &x.data, w, b, d_in, h_dim);
            if stash {
                // the backward wants the pre-activation; two-pass form
                ops::bias_add(&mut u, bias);
                let mut h0 = u.clone();
                ops::relu(&mut h0);
                u_in = Some(u);
                h0
            } else {
                // fused epilogue — elementwise-identical to bias_add+relu
                ops::bias_add_relu(&mut u, bias);
                u
            }
        } else {
            anyhow::ensure!(d_in == h_dim, "stage {j}: input dim {d_in} != hidden {h_dim}");
            x.data.clone()
        };

        let mut hs: Vec<Vec<f32>> = Vec::new();
        let mut us: Vec<Vec<f32>> = Vec::new();
        for l in 0..n_layers {
            let w = Self::view(flat, &views[vi + 2 * l]);
            let bias = Self::view(flat, &views[vi + 2 * l + 1]);
            let mut u = vec![0.0f32; b * h_dim];
            ops::matmul(&mut u, &h, w, b, h_dim, h_dim);
            if stash {
                ops::bias_add(&mut u, bias);
                let mut r = u.clone();
                ops::relu(&mut r);
                hs.push(h.clone());
                us.push(u);
                ops::axpy(&mut h, RES_SCALE, &r);
            } else {
                // fused epilogue — elementwise-identical to bias_add+relu
                ops::bias_add_relu(&mut u, bias);
                ops::axpy(&mut h, RES_SCALE, &u);
            }
        }
        Ok((h, u_in, hs, us))
    }

    /// Logits of the loss stage: body forward + the head linear.
    fn logits(&self, flat: &[f32], x: &Tensor) -> Result<Vec<f32>> {
        let flat_q = self.q_params(flat);
        let flat: &[f32] = &flat_q;
        let x_q = self.q_act(x);
        let x: &Tensor = &x_q;
        let j = self.manifest.n_stages - 1;
        let (h, _, _, _) = self.body_fwd(j, flat, x, false)?;
        let views = &self.layout.stages[j].views;
        let (out_wv, out_bv) = (&views[views.len() - 2], &views[views.len() - 1]);
        let b = x.shape[0];
        let (h_dim, c) = (self.shape.hidden, self.shape.classes);
        let mut logits = vec![0.0f32; b * c];
        ops::matmul(&mut logits, &h, Self::view(flat, out_wv), b, h_dim, c);
        ops::bias_add(&mut logits, Self::view(flat, out_bv));
        Ok(logits)
    }

    /// Unified backward of stage j: recompute the forward with stashes,
    /// seed the gradient from the loss head (`targets`) or the upstream
    /// cotangent (`gy`), and walk the body in reverse writing every
    /// parameter-gradient view of `gdst` exactly once.  Returns (loss —
    /// 0 for non-loss stages — and gx w.r.t. the stage input).
    fn stage_bwd(
        &self,
        j: usize,
        flat: &[f32],
        x: &Tensor,
        gy: Option<&Tensor>,
        targets: Option<&IntTensor>,
        gdst: &mut [f32],
    ) -> Result<(f32, Tensor)> {
        // bf16 mode: the recomputation sees exactly the rounded values the
        // forward saw (rounding is idempotent); gradients stay f32.
        let flat_q = self.q_params(flat);
        let flat: &[f32] = &flat_q;
        let x_q = self.q_act(x);
        let x: &Tensor = &x_q;
        let (has_input, n_layers, has_head) = self.stage_shape(j);
        let views = &self.layout.stages[j].views;
        anyhow::ensure!(
            gdst.len() == self.layout.stage_len(j),
            "stage {j}: gdst len {} != stage run {}",
            gdst.len(),
            self.layout.stage_len(j)
        );
        let b = x.shape[0];
        let (h_dim, c) = (self.shape.hidden, self.shape.classes);
        let (h_last, u_in, hs, us) = self.body_fwd(j, flat, x, true)?;

        // seed gradient: loss head or upstream cotangent
        let mut loss = 0.0f32;
        let mut g: Vec<f32> = if has_head {
            let t = targets.context("loss stage needs targets")?;
            anyhow::ensure!(t.data.len() == b, "targets len != batch");
            let (out_wv, out_bv) = (&views[views.len() - 2], &views[views.len() - 1]);
            let out_w = Self::view(flat, out_wv);
            let mut logits = vec![0.0f32; b * c];
            ops::matmul(&mut logits, &h_last, out_w, b, h_dim, c);
            ops::bias_add(&mut logits, Self::view(flat, out_bv));
            let mut dlogits = vec![0.0f32; b * c];
            loss = ops::softmax_ce(&logits, &t.data, c, &mut dlogits);
            ops::matmul_tn(Self::view_mut(gdst, out_wv), &h_last, &dlogits, b, h_dim, c);
            ops::col_sums(Self::view_mut(gdst, out_bv), &dlogits);
            let mut g = vec![0.0f32; b * h_dim];
            ops::matmul_nt_acc(&mut g, &dlogits, out_w, b, c, h_dim);
            g
        } else {
            let gy = gy.context("non-loss stage needs an upstream cotangent")?;
            anyhow::ensure!(
                gy.data.len() == b * h_dim,
                "stage {j}: cotangent is {} elems, want {}",
                gy.data.len(),
                b * h_dim
            );
            gy.data.clone()
        };

        // residual layers, reverse order
        let vi = if has_input { 2 } else { 0 };
        let mut du = vec![0.0f32; b * h_dim];
        for l in (0..n_layers).rev() {
            let wv = &views[vi + 2 * l];
            let bv = &views[vi + 2 * l + 1];
            ops::relu_bwd_scaled(&mut du, &g, &us[l], RES_SCALE);
            ops::matmul_tn(Self::view_mut(gdst, wv), &hs[l], &du, b, h_dim, h_dim);
            ops::col_sums(Self::view_mut(gdst, bv), &du);
            ops::matmul_nt_acc(&mut g, &du, Self::view(flat, wv), b, h_dim, h_dim);
        }

        // stage-0 prologue
        let gx = if has_input {
            let (wv, bv) = (&views[0], &views[1]);
            let u = u_in.expect("stage 0 stashed its prologue pre-activation");
            let mut du_in = vec![0.0f32; b * h_dim];
            ops::relu_bwd_scaled(&mut du_in, &g, &u, 1.0);
            let d = self.shape.input_dim;
            ops::matmul_tn(Self::view_mut(gdst, wv), &x.data, &du_in, b, d, h_dim);
            ops::col_sums(Self::view_mut(gdst, bv), &du_in);
            let mut gx = vec![0.0f32; b * d];
            ops::matmul_nt_acc(&mut gx, &du_in, Self::view(flat, wv), b, h_dim, d);
            Tensor::new(vec![b, d], gx)
        } else {
            Tensor::new(vec![b, h_dim], g)
        };
        Ok((loss, gx))
    }

    fn act_f32<'a>(&self, j: usize, x: &'a HostTensor) -> Result<&'a Tensor> {
        x.as_f32().with_context(|| {
            format!("native backend: stage {j} input must be f32 (mlp family)")
        })
    }
}

#[allow(clippy::too_many_arguments)]
impl Backend for NativeBackend {
    type Act = HostTensor;
    type Exec = NativeExec;

    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init_params_flat(&self) -> Result<Vec<f32>> {
        Ok(self.init.clone())
    }

    fn executor(&self, mode: ExecMode) -> NativeExec {
        NativeExec { _requested: mode }
    }

    fn exec_mode(&self, _exec: &NativeExec) -> ExecMode {
        // single execution path: a requested DeviceResident coerces here
        ExecMode::HostLiteral
    }

    fn input(&self, _exec: &mut NativeExec, x: HostTensor) -> Result<HostTensor> {
        Ok(x)
    }

    fn fwd(
        &self,
        _exec: &mut NativeExec,
        stage: usize,
        _version: u64,
        flat: &[f32],
        x: &HostTensor,
    ) -> Result<HostTensor> {
        // kernel-level spans are opt-in (trace --trace-kernels knob);
        // kernel_start is a single atomic load when the knob is off
        let t0 = crate::trace::kernel_start();
        let y = HostTensor::F32(Backend::stage_fwd_flat(self, stage, flat, x)?);
        crate::trace::kernel_end(t0, 0, stage, _version);
        Ok(y)
    }

    fn last_bwd(
        &self,
        _exec: &mut NativeExec,
        _version: u64,
        flat: &[f32],
        x: &HostTensor,
        targets: &IntTensor,
        gdst: &mut [f32],
    ) -> Result<(f32, HostTensor)> {
        let last = self.manifest.n_stages - 1;
        let x = self.act_f32(last, x)?;
        let t0 = crate::trace::kernel_start();
        let (loss, gx) = self.stage_bwd(last, flat, x, None, Some(targets), gdst)?;
        crate::trace::kernel_end(t0, 1, last, _version);
        Ok((loss, HostTensor::F32(gx)))
    }

    fn mid_bwd(
        &self,
        _exec: &mut NativeExec,
        stage: usize,
        _version: u64,
        flat: &[f32],
        x: &HostTensor,
        gy: &HostTensor,
        gdst: &mut [f32],
    ) -> Result<HostTensor> {
        let x = self.act_f32(stage, x)?;
        let gy = self.act_f32(stage, gy)?;
        let t0 = crate::trace::kernel_start();
        let (_, gx) = self.stage_bwd(stage, flat, x, Some(gy), None, gdst)?;
        crate::trace::kernel_end(t0, 1, stage, _version);
        Ok(HostTensor::F32(gx))
    }

    fn first_bwd(
        &self,
        _exec: &mut NativeExec,
        _version: u64,
        flat: &[f32],
        x: &HostTensor,
        gy: &HostTensor,
        gdst: &mut [f32],
    ) -> Result<()> {
        let x = self.act_f32(0, x)?;
        let gy = self.act_f32(0, gy)?;
        let t0 = crate::trace::kernel_start();
        self.stage_bwd(0, flat, x, Some(gy), None, gdst)?;
        crate::trace::kernel_end(t0, 1, 0, _version);
        Ok(())
    }

    fn sgd(
        &self,
        _exec: &mut NativeExec,
        stage: usize,
        _version: u64,
        cur: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let t0 = crate::trace::kernel_start();
        Backend::sgd_update_flat(self, stage, cur, moms, grads, lr, out)?;
        crate::trace::kernel_end(t0, 2, stage, _version);
        Ok(())
    }

    fn stage_fwd_flat(&self, stage: usize, flat: &[f32], x: &HostTensor) -> Result<Tensor> {
        anyhow::ensure!(
            stage + 1 < self.manifest.n_stages,
            "stage_fwd_flat on the loss stage — use last_fwd_loss_flat/predict_flat"
        );
        let x = self.act_f32(stage, x)?;
        let flat_q = self.q_params(flat);
        let x_q = self.q_act(x);
        let (mut h, _, _, _) = self.body_fwd(stage, &flat_q, &x_q, false)?;
        if self.precision == Precision::Bf16 {
            // quantize the stage-boundary hand-off (see module docs)
            bf16::round_slice(&mut h);
        }
        let b = x.shape[0];
        Ok(Tensor::new(vec![b, self.shape.hidden], h))
    }

    fn last_fwd_loss_flat(
        &self,
        flat: &[f32],
        x: &Tensor,
        targets: &IntTensor,
    ) -> Result<f32> {
        let logits = self.logits(flat, x)?;
        Ok(ops::softmax_ce_loss(&logits, &targets.data, self.shape.classes))
    }

    fn predict_flat(&self, flat: &[f32], x: &Tensor) -> Result<Tensor> {
        let logits = self.logits(flat, x)?;
        let b = x.shape[0];
        Ok(Tensor::new(vec![b, self.shape.classes], logits))
    }

    /// The python `sgd_momentum` kernel, elementwise over the flat run:
    /// m' = µ·m + g; p' = p − lr·m' (µ from the manifest).  Partitioned
    /// across the kernel pool in fast mode — elementwise with no
    /// reduction, so bit-identical at any thread count.  Always f32: the
    /// master parameters and optimizer state are full-precision in every
    /// [`Precision`] mode.
    fn sgd_update_flat(
        &self,
        stage: usize,
        params: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(
            params.len() == moms.len()
                && params.len() == grads.len()
                && params.len() == out.len()
                && params.len() == self.layout.stage_len(stage),
            "stage {stage}: flat run length mismatch"
        );
        let mu = self.manifest.momentum;
        let len = params.len();
        if ops::kernel_mode() == ops::KernelMode::ScalarReference {
            for i in 0..len {
                let m = mu * moms[i] + grads[i];
                out[i] = params[i] - lr * m;
                moms[i] = m;
            }
            return Ok(());
        }
        // Elementwise with no reduction: any index partition produces the
        // same bits, so the pool split is unconditionally bit-identical to
        // the scalar loop above.
        let nblocks = par::partition(len, 4096);
        let per = len.div_ceil(nblocks.max(1)).max(1);
        let pm = par::SendPtr(moms.as_mut_ptr());
        let po = par::SendPtr(out.as_mut_ptr());
        par::run(nblocks, |blk| {
            let lo = blk * per;
            let hi = (lo + per).min(len);
            if lo >= hi {
                return;
            }
            // disjoint [lo, hi) windows per block — no two blocks alias
            let mb = unsafe { std::slice::from_raw_parts_mut(pm.0.add(lo), hi - lo) };
            let ob = unsafe { std::slice::from_raw_parts_mut(po.0.add(lo), hi - lo) };
            let pb = &params[lo..hi];
            let gb = &grads[lo..hi];
            for i in 0..hi - lo {
                let m = mu * mb[i] + gb[i];
                ob[i] = pb[i] - lr * m;
                mb[i] = m;
            }
        });
        Ok(())
    }
}

/// Check the manifest describes an mlp-family model this backend can
/// execute, and extract its dimensions.
fn validate_mlp(m: &Manifest) -> Result<MlpShape> {
    anyhow::ensure!(
        m.family == "mlp",
        "native backend executes the mlp family only, bundle `{}` is `{}` — \
         build with `--features xla` for transformer/convnet",
        m.name,
        m.family
    );
    anyhow::ensure!(m.n_stages >= 1, "empty model");
    let first = &m.stages[0];
    anyhow::ensure!(
        first.params.len() >= 2 && first.params[0].shape.len() == 2,
        "stage 0 must start with the input projection"
    );
    let input_dim = first.params[0].shape[0];
    let hidden = first.params[0].shape[1];
    let last = &m.stages[m.n_stages - 1];
    let head_w = &last.params[last.params.len() - 2];
    anyhow::ensure!(
        head_w.shape.len() == 2 && head_w.shape[0] == hidden,
        "loss head shape mismatch"
    );
    let classes = head_w.shape[1];
    // every stage: optional [D,H]+[H] prologue, pairs of [H,H]+[H]
    // residual layers, optional [H,C]+[C] head — validated by elimination
    for (j, st) in m.stages.iter().enumerate() {
        let extras =
            usize::from(j == 0) * 2 + usize::from(j == m.n_stages - 1) * 2;
        anyhow::ensure!(
            st.params.len() >= extras && (st.params.len() - extras) % 2 == 0,
            "stage {j}: parameter count {} does not match the mlp pattern",
            st.params.len()
        );
        let lo = usize::from(j == 0) * 2;
        let hi = st.params.len() - usize::from(j == m.n_stages - 1) * 2;
        for pair in st.params[lo..hi].chunks_exact(2) {
            anyhow::ensure!(
                pair[0].shape == [hidden, hidden] && pair[1].shape == [hidden],
                "stage {j}: residual layer shape mismatch (want [{hidden},{hidden}]+[{hidden}])"
            );
        }
    }
    Ok(MlpShape { input_dim, hidden, classes })
}

/// Synthesize the manifest of an in-memory mlp bundle (mirrors the
/// stage/spec construction of `python/compile/model.py::Mlp` +
/// `aot.py`'s manifest emission).
fn synthetic_manifest(cfg: &NativeMlpConfig) -> Manifest {
    let (h, d, c, mb) = (cfg.hidden, cfg.input_dim, cfg.classes, cfg.microbatch);
    let mut stages = Vec::with_capacity(cfg.n_stages);
    for j in 0..cfg.n_stages {
        let mut params = Vec::new();
        if j == 0 {
            params.push(ParamSpec { name: "in_w".into(), shape: vec![d, h] });
            params.push(ParamSpec { name: "in_b".into(), shape: vec![h] });
        }
        for l in 0..cfg.layers_per_stage {
            params.push(ParamSpec { name: format!("s{j}l{l}_w"), shape: vec![h, h] });
            params.push(ParamSpec { name: format!("s{j}l{l}_b"), shape: vec![h] });
        }
        if j == cfg.n_stages - 1 {
            params.push(ParamSpec { name: "out_w".into(), shape: vec![h, c] });
            params.push(ParamSpec { name: "out_b".into(), shape: vec![c] });
        }
        let input = if j == 0 {
            IoSpec { shape: vec![mb, d], dtype: DType::F32 }
        } else {
            IoSpec { shape: vec![mb, h], dtype: DType::F32 }
        };
        let output = (j != cfg.n_stages - 1)
            .then(|| IoSpec { shape: vec![mb, h], dtype: DType::F32 });
        // analytic accounting, following Mlp.stage_act_bytes / stage_flops
        let per_elem = 2 * cfg.layers_per_stage as u64 + if j == 0 { 2 } else { 0 };
        let act_bytes = 4 * mb as u64 * h as u64 * per_elem;
        let mut flops = 2 * (mb * h * h * cfg.layers_per_stage) as u64;
        if j == 0 {
            flops += 2 * (mb * d * h) as u64;
        }
        if j == cfg.n_stages - 1 {
            flops += 2 * (mb * h * c) as u64;
        }
        stages.push(StageSpec {
            index: j,
            params,
            input,
            output,
            act_bytes,
            flops,
            artifacts: Vec::new(),
        });
    }
    let total_param_elems = stages.iter().map(|s| s.param_elems()).sum();
    Manifest {
        name: "native_mlp".into(),
        family: "mlp".into(),
        n_stages: cfg.n_stages,
        n_microbatches: if cfg.n_microbatches == 0 {
            cfg.n_stages
        } else {
            cfg.n_microbatches
        },
        lr: cfg.lr,
        momentum: cfg.momentum,
        data: DataSpec::Class {
            classes: c,
            input_dim: d,
            batch: mb,
            noise: cfg.noise,
            seed: cfg.data_seed,
        },
        target: IoSpec { shape: vec![mb], dtype: DType::I32 },
        stages,
        total_param_elems,
        golden_steps: 0,
        dir: std::path::PathBuf::from("<native_mlp synthetic>"),
    }
}

/// Deterministic θ_0 (one sequential RNG stream over tensors in arena
/// order): weights ~ N(0, 1/√fan_in), the classifier head ~ N(0, 0.05)
/// so the initial loss sits at ln(classes), biases zero — the same
/// scheme as `Mlp.init_params`, realized with the crate's RNG.
fn init_params(m: &Manifest, seed: u64) -> Vec<f32> {
    let mut rng = XorShift64Star::new(splitmix64(seed ^ 0x1417));
    let mut out = Vec::with_capacity(m.total_param_elems);
    for st in &m.stages {
        for p in &st.params {
            let n = p.elems();
            if p.name.ends_with("_b") {
                out.extend(std::iter::repeat_n(0.0f32, n));
            } else {
                let std = if p.name == "out_w" {
                    0.05
                } else {
                    (1.0 / p.shape[0] as f32).sqrt()
                };
                out.extend((0..n).map(|_| std * rng.normal()));
            }
        }
    }
    debug_assert_eq!(out.len(), m.total_param_elems);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_is_self_consistent() {
        let nb = NativeBackend::default_mlp();
        let m = &nb.manifest;
        assert_eq!(m.n_stages, 4);
        assert_eq!(m.stages.len(), 4);
        assert_eq!(
            m.total_param_elems,
            m.stages.iter().map(|s| s.param_elems()).sum::<usize>()
        );
        assert_eq!(nb.init.len(), m.total_param_elems);
        assert!(validate_mlp(m).is_ok());
        // stage shapes: 0 has prologue, last has head
        assert_eq!(nb.stage_shape(0), (true, 2, false));
        assert_eq!(nb.stage_shape(3), (false, 2, true));
        assert!(m.psi_p_bytes() > 0 && m.b_psi_a_bytes() > 0);
    }

    #[test]
    fn repartitioned_preserves_totals() {
        let nb = NativeBackend::synthetic(NativeMlpConfig::deep_narrow());
        assert_eq!(nb.synthetic_config().unwrap().n_stages, 4);
        let re = nb.repartitioned(8).unwrap(); // 16 residual layers → 8×2
        assert_eq!(re.manifest.n_stages, 8);
        assert_eq!(re.manifest.n_microbatches, 8);
        assert_eq!(re.manifest.total_param_elems, nb.manifest.total_param_elems);
        assert!(validate_mlp(&re.manifest).is_ok());
        assert!(nb.repartitioned(5).is_err(), "5 does not divide 16");
        let one = nb.repartitioned(1).unwrap();
        assert_eq!(one.manifest.n_stages, 1);
        assert!(validate_mlp(&one.manifest).is_ok());
    }

    #[test]
    fn init_is_deterministic_and_finite() {
        let a = NativeBackend::default_mlp();
        let b = NativeBackend::default_mlp();
        assert_eq!(a.init, b.init);
        assert!(a.init.iter().all(|x| x.is_finite()));
        // biases zero, weights not all zero
        assert!(a.init.iter().any(|x| *x != 0.0));
    }

    #[test]
    fn forward_shapes_and_initial_loss_near_ln_classes() {
        let nb = NativeBackend::default_mlp();
        let data = crate::data::DataSource::from_manifest(&nb.manifest);
        let crate::data::MicroBatch::Class { x, labels } = data.microbatch(0, 0) else {
            panic!("mlp bundle is classification")
        };
        let flat = nb.init_params_flat().unwrap();
        let l = nb.layout().clone();
        let batch = nb.manifest.target.shape[0];
        let mut a = HostTensor::F32(x);
        for j in 0..nb.manifest.n_stages - 1 {
            let y = Backend::stage_fwd_flat(&nb, j, &flat[l.stage_range(j)], &a).unwrap();
            assert_eq!(y.shape, vec![batch, nb.shape.hidden]);
            assert!(y.is_finite());
            a = HostTensor::F32(y);
        }
        let last = nb.manifest.n_stages - 1;
        let loss = nb
            .last_fwd_loss_flat(&flat[l.stage_range(last)], a.as_f32().unwrap(), &labels)
            .unwrap();
        // small head init ⇒ logits near zero ⇒ loss near ln(10); the
        // residual growth across 8 layers inflates it somewhat (≈ 2.69
        // for the default seeds, vs ln 10 ≈ 2.30)
        assert!((loss - 10.0f32.ln()).abs() < 0.6, "initial loss {loss}");
    }

    #[test]
    fn bf16_mode_is_deterministic_and_tracks_f32() {
        let nb = NativeBackend::default_mlp();
        let nb16 = NativeBackend::default_mlp().with_precision(Precision::Bf16);
        assert_eq!(nb16.precision().name(), "bf16");
        let data = crate::data::DataSource::from_manifest(&nb.manifest);
        let crate::data::MicroBatch::Class { x, labels } = data.microbatch(0, 0) else {
            panic!("mlp bundle is classification")
        };
        let flat = nb.init_params_flat().unwrap();
        let l = nb.layout().clone();
        let run = |b: &NativeBackend| -> f32 {
            let mut a = HostTensor::F32(x.clone());
            for j in 0..b.manifest.n_stages - 1 {
                let y = Backend::stage_fwd_flat(b, j, &flat[l.stage_range(j)], &a).unwrap();
                a = HostTensor::F32(y);
            }
            let last = b.manifest.n_stages - 1;
            b.last_fwd_loss_flat(&flat[l.stage_range(last)], a.as_f32().unwrap(), &labels)
                .unwrap()
        };
        let lf = run(&nb);
        let l16a = run(&nb16);
        let l16b = run(&nb16);
        // fixed rounding points ⇒ bit-identical across repeats
        assert_eq!(l16a.to_bits(), l16b.to_bits(), "bf16 must be deterministic");
        // ≤ 2⁻⁸ relative per rounding; loosely bounded end-to-end
        assert!(
            (lf - l16a).abs() / lf.abs().max(1e-6) < 0.05,
            "f32 loss {lf} vs bf16 loss {l16a}"
        );
    }
}
