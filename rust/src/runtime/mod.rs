//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator's hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Interchange is HLO **text** because the
//! crate's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos
//! (64-bit instruction ids); the text parser reassigns ids.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.

pub mod bundle;
pub mod literal;

use std::path::Path;

use anyhow::{Context, Result};

pub use bundle::BundleRuntime;
pub use literal::{
    literal_into_slice, literal_to_tensor, slice_to_literal, tensor_to_literal,
};

/// Shared PJRT client + compile cache keyed by artifact path.
pub struct Engine {
    pub client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(anyhow_xla)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(anyhow_xla)
            .with_context(|| format!("compile {path:?}"))
    }
}

/// The `xla` crate error type doesn't implement std::error::Error for
/// anyhow conversion in all versions; normalize here.
pub fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// Execute and unpack the single-tuple result into literals.
/// Accepts owned or borrowed literals (the param-literal cache passes refs).
pub fn execute_tuple<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[L],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<L>(args).map_err(anyhow_xla)?;
    let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
    lit.to_tuple().map_err(anyhow_xla)
}
