//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator's hot path — through the literal boundary (the
//! reference path) or the device-resident boundary ([`device_store`]:
//! persistent parameter/momentum buffers, device-side activation
//! hand-off, transfer accounting).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Interchange is HLO **text** because the
//! crate's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos
//! (64-bit instruction ids); the text parser reassigns ids.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.

pub mod bundle;
pub mod device_store;
pub mod literal;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

pub use bundle::{BundleRuntime, Kind};
pub use device_store::{Act, DeviceParamStore, DeviceTensor, ExecMode, Executor};
pub use literal::{
    literal_into_slice, literal_to_tensor, slice_to_literal, tensor_to_literal,
};

/// Host↔device transfer accounting at the runtime boundary (DESIGN-PERF.md
/// §Device residency).  Counted where the data crosses: literal/buffer
/// construction from host state is `h2d`, literal read-back is `d2h`.
/// `param_uploads` counts *stage-level* parameter upload events — the
/// quantity the device-resident contract bounds (≤ 1 per stage per
/// committed θ-version, vs one per stage per micro-batch on the literal
/// path).  Atomics so the shared runtime can account from worker threads.
#[derive(Debug, Default)]
pub struct TransferStats {
    pub h2d_bytes: AtomicU64,
    pub d2h_bytes: AtomicU64,
    pub param_uploads: AtomicU64,
}

impl TransferStats {
    pub fn add_h2d(&self, bytes: u64) {
        self.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_d2h(&self, bytes: u64) {
        self.d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_param_upload(&self, bytes: u64) {
        self.param_uploads.fetch_add(1, Ordering::Relaxed);
        self.add_h2d(bytes);
    }

    pub fn h2d_bytes(&self) -> u64 {
        self.h2d_bytes.load(Ordering::Relaxed)
    }

    pub fn d2h_bytes(&self) -> u64 {
        self.d2h_bytes.load(Ordering::Relaxed)
    }

    pub fn param_uploads(&self) -> u64 {
        self.param_uploads.load(Ordering::Relaxed)
    }

    /// Zero all counters (benches snapshot between phases).
    pub fn reset(&self) {
        self.h2d_bytes.store(0, Ordering::Relaxed);
        self.d2h_bytes.store(0, Ordering::Relaxed);
        self.param_uploads.store(0, Ordering::Relaxed);
    }
}

/// Shared PJRT client + compile cache keyed by artifact path.
pub struct Engine {
    pub client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(anyhow_xla)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(anyhow_xla)
            .with_context(|| format!("compile {path:?}"))
    }
}

/// The `xla` crate error type doesn't implement std::error::Error for
/// anyhow conversion in all versions; normalize here.
pub fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// Execute and unpack the single-tuple result into literals.
/// Accepts owned or borrowed literals (the param-literal cache passes refs).
pub fn execute_tuple<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[L],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<L>(args).map_err(anyhow_xla)?;
    let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
    lit.to_tuple().map_err(anyhow_xla)
}

/// Device-buffer variant of [`execute_tuple`]: arguments are resident
/// `PjRtBuffer`s (`PjRtLoadedExecutable::execute_b`), so no host→device
/// argument conversion happens per call — the parameter buffers in a
/// [`DeviceParamStore`] are passed by reference micro-batch after
/// micro-batch.  The crate returns the result as a single tuple buffer
/// (same convention as [`execute_tuple`]); splitting it into elements
/// happens at the literal layer, which on the CPU PJRT backend is one
/// memcpy — see DESIGN-PERF.md §Device residency for what this does and
/// does not avoid.
pub fn execute_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[B],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute_b::<B>(args).map_err(anyhow_xla)?;
    let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
    lit.to_tuple().map_err(anyhow_xla)
}
