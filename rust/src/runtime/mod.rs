//! Execution runtimes behind one [`Backend`] boundary (DESIGN-PERF.md
//! §Backend boundary):
//!
//! - [`backend`] — the trait the coordinators drive: stage forward,
//!   first/mid/last backward into arena slices, fused SGD, predict+loss,
//!   plus [`ExecMode`] and backend selection (`CDP_BACKEND`).
//! - [`native`]  — pure-Rust [`NativeBackend`]: the mlp stage graphs
//!   executed with `tensor::ops` kernels.  The default build; zero
//!   external dependencies.
//! - [`bundle`] / [`device_store`] / [`literal`] (feature `xla`) — the
//!   PJRT path: load AOT HLO-text artifacts, compile once, execute
//!   through the literal boundary or the device-resident boundary
//!   (persistent parameter/momentum buffers, device-side activation
//!   hand-off, transfer accounting).
//!
//! The XLA path wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Interchange is HLO **text** because the
//! crate's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos
//! (64-bit instruction ids); the text parser reassigns ids.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained — and without the `xla` feature, self-contained from
//! `cargo build` alone.

pub mod backend;
#[cfg(feature = "xla")]
pub mod bundle;
#[cfg(feature = "xla")]
pub mod device_store;
#[cfg(feature = "xla")]
pub mod literal;
pub mod native;

use std::sync::atomic::{AtomicU64, Ordering};

pub use backend::{backend_choice, Activation, Backend, BackendChoice, ExecMode, Precision};
pub use native::{NativeBackend, NativeExec, NativeMlpConfig};

#[cfg(feature = "xla")]
pub use bundle::{BundleRuntime, Kind, XlaBackend};
#[cfg(feature = "xla")]
pub use device_store::{Act, DeviceParamStore, DeviceTensor, Executor};
#[cfg(feature = "xla")]
pub use literal::{
    literal_into_slice, literal_to_tensor, slice_to_literal, tensor_to_literal,
};

/// Host↔device transfer accounting at the runtime boundary (DESIGN-PERF.md
/// §Device residency).  Counted where the data crosses: literal/buffer
/// construction from host state is `h2d`, literal read-back is `d2h`.
/// `param_uploads` counts *stage-level* parameter upload events — the
/// quantity the device-resident contract bounds (≤ 1 per stage per
/// committed θ-version, vs one per stage per micro-batch on the literal
/// path).  Atomics so the shared runtime can account from worker threads.
/// The native backend has no device and keeps these at zero.
#[derive(Debug, Default)]
pub struct TransferStats {
    pub h2d_bytes: AtomicU64,
    pub d2h_bytes: AtomicU64,
    pub param_uploads: AtomicU64,
}

impl TransferStats {
    pub fn add_h2d(&self, bytes: u64) {
        self.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_d2h(&self, bytes: u64) {
        self.d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_param_upload(&self, bytes: u64) {
        self.param_uploads.fetch_add(1, Ordering::Relaxed);
        self.add_h2d(bytes);
    }

    pub fn h2d_bytes(&self) -> u64 {
        self.h2d_bytes.load(Ordering::Relaxed)
    }

    pub fn d2h_bytes(&self) -> u64 {
        self.d2h_bytes.load(Ordering::Relaxed)
    }

    pub fn param_uploads(&self) -> u64 {
        self.param_uploads.load(Ordering::Relaxed)
    }

    /// Zero all counters (benches snapshot between phases).
    pub fn reset(&self) {
        self.h2d_bytes.store(0, Ordering::Relaxed);
        self.d2h_bytes.store(0, Ordering::Relaxed);
        self.param_uploads.store(0, Ordering::Relaxed);
    }
}

/// Shared PJRT client + compile cache keyed by artifact path.
#[cfg(feature = "xla")]
pub struct Engine {
    pub client: xla::PjRtClient,
}

#[cfg(feature = "xla")]
impl Engine {
    pub fn cpu() -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_hlo_file(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        use anyhow::Context;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(anyhow_xla)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(anyhow_xla)
            .with_context(|| format!("compile {path:?}"))
    }
}

/// The `xla` crate error type doesn't implement std::error::Error for
/// anyhow conversion in all versions; normalize here.
#[cfg(feature = "xla")]
pub fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// Execute and unpack the single-tuple result into literals.
/// Accepts owned or borrowed literals (the param-literal cache passes refs).
#[cfg(feature = "xla")]
pub fn execute_tuple<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[L],
) -> anyhow::Result<Vec<xla::Literal>> {
    let result = exe.execute::<L>(args).map_err(anyhow_xla)?;
    let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
    lit.to_tuple().map_err(anyhow_xla)
}

/// Device-buffer variant of [`execute_tuple`]: arguments are resident
/// `PjRtBuffer`s (`PjRtLoadedExecutable::execute_b`), so no host→device
/// argument conversion happens per call — the parameter buffers in a
/// [`DeviceParamStore`] are passed by reference micro-batch after
/// micro-batch.  The crate returns the result as a single tuple buffer
/// (same convention as [`execute_tuple`]); splitting it into elements
/// happens at the literal layer, which on the CPU PJRT backend is one
/// memcpy — see DESIGN-PERF.md §Device residency for what this does and
/// does not avoid.
#[cfg(feature = "xla")]
pub fn execute_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[B],
) -> anyhow::Result<Vec<xla::Literal>> {
    let result = exe.execute_b::<B>(args).map_err(anyhow_xla)?;
    let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
    lit.to_tuple().map_err(anyhow_xla)
}
