//! Metrics: named counters + time series, CSV/JSON emission.
//!
//! The trainers and the simulator record everything through this module so
//! benches and examples can print the paper's tables/figures from one
//! place.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::json::{Json, JsonError};

/// Append-only series of (step, value) — loss curves, memory curves.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn values(&self) -> Vec<f64> {
        self.points.iter().map(|(_, y)| *y).collect()
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|(_, y)| *y)
    }

    /// Trailing-window mean (the paper smooths Fig 3 over 7 epochs).
    pub fn smoothed(&self, window: usize) -> Vec<(f64, f64)> {
        let w = window.max(1);
        self.points
            .iter()
            .enumerate()
            .map(|(i, (x, _))| {
                let lo = i.saturating_sub(w - 1);
                let mean = self.points[lo..=i].iter().map(|(_, y)| y).sum::<f64>()
                    / (i - lo + 1) as f64;
                (*x, mean)
            })
            .collect()
    }
}

/// A run's metric sink: counters + series, dumpable as CSV or JSON.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, u64>,
    pub series: BTreeMap<String, Series>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        // get_mut-first: the steady-state path (key already present)
        // must not allocate a `String` per call.
        if let Some(c) = self.counters.get_mut(name) {
            *c += by;
        } else {
            self.counters.insert(name.to_string(), by);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn record(&mut self, series: &str, x: f64, y: f64) {
        // Same discipline as `inc`: allocate the key only on first use.
        if let Some(s) = self.series.get_mut(series) {
            s.push(x, y);
        } else {
            let mut s = Series::new(series);
            s.push(x, y);
            self.series.insert(series.to_string(), s);
        }
    }

    pub fn get_series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        obj.insert("counters".to_string(), Json::Obj(counters));
        let series: BTreeMap<String, Json> = self
            .series
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    Json::Arr(
                        s.points
                            .iter()
                            .map(|(x, y)| {
                                Json::Arr(vec![Json::Num(*x), Json::Num(*y)])
                            })
                            .collect(),
                    ),
                )
            })
            .collect();
        obj.insert("series".to_string(), Json::Obj(series));
        Json::Obj(obj)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// CSV with one column per series, aligned by index.
    pub fn write_series_csv(&self, path: &Path, names: &[&str]) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        write!(f, "step")?;
        for n in names {
            write!(f, ",{n}")?;
        }
        writeln!(f)?;
        let rows = names
            .iter()
            .filter_map(|n| self.series.get(*n))
            .map(|s| s.points.len())
            .max()
            .unwrap_or(0);
        for i in 0..rows {
            write!(f, "{i}")?;
            for n in names {
                match self.series.get(*n).and_then(|s| s.points.get(i)) {
                    Some((_, y)) => write!(f, ",{y}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// Parse a metrics JSON back (round-trip for tooling/tests).
pub fn parse_metrics(text: &str) -> Result<Metrics, JsonError> {
    let j = Json::parse(text)?;
    let mut m = Metrics::new();
    if let Some(Json::Obj(cs)) = j.get("counters") {
        for (k, v) in cs {
            if let Some(n) = v.as_f64() {
                m.counters.insert(k.clone(), n as u64);
            }
        }
    }
    if let Some(Json::Obj(ss)) = j.get("series") {
        for (k, v) in ss {
            let mut s = Series::new(k);
            if let Some(points) = v.as_arr() {
                for p in points {
                    if let Some(pair) = p.as_arr() {
                        if pair.len() == 2 {
                            s.push(pair[0].as_f64().unwrap(), pair[1].as_f64().unwrap());
                        }
                    }
                }
            }
            m.series.insert(k.clone(), s);
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_series() {
        let mut m = Metrics::new();
        m.inc("comm_bytes", 100);
        m.inc("comm_bytes", 20);
        m.record("loss", 0.0, 4.0);
        m.record("loss", 1.0, 3.0);
        assert_eq!(m.counter("comm_bytes"), 120);
        assert_eq!(m.get_series("loss").unwrap().values(), vec![4.0, 3.0]);
        assert_eq!(m.get_series("loss").unwrap().last(), Some(3.0));
    }

    #[test]
    fn smoothing_window() {
        let mut s = Series::new("x");
        for i in 0..5 {
            s.push(i as f64, (i as f64) * 2.0);
        }
        let sm = s.smoothed(2);
        assert_eq!(sm[0].1, 0.0);
        assert_eq!(sm[1].1, 1.0); // mean(0, 2)
        assert_eq!(sm[4].1, 7.0); // mean(6, 8)
    }

    #[test]
    fn json_roundtrip() {
        let mut m = Metrics::new();
        m.inc("a", 7);
        m.record("s", 0.0, 1.5);
        let text = m.to_json().to_string();
        let back = parse_metrics(&text).unwrap();
        assert_eq!(back.counter("a"), 7);
        assert_eq!(back.get_series("s").unwrap().values(), vec![1.5]);
    }

    #[test]
    fn non_finite_series_round_trip_as_valid_json() {
        let mut m = Metrics::new();
        m.record("loss", 0.0, f64::NAN);
        m.record("loss", 1.0, f64::INFINITY);
        m.record("loss", 2.0, f64::NEG_INFINITY);
        m.record("loss", 3.0, 0.25);
        let text = m.to_json().to_string();
        // The emitted document must be parseable JSON even with the
        // diverged-loss values in it.
        let back = parse_metrics(&text).unwrap();
        let vals = back.get_series("loss").unwrap().values();
        assert!(vals[0].is_nan());
        assert_eq!(vals[1], f64::INFINITY);
        assert_eq!(vals[2], f64::NEG_INFINITY);
        assert_eq!(vals[3], 0.25);
    }
}
