//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters with defaults keep call sites terse.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); skip argv[0] yourself.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                    out.present.push(rest.to_string());
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                    out.present.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: expected integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: expected integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: expected number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key}: expected bool, got `{v}`"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn kv_styles() {
        let a = parse("train --bundle tiny --steps=10 --verbose --lr 0.5");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str_or("bundle", "x"), "tiny");
        assert_eq!(a.usize_or("steps", 0), 10);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.f64_or("lr", 0.0), 0.5);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.str_or("bundle", "tiny"), "tiny");
        assert!(!a.has("anything"));
    }

    #[test]
    fn flag_before_positional() {
        let a = parse("--flag sub cmd");
        // `--flag sub`: consumes `sub` as its value (documented behaviour)
        assert_eq!(a.get("flag"), Some("sub"));
        assert_eq!(a.positional, vec!["cmd"]);
    }
}
