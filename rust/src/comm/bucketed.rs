//! Eager bucketed gradient reduction (DESIGN-PERF.md §Bucket overlap).
//!
//! The step-boundary reductions the trainers shipped with serialize
//! compute and communication: a worker finishes its *entire* backward
//! pass, then the gradient ring (or the ZeRO shard sends) start.  The
//! paper's point (§3, Fig 1c) — echoed by PipeDream's weight stashing and
//! ZeRO/OSDP bucketing — is that gradient communication can be *balanced
//! across the step*: stage `s`'s gradients are final the moment stage
//! `s`'s backward lands, while stages `s−1..0` still have backprop left
//! to run.
//!
//! [`BucketedReducer`] realizes that: each stage's flat gradient run is
//! partitioned into fixed-size buckets ([`ArenaLayout::stage_buckets`]),
//! and the ring hop / shard send for bucket `b` of stage `s` launches as
//! soon as the trainer's backward callback reaches stage `s` — the comm
//! for stage `s` overlaps the backward of stage `s−1`.
//!
//! Determinism: within every bucket the partial sums still accumulate in
//! micro-batch order 1..N (the ring's first member starts, each member
//! adds its own contribution, the owner folds the last add and the 1/N
//! average into one fused pass).  Per element this is exactly the sum
//! order of the step-boundary reduction, so loss sequences remain
//! bit-identical to the reference trainer — asserted in rust/tests/.
//!
//! The ring protocol is addressed through a [`RingView`] — position-based
//! roles over explicit endpoint ids — so after a worker loss the
//! survivors re-form an N−1 ring ([`RingView::from_live`]) and the same
//! code runs unchanged (DESIGN-ROBUSTNESS.md).

use crate::comm::{tags, CommError, Endpoint, EventKind, RingView};
use crate::parallel::arena::ArenaLayout;
use crate::tensor::ops;

/// Default bucket granularity: 16 Ki f32 (64 KiB) — small enough that a
/// wide stage yields several overlappable launches, large enough that
/// per-bucket tag/queue overhead stays negligible.
pub const DEFAULT_BUCKET_ELEMS: usize = 16 * 1024;

/// Bucket size override for experiments: `CDP_BUCKET_ELEMS=<n>`.
pub fn bucket_elems_from_env() -> usize {
    std::env::var("CDP_BUCKET_ELEMS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|n| *n > 0)
        .unwrap_or(DEFAULT_BUCKET_ELEMS)
}

/// Hard cap on buckets per stage — the tag sub-field budget
/// ([`tags::grad_shard`] carries 14 bucket bits, the tighter of the two
/// grad-bucket namespaces).  Exceeding it would alias tags, so bucket
/// sizes are clamped to respect it rather than trusted.
pub const MAX_BUCKETS_PER_STAGE: usize = 1 << 14;

/// The bucket size actually used for a stage: the configured size,
/// raised just enough that the stage tiles into ≤
/// [`MAX_BUCKETS_PER_STAGE`] buckets.  Pure function of (configured
/// size, stage length), so every worker — sender and receiver — derives
/// the identical partition from the shared layout.
pub fn effective_bucket_elems(bucket_elems: usize, stage_len: usize) -> usize {
    bucket_elems.max(stage_len.div_ceil(MAX_BUCKETS_PER_STAGE))
}

/// Fixed-size bucket partitioner + the eager reduction protocols built on
/// it.  Stateless apart from the bucket size, so every worker constructs
/// its own (the *layout* is the shared contract).
#[derive(Clone, Copy, Debug)]
pub struct BucketedReducer {
    pub bucket_elems: usize,
}

impl BucketedReducer {
    pub fn new(bucket_elems: usize) -> Self {
        assert!(bucket_elems > 0, "bucket_elems must be positive");
        Self { bucket_elems }
    }

    pub fn from_env() -> Self {
        Self::new(bucket_elems_from_env())
    }

    /// Clamped bucket size for one stage (see [`effective_bucket_elems`]).
    fn stage_elems(&self, layout: &ArenaLayout, stage: usize) -> usize {
        effective_bucket_elems(self.bucket_elems, layout.stage_len(stage))
    }

    /// Eager ring hop for one stage of the multi-trainer CDP ring, called
    /// by ring member `ring.pos` the moment stage `stage`'s backward
    /// output lands in `own` (the worker's flat stage-run gradients).
    /// The first member (position 0, micro-batch 1) launches each bucket
    /// immediately; middle members add their contribution to the received
    /// partial in place and forward the handle; the owner (position
    /// `m−1`, the only optimizer state) folds its own contribution and
    /// the 1/m average into one fused pass per bucket, assembling the
    /// averaged stage sums into `avg_out`.
    ///
    /// `avg_out` must be `Some` exactly on the owner.  Per-element sum
    /// order is micro-batch order 1..m — bit-identical to the step-
    /// boundary ring it replaces.  `ring` is usually [`RingView::full`];
    /// after a worker loss the survivors pass [`RingView::from_live`] and
    /// the reduction runs on the smaller ring unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn ring_stage(
        &self,
        ep: &mut Endpoint,
        ring: &RingView,
        layout: &ArenaLayout,
        step: u64,
        stage: usize,
        own: &[f32],
        mut avg_out: Option<&mut [f32]>,
    ) -> Result<(), CommError> {
        let m = ring.m;
        let pos = ring.pos;
        let owner = m - 1;
        let inv = 1.0 / m as f32;
        debug_assert_eq!(own.len(), layout.stage_len(stage));
        debug_assert_eq!(avg_out.is_some(), pos == owner, "avg_out ⇔ owner");
        if m == 1 {
            // single member: own grads are the full sum (inv == 1.0, the
            // scale still runs so the averaged contract is uniform)
            let out = avg_out.expect("single member is the owner");
            out.copy_from_slice(own);
            ops::scale(out, inv);
            return Ok(());
        }
        for b in layout.stage_buckets(stage, self.stage_elems(layout, stage)) {
            let tag = tags::grad_bucket(step, stage, b.index);
            let nbytes = b.len() as u64 * 4;
            if pos == 0 {
                ep.stats().mark(EventKind::GradSend, ep.id, stage, step, nbytes);
                ep.send_copy(ring.right, tag, &own[b.range()])?;
            } else {
                let mut part = ep.recv(ring.left, tag)?;
                crate::trace::instant(
                    crate::trace::TraceKind::GradRecv,
                    crate::trace::Fields {
                        worker: ep.id as u32,
                        stage: stage as u32,
                        step,
                        bytes: nbytes,
                        ..crate::trace::Fields::default()
                    },
                );
                if pos < owner {
                    ops::add_into(part.make_mut(), &own[b.range()]);
                    ep.stats().mark(EventKind::GradSend, ep.id, stage, step, nbytes);
                    ep.send(ring.right, tag, part)?;
                } else {
                    let out = avg_out.as_deref_mut().expect("owner has avg_out");
                    ops::add_scale_into(&mut out[b.range()], &part, &own[b.range()], inv);
                }
            }
        }
        Ok(())
    }

    /// Eager ZeRO shard send: push stage `stage`'s gradients for micro-
    /// batch `mb` (1-based) to the stage owner, bucket by bucket, the
    /// moment they land.  Pure sends — never blocks, so the caller's
    /// remaining backward keeps running while the fabric carries these.
    #[allow(clippy::too_many_arguments)]
    pub fn shard_send(
        &self,
        ep: &Endpoint,
        layout: &ArenaLayout,
        step: u64,
        stage: usize,
        mb: usize,
        owner: usize,
        own: &[f32],
    ) -> Result<(), CommError> {
        debug_assert_ne!(owner, ep.id, "own shard never travels");
        debug_assert_eq!(own.len(), layout.stage_len(stage));
        for b in layout.stage_buckets(stage, self.stage_elems(layout, stage)) {
            ep.stats().mark(EventKind::GradSend, ep.id, stage, step, b.len() as u64 * 4);
            ep.send_copy(owner, tags::grad_shard(step, stage, mb, b.index), &own[b.range()])?;
        }
        Ok(())
    }

    /// Owner-side ZeRO reduction for its stage: accumulate every micro-
    /// batch's shard in order 1..N (its own contribution, `own`, in its
    /// slot), then average — landing in `gsum`.  Bucket arrivals may be
    /// out of order on the wire; the (from, tag) parking in [`Endpoint`]
    /// restores them, so the per-element sum order is unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn shard_reduce(
        &self,
        ep: &mut Endpoint,
        layout: &ArenaLayout,
        step: u64,
        stage: usize,
        my_mb: usize,
        n_mb: usize,
        own: &[f32],
        gsum: &mut [f32],
    ) -> Result<(), CommError> {
        debug_assert_eq!(gsum.len(), layout.stage_len(stage));
        gsum.fill(0.0);
        for mb in 1..=n_mb {
            if mb == my_mb {
                ops::add_into(gsum, own);
            } else {
                for b in layout.stage_buckets(stage, self.stage_elems(layout, stage)) {
                    let part = ep.recv(mb - 1, tags::grad_shard(step, stage, mb, b.index))?;
                    crate::trace::instant(
                        crate::trace::TraceKind::GradRecv,
                        crate::trace::Fields {
                            worker: ep.id as u32,
                            stage: stage as u32,
                            step,
                            bytes: part.len() as u64 * 4,
                            ..crate::trace::Fields::default()
                        },
                    );
                    ops::add_into(&mut gsum[b.range()], &part);
                }
            }
        }
        ops::scale(gsum, 1.0 / n_mb as f32);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;
    use crate::tensor::ops::add_into;
    use std::thread;

    fn layout() -> std::sync::Arc<ArenaLayout> {
        // two stages, lens 10 and 5 — bucket size 4 forces short tails
        ArenaLayout::from_stage_shapes(&[vec![vec![10]], vec![vec![5]]])
    }

    /// Reference: plain mb-order sum + average, per stage.
    fn reference_avg(rows: &[Vec<f32>]) -> Vec<f32> {
        let mut sum = vec![0.0f32; rows[0].len()];
        for r in rows {
            add_into(&mut sum, r);
        }
        let inv = 1.0 / rows.len() as f32;
        for v in &mut sum {
            *v *= inv;
        }
        sum
    }

    #[test]
    fn ring_stage_matches_reference_bitwise() {
        for n in [1usize, 2, 3, 4] {
            let l = layout();
            let (eps, _) = Fabric::new(n);
            // values whose f32 sum order matters
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|w| {
                    (0..l.total_len)
                        .map(|k| ((w * 31 + k) as f32).sin() * 1e4)
                        .collect()
                })
                .collect();
            let grads_c = grads.clone();
            let l2 = l.clone();
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    let l = l2.clone();
                    let own_all = grads_c[ep.id].clone();
                    thread::spawn(move || {
                        let red = BucketedReducer::new(4);
                        let ring = RingView::full(&ep);
                        let owner = ring.m - 1;
                        let mut avg = l.zeros();
                        for stage in (0..l.n_stages()).rev() {
                            let r = l.stage_range(stage);
                            let own = &own_all[r.clone()];
                            let out = if ring.pos == owner {
                                Some(&mut avg[r])
                            } else {
                                None
                            };
                            red.ring_stage(&mut ep, &ring, &l, 7, stage, own, out)
                                .unwrap();
                        }
                        (ring.pos == owner).then_some(avg)
                    })
                })
                .collect();
            let mut results: Vec<_> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            let avg = results.pop().flatten().expect("owner (last worker) returns the average");
            for stage in 0..l.n_stages() {
                let r = l.stage_range(stage);
                let rows: Vec<Vec<f32>> =
                    grads.iter().map(|g| g[r.clone()].to_vec()).collect();
                let want = reference_avg(&rows);
                let got = &avg[r];
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} stage={stage}");
                }
            }
        }
    }

    #[test]
    fn ring_stage_on_live_subset_matches_reference() {
        // 4-worker fabric, worker 2 lost: the 3 survivors re-form and the
        // averaged result must bitwise match a plain 3-row reference in
        // ring-position order.
        let live = [0usize, 1, 3];
        let l = layout();
        let (eps, _) = Fabric::new(4);
        let grads: Vec<Vec<f32>> = (0..4)
            .map(|w| {
                (0..l.total_len)
                    .map(|k| ((w * 13 + k) as f32).sin() * 1e4)
                    .collect()
            })
            .collect();
        let grads_c = grads.clone();
        let l2 = l.clone();
        let handles: Vec<_> = eps
            .into_iter()
            .filter(|ep| live.contains(&ep.id))
            .map(|mut ep| {
                let l = l2.clone();
                let own_all = grads_c[ep.id].clone();
                thread::spawn(move || {
                    let red = BucketedReducer::new(4);
                    let ring = RingView::from_live(ep.id, &live);
                    let mut avg = l.zeros();
                    for stage in (0..l.n_stages()).rev() {
                        let r = l.stage_range(stage);
                        let out = (ring.pos == ring.m - 1).then(|| &mut avg[r.clone()]);
                        red.ring_stage(&mut ep, &ring, &l, 3, stage, &own_all[r.clone()], out)
                            .unwrap();
                    }
                    (ep.id, avg)
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let avg = &results.iter().find(|(id, _)| *id == 3).unwrap().1;
        for stage in 0..l.n_stages() {
            let r = l.stage_range(stage);
            let rows: Vec<Vec<f32>> =
                live.iter().map(|&w| grads[w][r.clone()].to_vec()).collect();
            let want = reference_avg(&rows);
            for (a, b) in avg[r].iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "stage {stage}");
            }
        }
    }

    #[test]
    fn shard_protocol_matches_reference_bitwise() {
        let n = 3usize;
        let l =
            ArenaLayout::from_stage_shapes(&[vec![vec![7]], vec![vec![9]], vec![vec![4]]]);
        let (eps, _) = Fabric::new(n);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|w| (0..l.total_len).map(|k| ((w + 2 * k) as f32).cos() * 1e3).collect())
            .collect();
        let grads_c = grads.clone();
        let l2 = l.clone();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let l = l2.clone();
                let own_all = grads_c[ep.id].clone();
                thread::spawn(move || {
                    let red = BucketedReducer::new(3);
                    let w = ep.id;
                    let mb = w + 1;
                    // eager sends for non-owned stages (backward order)
                    for stage in (0..l.n_stages()).rev() {
                        if stage != w {
                            red.shard_send(
                                &ep,
                                &l,
                                9,
                                stage,
                                mb,
                                stage, // worker j owns stage j
                                &own_all[l.stage_range(stage)],
                            )
                            .unwrap();
                        }
                    }
                    // owner-side reduction of my stage
                    let mut gsum = l.stage_zeros(w);
                    red.shard_reduce(
                        &mut ep,
                        &l,
                        9,
                        w,
                        mb,
                        n,
                        &own_all[l.stage_range(w)],
                        &mut gsum,
                    )
                    .unwrap();
                    gsum
                })
            })
            .collect();
        let sums: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (stage, got) in sums.iter().enumerate() {
            let r = l.stage_range(stage);
            let rows: Vec<Vec<f32>> = grads.iter().map(|g| g[r.clone()].to_vec()).collect();
            let want = reference_avg(&rows);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "stage {stage}");
            }
        }
    }

    #[test]
    fn default_bucket_size_is_sane() {
        assert!(BucketedReducer::from_env().bucket_elems > 0);
        assert_eq!(DEFAULT_BUCKET_ELEMS, 16 * 1024);
    }

    #[test]
    fn bucket_count_clamped_to_tag_budget() {
        // small stages keep the configured size
        assert_eq!(effective_bucket_elems(16, 100), 16);
        // 1-elem buckets over a huge stage would overflow the 14-bit
        // bucket tag field; the clamp raises the size until it fits
        let len = 50_000_000usize;
        let e = effective_bucket_elems(1, len);
        assert!(len.div_ceil(e) <= MAX_BUCKETS_PER_STAGE);
    }
}
