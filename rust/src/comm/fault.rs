//! Deterministic, seeded fault injection for the in-process fabric.
//!
//! A [`FaultInjector`] sits between [`super::Endpoint::send`] and the
//! destination channel.  Each directed edge `(from → to)` owns an
//! independent RNG stream seeded from `splitmix64(plan.seed ^ edge)`, so a
//! given [`FaultPlan`] reproduces the exact same perturbation schedule on
//! every run regardless of thread interleaving: an edge's stream is
//! consumed only by sends on that edge, and each sender's per-edge send
//! order is deterministic (the trainers' schedules are).
//!
//! Per message, one uniform draw selects (cumulative probabilities):
//!
//! - **drop** — the message is diverted to the edge's `lost` stash; the
//!   receiver's timeout/backoff loop recovers it via [`FaultInjector::recover`].
//! - **duplicate** — delivered twice with the same sequence number; the
//!   receiver's dedup filter drops the copy.
//! - **delay** — held, delivered just before the edge's next message
//!   (per-edge order preserved; wall-clock delayed so the receiver's
//!   backoff path is exercised).
//! - **reorder** — held, delivered just *after* the edge's next message
//!   (a one-slot swap; the receiver's parked queue / seq filter absorb it).
//!
//! The injector doubles as the retransmission buffer a real transport
//! would keep on the sender: `recover(to, from)` flushes everything held
//! or lost on that edge.  It is the deterministic in-process analogue of
//! a NACK-triggered retransmit — nothing is ever lost permanently, which
//! is exactly the contract that makes the retry path loss-transparent
//! (faulty-run losses bit-identical to clean, asserted in
//! tests/robustness.rs).
//!
//! Scripted worker-kill ([`KillSpec`]) is carried here too, but executed
//! by the coordinators (the worker exits at the top of the given step,
//! before sending anything); the injector only transports the script.
//!
//! Control-plane tags (heartbeat, checkpoint) never reach the injector —
//! [`super::Endpoint::send`] routes them directly (fault model in
//! DESIGN-ROBUSTNESS.md).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Mutex;

use super::{CommError, Msg};
use crate::util::rng::{splitmix64, XorShift64Star};

/// Kill worker `worker` at the top of step `at_step` (before it sends
/// anything for that step).  Coordinators that support degradation
/// (multi's cyclic ring) re-form without it at that θ-version boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub worker: usize,
    pub at_step: u64,
}

/// Seeded fault schedule for a fabric.  Probabilities are per message,
/// evaluated on one uniform draw in the order drop → dup → delay →
/// reorder (cumulative), so `p_drop + p_dup + p_delay + p_reorder ≤ 1`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub p_drop: f32,
    pub p_dup: f32,
    pub p_delay: f32,
    pub p_reorder: f32,
    pub kill: Option<KillSpec>,
}

impl FaultPlan {
    /// Uniformly lossy edges: drop, duplicate and reorder each at `p`.
    pub fn lossy(seed: u64, p: f32) -> Self {
        Self { seed, p_drop: p, p_dup: p, p_delay: 0.0, p_reorder: p, kill: None }
    }

    /// No message perturbation; only a scripted worker-kill.
    pub fn kill_only(worker: usize, at_step: u64) -> Self {
        Self { kill: Some(KillSpec { worker, at_step }), ..Self::default() }
    }

    pub fn with_kill(mut self, worker: usize, at_step: u64) -> Self {
        self.kill = Some(KillSpec { worker, at_step });
        self
    }
}

/// Per-edge perturbation state.  `rng` is this edge's private stream;
/// `delayed` / `reordered` hold in-flight messages; `lost` stashes
/// dropped ones until a receiver recovers them.
#[derive(Debug)]
struct EdgeState {
    rng: XorShift64Star,
    delayed: VecDeque<Msg>,
    reordered: Option<Msg>,
    lost: Vec<Msg>,
}

/// See the module docs.  Shared (`Arc`) by every endpoint of a fabric
/// built with [`super::Fabric::with_faults`].
pub struct FaultInjector {
    plan: FaultPlan,
    n: usize,
    txs: Vec<Sender<Msg>>,
    edges: Vec<Mutex<EdgeState>>,
    drops: AtomicU64,
    dups: AtomicU64,
    delays: AtomicU64,
    reorders: AtomicU64,
    recovered: AtomicU64,
}

impl FaultInjector {
    pub(super) fn new(plan: FaultPlan, n: usize, txs: Vec<Sender<Msg>>) -> Self {
        let total = plan.p_drop + plan.p_dup + plan.p_delay + plan.p_reorder;
        assert!(
            (0.0..=1.0).contains(&total),
            "fault probabilities sum to {total}, must be within [0, 1]"
        );
        let edges = (0..n * n)
            .map(|e| {
                Mutex::new(EdgeState {
                    rng: XorShift64Star::new(splitmix64(plan.seed ^ (e as u64 + 1))),
                    delayed: VecDeque::new(),
                    reordered: None,
                    lost: Vec::new(),
                })
            })
            .collect();
        Self {
            plan,
            n,
            txs,
            edges,
            drops: AtomicU64::new(0),
            dups: AtomicU64::new(0),
            delays: AtomicU64::new(0),
            reorders: AtomicU64::new(0),
            recovered: AtomicU64::new(0),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The scripted kill step for `worker`, if this plan has one.
    pub fn kill_step_for(&self, worker: usize) -> Option<u64> {
        self.plan
            .kill
            .filter(|k| k.worker == worker)
            .map(|k| k.at_step)
    }

    /// Messages diverted to an edge's lost stash so far.
    pub fn drops(&self) -> u64 {
        self.drops.load(Ordering::Relaxed)
    }

    /// Messages delivered twice so far.
    pub fn dups(&self) -> u64 {
        self.dups.load(Ordering::Relaxed)
    }

    /// Messages held for order-preserving delay so far.
    pub fn delays(&self) -> u64 {
        self.delays.load(Ordering::Relaxed)
    }

    /// Messages swapped past their successor so far.
    pub fn reorders(&self) -> u64 {
        self.reorders.load(Ordering::Relaxed)
    }

    /// Messages flushed out of held/lost stashes by receiver recovery.
    pub fn recovered(&self) -> u64 {
        self.recovered.load(Ordering::Relaxed)
    }

    /// Deliver directly to the destination channel, skipping stats (the
    /// logical send was already accounted).  A dead receiver is fine:
    /// losing messages to a dead worker is the failure being simulated.
    fn deliver(&self, to: usize, msg: Msg) {
        let _ = self.txs[to].send(msg);
    }

    /// Route one message through the edge's perturbation schedule.  On an
    /// injected fabric a dead peer never fails the send (a lossy wire
    /// can't tell) — it surfaces as the peer's silence, i.e. a recv
    /// [`CommError::Timeout`] on whoever waits for it, which is the
    /// detection path the coordinators' heartbeats use.
    pub(super) fn route(&self, to: usize, msg: Msg) -> Result<(), CommError> {
        let mut e = self.edges[msg.from * self.n + to]
            .lock()
            .expect("edge state poisoned");
        // pending delayed messages go first (order preserved), then a
        // held reorder partner is released after the current message.
        while let Some(d) = e.delayed.pop_front() {
            self.deliver(to, d);
        }
        let held = e.reordered.take();
        let u = e.rng.uniform();
        let p = &self.plan;
        if u < p.p_drop {
            self.drops.fetch_add(1, Ordering::Relaxed);
            e.lost.push(msg);
        } else if u < p.p_drop + p.p_dup {
            self.dups.fetch_add(1, Ordering::Relaxed);
            self.deliver(to, msg.clone());
            self.deliver(to, msg);
        } else if u < p.p_drop + p.p_dup + p.p_delay {
            self.delays.fetch_add(1, Ordering::Relaxed);
            e.delayed.push_back(msg);
        } else if u < p.p_drop + p.p_dup + p.p_delay + p.p_reorder {
            self.reorders.fetch_add(1, Ordering::Relaxed);
            e.reordered = Some(msg);
        } else {
            self.deliver(to, msg);
        }
        if let Some(h) = held {
            self.deliver(to, h);
        }
        Ok(())
    }

    /// Flush everything held or lost on the `from → to` edge back onto
    /// the wire — the receiver calls this from its timeout/backoff loop.
    /// The deterministic analogue of a NACK-triggered retransmit; seqs
    /// are unchanged, so anything that raced the original is deduped.
    pub fn recover(&self, to: usize, from: usize) {
        let mut e = self.edges[from * self.n + to]
            .lock()
            .expect("edge state poisoned");
        let mut flushed = 0u64;
        while let Some(d) = e.delayed.pop_front() {
            self.deliver(to, d);
            flushed += 1;
        }
        if let Some(h) = e.reordered.take() {
            self.deliver(to, h);
            flushed += 1;
        }
        for m in e.lost.drain(..) {
            self.deliver(to, m);
            flushed += 1;
        }
        if flushed > 0 {
            self.recovered.fetch_add(flushed, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{tags, Fabric};
    use std::time::Duration;

    #[test]
    fn clean_plan_is_transparent() {
        let (mut eps, _, inj) = Fabric::with_faults(2, FaultPlan::default());
        let e0 = eps.remove(0);
        let mut e1 = eps.remove(0);
        for i in 0..20u64 {
            e0.send(1, tags::grad(i, 0), vec![i as f32]).unwrap();
            assert_eq!(e1.recv(0, tags::grad(i, 0)).unwrap(), vec![i as f32]);
        }
        assert_eq!(inj.drops() + inj.dups() + inj.delays() + inj.reorders(), 0);
    }

    #[test]
    fn dropped_messages_are_recovered_by_receiver_backoff() {
        let plan = FaultPlan { seed: 7, p_drop: 1.0, ..FaultPlan::default() };
        let (mut eps, _, inj) = Fabric::with_faults(2, plan);
        let e0 = eps.remove(0);
        let mut e1 = eps.remove(0);
        for i in 0..5u64 {
            e0.send(1, tags::grad(i, 0), vec![i as f32]).unwrap();
            // every message is dropped; the recv backoff loop recovers it
            let got = e1
                .recv_deadline(0, tags::grad(i, 0), Duration::from_secs(5))
                .unwrap();
            assert_eq!(got, vec![i as f32]);
        }
        assert_eq!(inj.drops(), 5);
        assert_eq!(inj.recovered(), 5);
    }

    #[test]
    fn duplicates_are_deduped_not_delivered_twice() {
        let plan = FaultPlan { seed: 3, p_dup: 1.0, ..FaultPlan::default() };
        let (mut eps, _, inj) = Fabric::with_faults(2, plan);
        let e0 = eps.remove(0);
        let mut e1 = eps.remove(0);
        for i in 0..4u64 {
            e0.send(1, tags::grad(i, 0), vec![i as f32]).unwrap();
        }
        for i in 0..4u64 {
            assert_eq!(e1.recv(0, tags::grad(i, 0)).unwrap(), vec![i as f32]);
        }
        assert_eq!(inj.dups(), 4);
        // a second receive of any tag must time out — the duplicate copies
        // were filtered before parking, not left behind
        let err = e1
            .recv_deadline(0, tags::grad(0, 0), Duration::from_millis(40))
            .unwrap_err();
        assert!(matches!(err, crate::comm::CommError::Timeout { .. }));
    }

    #[test]
    fn reordered_messages_arrive_and_match_by_tag() {
        let plan = FaultPlan { seed: 11, p_reorder: 1.0, ..FaultPlan::default() };
        let (mut eps, _, inj) = Fabric::with_faults(2, plan);
        let e0 = eps.remove(0);
        let mut e1 = eps.remove(0);
        for i in 0..6u64 {
            e0.send(1, tags::grad(i, 0), vec![i as f32]).unwrap();
        }
        // every message is held one slot; tag-addressed recv + recovery
        // still yields each exactly once, in any order we ask
        for i in (0..6u64).rev() {
            let got = e1
                .recv_deadline(0, tags::grad(i, 0), Duration::from_secs(5))
                .unwrap();
            assert_eq!(got, vec![i as f32]);
        }
        assert_eq!(inj.reorders(), 6);
    }

    #[test]
    fn same_seed_same_schedule() {
        let plan = FaultPlan { seed: 42, p_drop: 0.3, p_dup: 0.3, ..FaultPlan::default() };
        let run = || {
            let (mut eps, _, inj) = Fabric::with_faults(2, plan);
            let e0 = eps.remove(0);
            let mut e1 = eps.remove(0);
            for i in 0..50u64 {
                e0.send(1, tags::grad(i, 0), vec![i as f32]).unwrap();
                let got = e1
                    .recv_deadline(0, tags::grad(i, 0), Duration::from_secs(5))
                    .unwrap();
                assert_eq!(got, vec![i as f32]);
            }
            (inj.drops(), inj.dups())
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "seeded schedule must be reproducible");
        assert!(a.0 > 0 && a.1 > 0, "plan actually injected faults: {a:?}");
    }

    #[test]
    fn control_plane_tags_bypass_injection() {
        let plan = FaultPlan { seed: 1, p_drop: 1.0, ..FaultPlan::default() };
        let (mut eps, _, inj) = Fabric::with_faults(2, plan);
        let e0 = eps.remove(0);
        let mut e1 = eps.remove(0);
        e0.send(1, tags::hb(3), vec![1.0]).unwrap();
        e0.send(1, tags::ckpt(3, 0, 0), vec![2.0]).unwrap();
        // p_drop = 1.0, yet both arrive without any recovery round
        assert_eq!(
            e1.recv_deadline(0, tags::hb(3), Duration::from_millis(200)).unwrap(),
            vec![1.0]
        );
        assert_eq!(
            e1.recv_deadline(0, tags::ckpt(3, 0, 0), Duration::from_millis(200))
                .unwrap(),
            vec![2.0]
        );
        assert_eq!(inj.drops(), 0);
    }

    #[test]
    fn kill_script_addresses_one_worker() {
        let plan = FaultPlan::kill_only(2, 5);
        let (_eps, _, inj) = Fabric::with_faults(4, plan);
        assert_eq!(inj.kill_step_for(2), Some(5));
        assert_eq!(inj.kill_step_for(1), None);
    }
}
