//! Communication fabric: byte-counted point-to-point channels between
//! workers plus the collectives the paper compares (paper Sec. 4 / Tab 1).
//!
//! Every transfer is accounted (bytes, messages) in shared [`CommStats`];
//! the trainers' comm numbers in EXPERIMENTS.md come from here, not from
//! analytic formulas (those live in `sim::analytic` and are cross-checked).
//!
//! Determinism: `reduce_to_root` adds contributions in rank order, and the
//! cyclic ring accumulates in micro-batch order — both match the
//! single-process reference trainer bit-for-bit (DESIGN.md invariants).

pub mod collectives;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Global transfer accounting, shared by all endpoints of a fabric.
#[derive(Debug, Default)]
pub struct CommStats {
    pub bytes: AtomicU64,
    pub messages: AtomicU64,
}

impl CommStats {
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct Msg {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

/// One worker's endpoint: send to any peer, tagged blocking receive.
pub struct Endpoint {
    pub id: usize,
    pub n: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order arrivals parked until someone asks for them.
    parked: HashMap<(usize, u64), Vec<Vec<f32>>>,
    stats: Arc<CommStats>,
}

impl Endpoint {
    /// Send `data` to `to` under `tag`.  f32 payloads only (params, grads,
    /// activations — everything the paper communicates).
    pub fn send(&self, to: usize, tag: u64, data: Vec<f32>) {
        assert_ne!(to, self.id, "self-send");
        self.stats
            .bytes
            .fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.txs[to]
            .send(Msg { from: self.id, tag, data })
            .expect("peer endpoint dropped");
    }

    /// Blocking receive of the message sent by `from` under `tag`.
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        if let Some(q) = self.parked.get_mut(&(from, tag)) {
            if !q.is_empty() {
                return q.remove(0);
            }
        }
        loop {
            let msg = self.rx.recv().expect("fabric closed");
            if msg.from == from && msg.tag == tag {
                return msg.data;
            }
            self.parked
                .entry((msg.from, msg.tag))
                .or_default()
                .push(msg.data);
        }
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    pub fn right(&self) -> usize {
        (self.id + 1) % self.n
    }

    pub fn left(&self) -> usize {
        (self.id + self.n - 1) % self.n
    }
}

/// Build a fully-connected fabric of `n` endpoints.
pub struct Fabric;

impl Fabric {
    pub fn new(n: usize) -> (Vec<Endpoint>, Arc<CommStats>) {
        let stats = Arc::new(CommStats::default());
        let mut txs_all = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs_all.push(tx);
            rxs.push(rx);
        }
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(id, rx)| Endpoint {
                id,
                n,
                txs: txs_all.clone(),
                rx,
                parked: HashMap::new(),
                stats: stats.clone(),
            })
            .collect();
        (endpoints, stats)
    }
}

/// Tag namespaces so concurrent protocols on one fabric can't collide.
pub mod tags {
    /// grad fragment for (step, stage)
    pub fn grad(step: u64, stage: usize) -> u64 {
        0x1_0000_0000 | (step << 8) | stage as u64
    }

    /// updated params for (step, stage)
    pub fn param(step: u64, stage: usize) -> u64 {
        0x2_0000_0000 | (step << 8) | stage as u64
    }

    /// scalar loss report for step
    pub fn loss(step: u64) -> u64 {
        0x3_0000_0000 | step
    }

    /// ring all-reduce phase p of step
    pub fn ring(step: u64, phase: usize) -> u64 {
        0x4_0000_0000 | (step << 8) | phase as u64
    }

    /// activation / activation-grad between pipeline stages
    pub fn act(step: u64, mb: usize, fwd: bool) -> u64 {
        let dir = if fwd { 0x10 } else { 0x20 };
        0x5_0000_0000 | (step << 16) | ((mb as u64) << 8) | dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn p2p_roundtrip_and_accounting() {
        let (mut eps, stats) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            let got = e1.recv(0, 7);
            assert_eq!(got, vec![1.0, 2.0, 3.0]);
            e1.send(0, 8, vec![4.0]);
        });
        e0.send(1, 7, vec![1.0, 2.0, 3.0]);
        let mut e0 = e0;
        assert_eq!(e0.recv(1, 8), vec![4.0]);
        h.join().unwrap();
        assert_eq!(stats.bytes(), 16);
        assert_eq!(stats.messages(), 2);
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 100, vec![1.0]);
        e0.send(1, 200, vec![2.0]);
        // receive in reverse order
        assert_eq!(e1.recv(0, 200), vec![2.0]);
        assert_eq!(e1.recv(0, 100), vec![1.0]);
    }

    #[test]
    fn neighbors_modulo_n() {
        let (eps, _) = Fabric::new(3);
        assert_eq!(eps[0].right(), 1);
        assert_eq!(eps[2].right(), 0);
        assert_eq!(eps[0].left(), 2);
    }

    #[test]
    fn tags_disjoint() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..4u64 {
            for stage in 0..4usize {
                assert!(seen.insert(tags::grad(step, stage)));
                assert!(seen.insert(tags::param(step, stage)));
                assert!(seen.insert(tags::ring(step, stage)));
                assert!(seen.insert(tags::act(step, stage, true)));
                assert!(seen.insert(tags::act(step, stage, false)));
            }
            assert!(seen.insert(tags::loss(step)));
        }
    }
}
