//! Communication fabric: byte-counted point-to-point channels between
//! workers plus the collectives the paper compares (paper Sec. 4 / Tab 1).
//!
//! Every transfer is accounted (bytes, messages) in shared [`CommStats`];
//! the trainers' comm numbers in EXPERIMENTS.md come from here, not from
//! analytic formulas (those live in `sim::analytic` and are cross-checked).
//!
//! Determinism: `reduce_to_root` adds contributions in rank order, and the
//! cyclic ring accumulates in micro-batch order — both match the
//! single-process reference trainer bit-for-bit (DESIGN.md invariants).
//!
//! ## Zero-copy payloads and the buffer pool (DESIGN-PERF.md)
//!
//! Messages carry a [`Payload`] — a cheaply clonable (`Arc`) handle to an
//! immutable `f32` buffer.  Forwarding a received payload along a ring or
//! fanning one buffer out to N peers clones the handle, not the data.
//! Buffers obtained from the fabric's shared [`BufferPool`] return to the
//! pool when the last handle drops, so steady-state traffic recycles the
//! same allocations step after step.  The free lists are segregated by
//! power-of-two capacity class, so `take` is O(#classes) under the lock.
//!
//! [`bucketed`] adds the eager bucketed gradient reduction: per-stage
//! grad runs split into fixed buckets whose ring hops launch while
//! backprop is still running (the paper's balanced-communication claim,
//! made measurable by the opt-in [`CommStats`] timeline).
//!
//! ## Fault tolerance (DESIGN-ROBUSTNESS.md)
//!
//! No receive blocks forever: [`Endpoint::recv`] runs against a deadline
//! and returns a typed [`CommError::Timeout`] carrying the decoded tag and
//! peer id instead of hanging; sends to a dropped peer return
//! [`CommError::PeerGone`] instead of panicking.  Every message carries a
//! per-(sender → receiver) sequence number so retransmitted or injected
//! duplicates are deduplicated before they can reach the parked queue.
//! [`fault::FaultInjector`] (attached via [`Fabric::with_faults`]) sits
//! between `send` and the wire, perturbing delivery — drop / duplicate /
//! delay / reorder, all driven by per-edge deterministic RNG streams — and
//! doubles as the retransmit buffer the receiver's timeout/backoff loop
//! recovers lost messages from.  Control-plane namespaces (heartbeat,
//! checkpoint) are exempt from injection; see the fault model in
//! DESIGN-ROBUSTNESS.md.
//!
//! ## Transports (`comm::transport`)
//!
//! The protocol layer above (tags, deadlines, seq dedup, parking) is
//! transport-agnostic: an [`Endpoint`] moves [`Msg`]s through a boxed
//! [`Transport`].  [`Fabric::new`] wires the in-process
//! [`transport::ChannelTransport`] (identical behavior to the
//! pre-transport fabric); [`Fabric::wire`] and [`Endpoint::over`] run
//! the same protocol over real UDS/TCP sockets with framed,
//! CRC-validated messages and reconnect supervision
//! ([`transport::WireTransport`]) — that is what makes N separate OS
//! processes a fabric.

pub mod bucketed;
pub mod collectives;
pub mod fault;
pub mod transport;

use std::cell::Cell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::ops::Deref;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

pub use fault::{FaultInjector, FaultPlan, KillSpec};
pub use transport::{
    ChannelTransport, RecvTimeoutErr, Transport, WireConfig, WireFaultPlan, WireKind,
    WireTransport,
};

/// Default receive deadline.  Generous: a clean in-process run never waits
/// anywhere near this long, so hitting it means a peer died or the fabric
/// wedged — the error is diagnosis, not flow control.
pub const DEFAULT_DEADLINE: Duration = Duration::from_secs(10);

/// First timeout slice of the receive retry loop; doubles per retry.
const BACKOFF_START: Duration = Duration::from_micros(200);
/// Backoff ceiling — keeps recovery probes frequent enough that an
/// injected-lossy edge adds at most ~this much latency per lost message.
const BACKOFF_MAX: Duration = Duration::from_millis(20);

// ------------------------------------------------------------- errors ----

/// A tag decoded back into its `namespace | step | sub` fields — every
/// [`CommError`] carries one so a timeout names the protocol message that
/// went missing, not just a 64-bit opaque.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TagInfo {
    pub ns: u8,
    pub step: u64,
    pub sub: u64,
    pub raw: u64,
}

impl TagInfo {
    pub fn ns_name(&self) -> &'static str {
        match self.ns {
            1 => "grad",
            2 => "grad_part",
            3 => "param",
            4 => "loss",
            5 => "ring",
            6 => "act",
            7 => "grad_bucket",
            8 => "grad_shard",
            9 => "hb",
            10 => "ckpt",
            _ => "unknown",
        }
    }
}

impl std::fmt::Display for TagInfo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}(step={}, sub={:#x})",
            self.ns_name(),
            self.step,
            self.sub
        )
    }
}

/// Recoverable fabric errors.  Each carries the peer id and the decoded
/// tag so a fault produces a diagnosable message, not a bare panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CommError {
    /// The deadline elapsed with no matching message.
    Timeout {
        peer: usize,
        tag: TagInfo,
        waited: Duration,
    },
    /// The destination endpoint was dropped (its receiver is gone).
    PeerGone { peer: usize, tag: TagInfo },
    /// Every sender of this endpoint's channel is gone.
    Closed { peer: usize, tag: TagInfo },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { peer, tag, waited } => write!(
                f,
                "recv timeout after {waited:?} waiting for {tag} from worker {peer}"
            ),
            CommError::PeerGone { peer, tag } => {
                write!(f, "worker {peer} gone (endpoint dropped) sending {tag}")
            }
            CommError::Closed { peer, tag } => {
                write!(f, "fabric closed waiting for {tag} from worker {peer}")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// What a [`TimelineEvent`] records.  The set is deliberately small: just
/// enough to prove (in benches/tests) that the bucketed gradient
/// reduction *overlaps* backprop — a `GradSend` with a timestamp earlier
/// than the last `BwdStageDone` is the overlap, made visible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A gradient bucket partial left a worker.
    GradSend,
    /// A worker finished one stage's backward pass.
    BwdStageDone,
    /// Updated parameters left the optimizer owner.
    ParamSend,
}

/// One timestamped comm/compute event (`ns` is relative to the fabric's
/// creation instant, so events from all workers share one clock).
///
/// Legacy view: since the structured tracing layer landed (`src/trace`),
/// the timeline is *stored* as [`crate::trace::TraceEvent`]s and this
/// struct is what [`CommStats::timeline`] converts back to for the
/// benches and reports that predate it.
#[derive(Clone, Copy, Debug)]
pub struct TimelineEvent {
    pub ns: u64,
    pub kind: EventKind,
    pub worker: usize,
    pub stage: usize,
    pub bytes: u64,
}

/// The structured-trace kind a legacy [`EventKind`] maps to.
fn to_trace_kind(kind: EventKind) -> crate::trace::TraceKind {
    match kind {
        EventKind::GradSend => crate::trace::TraceKind::GradSend,
        EventKind::BwdStageDone => crate::trace::TraceKind::Bwd,
        EventKind::ParamSend => crate::trace::TraceKind::ParamSend,
    }
}

/// Inverse of [`to_trace_kind`] for the kinds a [`CommStats`] timeline
/// can contain.
fn from_trace_kind(kind: crate::trace::TraceKind) -> Option<EventKind> {
    match kind {
        crate::trace::TraceKind::GradSend => Some(EventKind::GradSend),
        crate::trace::TraceKind::Bwd => Some(EventKind::BwdStageDone),
        crate::trace::TraceKind::ParamSend => Some(EventKind::ParamSend),
        _ => None,
    }
}

/// Global transfer accounting, shared by all endpoints of a fabric, plus
/// an opt-in event timeline (disabled by default — `mark` is a no-op
/// until [`CommStats::enable_timeline`] runs, so the hot path pays one
/// relaxed atomic load).
///
/// Counts are *offered* traffic, taken at the `send` call before any
/// fault injection: a clean run and a faulty run of the same schedule
/// report identical bytes/messages, and the injector's own counters
/// ([`fault::FaultInjector::drops`] etc.) account the wire perturbations.
#[derive(Debug)]
pub struct CommStats {
    pub bytes: AtomicU64,
    pub messages: AtomicU64,
    timeline_on: AtomicBool,
    epoch: Instant,
    timeline: Mutex<Vec<crate::trace::TraceEvent>>,
}

impl Default for CommStats {
    fn default() -> Self {
        Self {
            bytes: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            timeline_on: AtomicBool::new(false),
            epoch: Instant::now(),
            timeline: Mutex::new(Vec::new()),
        }
    }
}

impl CommStats {
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Start recording `mark` events (reserves capacity so steady-state
    /// recording does not reallocate per event).
    ///
    /// The enabled flag is published *while holding the timeline lock*:
    /// a concurrent `mark` either sees the flag off (no-op) or takes the
    /// lock after both the reserve and the store, so it can never
    /// interleave with the reservation and trigger a mid-mark realloc.
    pub fn enable_timeline(&self) {
        let mut tl = self.timeline.lock().expect("timeline poisoned");
        tl.reserve(4096);
        self.timeline_on.store(true, Ordering::Release);
    }

    /// Record one event.  Always forwards to the crate-wide structured
    /// trace (`crate::trace::instant`, a no-op unless `--trace` enabled
    /// it); additionally keeps a local copy when the opt-in timeline is
    /// enabled, timestamped against this fabric's epoch so all workers
    /// share one clock.
    pub fn mark(&self, kind: EventKind, worker: usize, stage: usize, step: u64, bytes: u64) {
        let fields = crate::trace::Fields {
            worker: worker as u32,
            stage: stage as u32,
            step,
            bytes,
            ..crate::trace::Fields::default()
        };
        crate::trace::instant(to_trace_kind(kind), fields);
        if !self.timeline_on.load(Ordering::Acquire) {
            return;
        }
        let ns = self.epoch.elapsed().as_nanos() as u64;
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .push(crate::trace::TraceEvent::new(to_trace_kind(kind), ns, 0, fields));
    }

    /// Snapshot of all recorded events in the legacy [`TimelineEvent`]
    /// shape (unsorted — workers interleave).
    pub fn timeline(&self) -> Vec<TimelineEvent> {
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .iter()
            .filter_map(|e| {
                Some(TimelineEvent {
                    ns: e.ns,
                    kind: from_trace_kind(e.kind)?,
                    worker: e.worker as usize,
                    stage: e.stage as usize,
                    bytes: e.bytes,
                })
            })
            .collect()
    }

    /// Snapshot of all recorded events as structured trace events — the
    /// preferred view; [`CommStats::timeline`] is the legacy adapter.
    pub fn trace_events(&self) -> Vec<crate::trace::TraceEvent> {
        self.timeline.lock().expect("timeline poisoned").clone()
    }

    /// Earliest timestamp of `kind`, if any was recorded.
    pub fn first_ns(&self, kind: EventKind) -> Option<u64> {
        let want = to_trace_kind(kind);
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .iter()
            .filter(|e| e.kind == want)
            .map(|e| e.ns)
            .min()
    }

    /// Latest timestamp of `kind`, if any was recorded.
    pub fn last_ns(&self, kind: EventKind) -> Option<u64> {
        let want = to_trace_kind(kind);
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .iter()
            .filter(|e| e.kind == want)
            .map(|e| e.ns)
            .max()
    }
}

// ---------------------------------------------------------------- pool ----

/// Free lists are segregated by power-of-two capacity class: class `c`
/// holds buffers with capacity in `[2^c, 2^{c+1})`.  A request of `len`
/// elements is served from the first non-empty class ≥ `⌈log2 len⌉`, so
/// every hit fits without regrowing and `take` is O(#classes) instead of
/// the old O(#free buffers) first-fit scan under the lock.
const N_CLASSES: usize = usize::BITS as usize;

/// Class a buffer of `cap` elements files under (⌊log2 cap⌋).
fn class_of_capacity(cap: usize) -> usize {
    debug_assert!(cap > 0);
    usize::BITS as usize - 1 - cap.leading_zeros() as usize
}

/// Smallest class guaranteed to fit a request of `len` (⌈log2 len⌉).
fn class_for_len(len: usize) -> usize {
    if len <= 1 {
        0
    } else {
        usize::BITS as usize - (len - 1).leading_zeros() as usize
    }
}

/// Per-class free lists: index = capacity class, entries = idle buffers.
type FreeLists = Vec<Vec<Vec<f32>>>;

#[derive(Debug)]
struct PoolInner {
    free: Mutex<FreeLists>,
    /// Buffers served from the free lists (steady-state hits).
    recycled: AtomicU64,
    /// Buffers that had to be freshly allocated (cold-start misses).
    allocated: AtomicU64,
}

impl Default for PoolInner {
    fn default() -> Self {
        Self {
            free: Mutex::new((0..N_CLASSES).map(|_| Vec::new()).collect()),
            recycled: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
        }
    }
}

/// Fabric-wide recycle bin for message buffers.  `Clone` shares the pool.
#[derive(Clone, Debug, Default)]
pub struct BufferPool {
    inner: Arc<PoolInner>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty buffer with capacity ≥ `len`, recycled when possible.
    /// Served from the size-classed free lists (first non-empty class
    /// that guarantees a fit — O(#classes) under the lock); a miss
    /// allocates at the class ceiling so the new buffer recycles for any
    /// request of its class.  The `recycled`/`allocated` counters keep
    /// honestly tracking heap traffic: a hit never regrows, a miss is
    /// exactly one allocation.
    fn take(&self, len: usize) -> Vec<f32> {
        let c0 = class_for_len(len);
        {
            let mut free = self.inner.free.lock().expect("pool poisoned");
            for class in free[c0..].iter_mut() {
                if let Some(mut buf) = class.pop() {
                    debug_assert!(buf.capacity() >= len);
                    self.inner.recycled.fetch_add(1, Ordering::Relaxed);
                    buf.clear();
                    return buf;
                }
            }
        }
        self.inner.allocated.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(len.next_power_of_two())
    }

    /// Copy `src` into a pooled buffer and wrap it as a [`Payload`]
    /// (the buffer returns here when the payload's last handle drops).
    pub fn payload_from_slice(&self, src: &[f32]) -> Payload {
        let mut buf = self.take(src.len());
        buf.extend_from_slice(src);
        Payload(Arc::new(PayloadBuf {
            data: buf,
            pool: Arc::downgrade(&self.inner),
        }))
    }

    /// Decode little-endian f32 bytes (a wire frame body) straight into
    /// a pooled buffer — the receive path's analogue of
    /// [`BufferPool::payload_from_slice`], no intermediate `Vec<f32>`.
    pub(crate) fn payload_from_le_bytes(&self, bytes: &[u8]) -> Payload {
        debug_assert_eq!(bytes.len() % 4, 0, "frame bodies are f32-aligned");
        let mut buf = self.take(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            buf.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Payload(Arc::new(PayloadBuf {
            data: buf,
            pool: Arc::downgrade(&self.inner),
        }))
    }

    /// Buffers served from the free list so far.
    pub fn recycled(&self) -> u64 {
        self.inner.recycled.load(Ordering::Relaxed)
    }

    /// Buffers freshly allocated so far.
    pub fn allocated(&self) -> u64 {
        self.inner.allocated.load(Ordering::Relaxed)
    }
}

// ------------------------------------------------------------- payload ----

#[derive(Debug)]
struct PayloadBuf {
    data: Vec<f32>,
    /// Owning pool, if any; `Weak` so dropping the fabric frees buffers.
    pool: Weak<PoolInner>,
}

impl Drop for PayloadBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.upgrade() {
            let buf = std::mem::take(&mut self.data);
            if buf.capacity() > 0 {
                let class = class_of_capacity(buf.capacity());
                pool.free.lock().expect("pool poisoned")[class].push(buf);
            }
        }
    }
}

/// A message body: shared, immutable `f32` data.  `clone` copies the
/// handle, not the data — that is what makes ring forwarding and broadcast
/// fan-out zero-copy.
#[derive(Clone, Debug)]
pub struct Payload(Arc<PayloadBuf>);

impl Payload {
    /// Wrap an owned vector (not pooled — it is freed on last drop).
    pub fn from_vec(v: Vec<f32>) -> Self {
        Payload(Arc::new(PayloadBuf { data: v, pool: Weak::new() }))
    }

    /// Mutable access.  Free when this handle is unique (the common case:
    /// a received message has exactly one owner); falls back to one copy
    /// when the buffer is shared (e.g. a broadcast payload someone kept).
    pub fn make_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.0).is_none() {
            let copied = self.0.data.clone();
            self.0 = Arc::new(PayloadBuf { data: copied, pool: Weak::new() });
        }
        &mut Arc::get_mut(&mut self.0).expect("unique after copy").data
    }

    /// Extract the underlying vector: moves when unique, copies otherwise.
    /// The buffer is detached from its pool either way.
    pub fn into_vec(self) -> Vec<f32> {
        match Arc::try_unwrap(self.0) {
            Ok(mut buf) => {
                buf.pool = Weak::new(); // don't recycle — caller owns it now
                std::mem::take(&mut buf.data)
            }
            Err(shared) => shared.data.clone(),
        }
    }
}

impl Deref for Payload {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0.data
    }
}

impl From<Vec<f32>> for Payload {
    fn from(v: Vec<f32>) -> Self {
        Payload::from_vec(v)
    }
}

impl PartialEq<[f32]> for Payload {
    fn eq(&self, other: &[f32]) -> bool {
        **self == *other
    }
}

impl PartialEq<Vec<f32>> for Payload {
    fn eq(&self, other: &Vec<f32>) -> bool {
        **self == other[..]
    }
}

// ------------------------------------------------------------ endpoint ----

/// One fabric message as a [`Transport`] carries it.  Public because the
/// transport trait is public SPI; protocol code never builds these by
/// hand — `Endpoint::send` assigns the seq and accounts the stats.
#[derive(Clone, Debug)]
pub struct Msg {
    pub from: usize,
    /// Per-(sender → receiver) sequence number, 1-based.  Retransmits and
    /// injected duplicates carry the original seq; the receiver dedups.
    pub seq: u64,
    pub tag: u64,
    pub data: Payload,
}

/// Receiver-side duplicate filter for one sender edge.  On the clean path
/// seqs arrive in order, so the watermark bumps and the `ahead` set stays
/// empty — no hashing, no allocation.  Under reordering the out-of-order
/// seqs park in `ahead` until the gap closes.
#[derive(Debug, Default)]
struct SeqTracker {
    /// Every seq ≤ this has been seen.
    max_contig: u64,
    /// Seen seqs beyond the contiguous watermark.
    ahead: HashSet<u64>,
}

impl SeqTracker {
    /// Record `seq`; returns true if it was already seen (a duplicate).
    fn duplicate(&mut self, seq: u64) -> bool {
        if seq <= self.max_contig {
            return true;
        }
        if seq == self.max_contig + 1 {
            self.max_contig += 1;
            if !self.ahead.is_empty() {
                while self.ahead.remove(&(self.max_contig + 1)) {
                    self.max_contig += 1;
                }
            }
            return false;
        }
        !self.ahead.insert(seq)
    }
}

/// One worker's endpoint: send to any peer, tagged deadline receive.
/// The protocol layer (seq assignment, dedup, parking, deadlines) lives
/// here and is identical whichever [`Transport`] moves the bytes.
pub struct Endpoint {
    pub id: usize,
    pub n: usize,
    transport: Box<dyn Transport>,
    /// Out-of-order arrivals parked until someone asks for them.
    parked: HashMap<(usize, u64), VecDeque<Payload>>,
    /// Next outgoing sequence number per destination (1-based).
    next_seq: Vec<Cell<u64>>,
    /// Duplicate filter per source.
    seen: Vec<SeqTracker>,
    deadline: Duration,
    injector: Option<Arc<FaultInjector>>,
    stats: Arc<CommStats>,
    pool: BufferPool,
}

impl Endpoint {
    /// Send `data` to `to` under `tag`.  f32 payloads only (params, grads,
    /// activations — everything the paper communicates).  Accepts a
    /// [`Payload`] (zero-copy hand-off / forward) or a plain `Vec<f32>`.
    /// Errors with [`CommError::PeerGone`] if `to`'s endpoint was dropped.
    pub fn send(
        &self,
        to: usize,
        tag: u64,
        data: impl Into<Payload>,
    ) -> Result<(), CommError> {
        let data = data.into();
        assert_ne!(to, self.id, "self-send");
        self.stats
            .bytes
            .fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        let seq = self.next_seq[to].get() + 1;
        self.next_seq[to].set(seq);
        let msg = Msg { from: self.id, seq, tag, data };
        match &self.injector {
            // Control-plane traffic (heartbeat, checkpoint) bypasses the
            // injector — see the fault model in DESIGN-ROBUSTNESS.md.
            Some(inj) if !tags::is_control(tag) => inj.route(to, msg),
            _ => self.transport.send(to, msg),
        }
    }

    /// Send a copy of `data`, staged through the fabric's buffer pool so
    /// steady-state sends allocate nothing.
    pub fn send_copy(&self, to: usize, tag: u64, data: &[f32]) -> Result<(), CommError> {
        let payload = self.pool.payload_from_slice(data);
        self.send(to, tag, payload)
    }

    /// Receive the message sent by `from` under `tag`, waiting at most the
    /// endpoint's default deadline (see [`Endpoint::set_deadline`]).
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Payload, CommError> {
        self.recv_deadline(from, tag, self.deadline)
    }

    /// Receive with an explicit deadline.  Waits in exponentially growing
    /// slices (`BACKOFF_START` … `BACKOFF_MAX`); after each empty
    /// slice it asks the fault injector (if any) to retransmit anything
    /// lost or held on the `from → self` edge, so injected-lossy edges
    /// recover without the sender's involvement.  Duplicates (retransmits
    /// that raced the original, injected dups) are dropped by sequence
    /// number before they can match or park.
    pub fn recv_deadline(
        &mut self,
        from: usize,
        tag: u64,
        deadline: Duration,
    ) -> Result<Payload, CommError> {
        if let Some(q) = self.parked.get_mut(&(from, tag)) {
            if let Some(p) = q.pop_front() {
                return Ok(p);
            }
        }
        let start = Instant::now();
        let mut slice = BACKOFF_START;
        loop {
            let waited = start.elapsed();
            if waited >= deadline {
                return Err(CommError::Timeout {
                    peer: from,
                    tag: tags::unpack(tag),
                    waited,
                });
            }
            match self.transport.recv_timeout(slice.min(deadline - waited)) {
                Ok(msg) => {
                    if self.seen[msg.from].duplicate(msg.seq) {
                        continue;
                    }
                    if msg.from == from && msg.tag == tag {
                        return Ok(msg.data);
                    }
                    self.parked
                        .entry((msg.from, msg.tag))
                        .or_default()
                        .push_back(msg.data);
                }
                Err(RecvTimeoutErr::Timeout) => {
                    if let Some(inj) = &self.injector {
                        inj.recover(self.id, from);
                    }
                    slice = (slice * 2).min(BACKOFF_MAX);
                }
                Err(RecvTimeoutErr::Closed) => {
                    return Err(CommError::Closed {
                        peer: from,
                        tag: tags::unpack(tag),
                    });
                }
            }
        }
    }

    /// Replace the default receive deadline (tests use short ones; the
    /// heartbeat detector uses its own explicit [`Endpoint::recv_deadline`]).
    pub fn set_deadline(&mut self, deadline: Duration) {
        self.deadline = deadline;
    }

    pub fn deadline(&self) -> Duration {
        self.deadline
    }

    /// The fault injector attached at fabric construction, if any.
    pub fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    /// The fabric-wide buffer pool this endpoint stages copies through.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    pub fn right(&self) -> usize {
        (self.id + 1) % self.n
    }

    pub fn left(&self) -> usize {
        (self.id + self.n - 1) % self.n
    }

    /// An endpoint over an externally built transport — the
    /// multi-process path, where each OS process holds exactly one
    /// endpoint of the fabric (`WireTransport::bind` + this).
    /// In-process fabrics use [`Fabric::new`] / [`Fabric::wire`].
    pub fn over(
        id: usize,
        n: usize,
        transport: Box<dyn Transport>,
        stats: Arc<CommStats>,
        pool: BufferPool,
    ) -> Self {
        Endpoint {
            id,
            n,
            transport,
            parked: HashMap::new(),
            next_seq: (0..n).map(|_| Cell::new(0)).collect(),
            seen: (0..n).map(|_| SeqTracker::default()).collect(),
            deadline: DEFAULT_DEADLINE,
            injector: None,
            stats,
            pool,
        }
    }
}

/// A (possibly partial) ring over a fabric's endpoints: position-based
/// roles (who is first, who is the optimizer owner) with endpoint-id
/// addressing.  The full fabric is the common case; after a worker loss
/// the survivors re-form with [`RingView::from_live`] and every ring
/// protocol keeps working on the smaller ring (DESIGN-ROBUSTNESS.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RingView {
    /// My position in the ring, 0-based.
    pub pos: usize,
    /// Ring size (number of live members).
    pub m: usize,
    /// Endpoint id of the member at position `pos - 1 (mod m)`.
    pub left: usize,
    /// Endpoint id of the member at position `pos + 1 (mod m)`.
    pub right: usize,
}

impl RingView {
    /// The full fabric as a ring (position = endpoint id).
    pub fn full(ep: &Endpoint) -> Self {
        Self { pos: ep.id, m: ep.n, left: ep.left(), right: ep.right() }
    }

    /// The ring over `live` (sorted, deduplicated endpoint ids) as seen
    /// from member `me`.  Panics if `me` is not in `live`.
    pub fn from_live(me: usize, live: &[usize]) -> Self {
        debug_assert!(live.windows(2).all(|w| w[0] < w[1]), "live set not sorted");
        let m = live.len();
        let pos = live
            .iter()
            .position(|&w| w == me)
            .expect("member not in live set");
        Self {
            pos,
            m,
            left: live[(pos + m - 1) % m],
            right: live[(pos + 1) % m],
        }
    }
}

/// Build a fully-connected fabric of `n` endpoints.
pub struct Fabric;

impl Fabric {
    pub fn new(n: usize) -> (Vec<Endpoint>, Arc<CommStats>) {
        let (eps, stats, _) = Self::build(n, None);
        (eps, stats)
    }

    /// A fabric whose edges run through a deterministic, seeded
    /// [`FaultInjector`] (drop / duplicate / delay / reorder plus the
    /// scripted worker-kill carried to the coordinators).
    pub fn with_faults(
        n: usize,
        plan: FaultPlan,
    ) -> (Vec<Endpoint>, Arc<CommStats>, Arc<FaultInjector>) {
        let (eps, stats, inj) = Self::build(n, Some(plan));
        (eps, stats, inj.expect("injector built"))
    }

    /// All `n` endpoints of a socket fabric in **one** process — real
    /// frames, real reconnect supervision, no process spawning.  This is
    /// what the wire tests and benches use; a real multi-process launch
    /// builds one endpoint per process with [`WireTransport::bind`] +
    /// [`Endpoint::over`] against the same [`WireConfig`].
    pub fn wire(cfg: &WireConfig) -> anyhow::Result<(Vec<Endpoint>, Arc<CommStats>)> {
        let stats = Arc::new(CommStats::default());
        let pool = BufferPool::new();
        let mut endpoints = Vec::with_capacity(cfg.n);
        for id in 0..cfg.n {
            let t = WireTransport::bind(id, cfg, pool.clone())?;
            endpoints.push(Endpoint::over(
                id,
                cfg.n,
                Box::new(t),
                stats.clone(),
                pool.clone(),
            ));
        }
        Ok((endpoints, stats))
    }

    fn build(
        n: usize,
        plan: Option<FaultPlan>,
    ) -> (Vec<Endpoint>, Arc<CommStats>, Option<Arc<FaultInjector>>) {
        let stats = Arc::new(CommStats::default());
        let pool = BufferPool::new();
        let mut txs_all: Vec<Sender<Msg>> = Vec::with_capacity(n);
        let mut inboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, inbox) = channel();
            txs_all.push(tx);
            inboxes.push(inbox);
        }
        let injector =
            plan.map(|p| Arc::new(FaultInjector::new(p, n, txs_all.clone())));
        let endpoints = inboxes
            .into_iter()
            .enumerate()
            .map(|(id, inbox)| Endpoint {
                id,
                n,
                transport: Box::new(ChannelTransport::new(txs_all.clone(), inbox)),
                parked: HashMap::new(),
                next_seq: (0..n).map(|_| Cell::new(0)).collect(),
                seen: (0..n).map(|_| SeqTracker::default()).collect(),
                deadline: DEFAULT_DEADLINE,
                injector: injector.clone(),
                stats: stats.clone(),
                pool: pool.clone(),
            })
            .collect();
        (endpoints, stats, injector)
    }
}

/// Tag namespaces so concurrent protocols on one fabric can't collide.
///
/// Layout (64 bits): `namespace(8) | step(32) | sub(24)`.  Steps are
/// masked to 32 bits — beyond any training run — and the sub field holds
/// protocol-specific addressing (stage, phase, micro-batch).  Nothing can
/// bleed across namespaces for any step < 2³² (tested below, including
/// steps ≥ 2²⁴ that overflowed the previous packing).
pub mod tags {
    use super::TagInfo;

    const NS_SHIFT: u32 = 56;
    const STEP_SHIFT: u32 = 24;
    const STEP_MASK: u64 = (1 << 32) - 1;
    const SUB_MASK: u64 = (1 << 24) - 1;

    /// Control-plane namespaces: heartbeat and checkpoint traffic is
    /// exempt from fault injection (DESIGN-ROBUSTNESS.md fault model).
    const NS_HB: u64 = 9;
    const NS_CKPT: u64 = 10;

    fn pack(ns: u64, step: u64, sub: u64) -> u64 {
        debug_assert!(step <= STEP_MASK, "step {step} exceeds 32-bit tag field");
        debug_assert!(sub <= SUB_MASK, "sub {sub:#x} exceeds 24-bit tag field");
        (ns << NS_SHIFT) | ((step & STEP_MASK) << STEP_SHIFT) | (sub & SUB_MASK)
    }

    /// Decode a packed tag back into its fields (for error context).
    pub fn unpack(tag: u64) -> TagInfo {
        TagInfo {
            ns: (tag >> NS_SHIFT) as u8,
            step: (tag >> STEP_SHIFT) & STEP_MASK,
            sub: tag & SUB_MASK,
            raw: tag,
        }
    }

    /// True for control-plane tags the fault injector must not perturb.
    pub fn is_control(tag: u64) -> bool {
        let ns = tag >> NS_SHIFT;
        ns == NS_HB || ns == NS_CKPT
    }

    /// grad fragment for (step, stage)
    pub fn grad(step: u64, stage: usize) -> u64 {
        pack(1, step, stage as u64)
    }

    /// per-micro-batch grad fragment for (step, stage, mb) — the
    /// unbucketed form of [`grad_shard`], kept for whole-run sharded
    /// sends (ZeRO's eager path uses `grad_shard`).
    pub fn grad_part(step: u64, stage: usize, mb: usize) -> u64 {
        debug_assert!(stage < 1 << 8 && mb < 1 << 16);
        pack(2, step, ((mb as u64) << 8) | stage as u64)
    }

    /// updated params for (step, stage)
    pub fn param(step: u64, stage: usize) -> u64 {
        pack(3, step, stage as u64)
    }

    /// scalar loss report for step
    pub fn loss(step: u64) -> u64 {
        pack(4, step, 0)
    }

    /// ring all-reduce phase p of step
    pub fn ring(step: u64, phase: usize) -> u64 {
        pack(5, step, phase as u64)
    }

    /// activation / activation-grad between pipeline stages
    pub fn act(step: u64, mb: usize, fwd: bool) -> u64 {
        let dir: u64 = if fwd { 0x1 } else { 0x2 };
        debug_assert!(mb < 1 << 16);
        pack(6, step, ((mb as u64) << 8) | dir)
    }

    /// gradient bucket partial for (step, stage, bucket) — the eager ring
    /// reduction launches one of these per bucket as backward stage runs
    /// complete (`comm::bucketed`).  Hard asserts (not debug): a field
    /// overflow would silently alias logically distinct messages, so the
    /// bound is enforced in release builds too — `comm::bucketed` clamps
    /// its bucket count to stay inside it.
    pub fn grad_bucket(step: u64, stage: usize, bucket: usize) -> u64 {
        assert!(stage < 1 << 8 && bucket < 1 << 16, "grad_bucket field overflow");
        pack(7, step, ((bucket as u64) << 8) | stage as u64)
    }

    /// per-micro-batch gradient bucket for (step, stage, mb, bucket) —
    /// ZeRO's eager sharded sends to the stage owner.  Hard asserts, same
    /// rationale as [`grad_bucket`].
    pub fn grad_shard(step: u64, stage: usize, mb: usize, bucket: usize) -> u64 {
        assert!(
            stage < 1 << 5 && mb < 1 << 5 && bucket < 1 << 14,
            "grad_shard field overflow"
        );
        pack(8, step, ((bucket as u64) << 10) | ((mb as u64) << 5) | stage as u64)
    }

    /// liveness heartbeat for a step (control plane — never injected).
    pub fn hb(step: u64) -> u64 {
        pack(NS_HB, step, 0)
    }

    /// checkpoint state transfer for (step, stage, part) where `part`
    /// distinguishes the arenas (0 = params, 1 = stale params,
    /// 2 = momentum).  Control plane — never injected.
    pub fn ckpt(step: u64, stage: usize, part: usize) -> u64 {
        debug_assert!(stage < 1 << 16 && part < 1 << 8);
        pack(NS_CKPT, step, ((stage as u64) << 8) | part as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn p2p_roundtrip_and_accounting() {
        let (mut eps, stats) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            let got = e1.recv(0, 7).unwrap();
            assert_eq!(got, vec![1.0, 2.0, 3.0]);
            e1.send(0, 8, vec![4.0]).unwrap();
        });
        e0.send(1, 7, vec![1.0, 2.0, 3.0]).unwrap();
        let mut e0 = e0;
        assert_eq!(e0.recv(1, 8).unwrap(), vec![4.0]);
        h.join().unwrap();
        assert_eq!(stats.bytes(), 16);
        assert_eq!(stats.messages(), 2);
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 100, vec![1.0]).unwrap();
        e0.send(1, 200, vec![2.0]).unwrap();
        // receive in reverse order
        assert_eq!(e1.recv(0, 200).unwrap(), vec![2.0]);
        assert_eq!(e1.recv(0, 100).unwrap(), vec![1.0]);
    }

    #[test]
    fn parked_queue_is_fifo() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        // same (from, tag) three times, parked behind a different tag
        e0.send(1, 9, vec![1.0]).unwrap();
        e0.send(1, 9, vec![2.0]).unwrap();
        e0.send(1, 9, vec![3.0]).unwrap();
        e0.send(1, 10, vec![99.0]).unwrap();
        assert_eq!(e1.recv(0, 10).unwrap(), vec![99.0]); // parks all three tag-9 msgs
        assert_eq!(e1.recv(0, 9).unwrap(), vec![1.0]);
        assert_eq!(e1.recv(0, 9).unwrap(), vec![2.0]);
        assert_eq!(e1.recv(0, 9).unwrap(), vec![3.0]);
    }

    #[test]
    fn recv_times_out_with_context_instead_of_hanging() {
        let (mut eps, _) = Fabric::new(2);
        let mut e0 = eps.remove(0);
        let t0 = Instant::now();
        let err = e0
            .recv_deadline(1, tags::param(3, 2), Duration::from_millis(50))
            .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline honored");
        match err {
            CommError::Timeout { peer, tag, .. } => {
                assert_eq!(peer, 1);
                assert_eq!(tag.ns_name(), "param");
                assert_eq!(tag.step, 3);
                assert_eq!(tag.sub, 2);
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    #[test]
    fn send_to_dropped_peer_errors_instead_of_panicking() {
        let (mut eps, _) = Fabric::new(2);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        drop(e1); // peer endpoint gone
        let err = e0.send(1, tags::loss(7), vec![1.0]).unwrap_err();
        match err {
            CommError::PeerGone { peer, tag } => {
                assert_eq!(peer, 1);
                assert_eq!(tag.ns_name(), "loss");
                assert_eq!(tag.step, 7);
            }
            other => panic!("expected PeerGone, got {other:?}"),
        }
        // the error formats with full context for diagnosis
        let msg = err.to_string();
        assert!(msg.contains("worker 1") && msg.contains("loss"), "{msg}");
    }

    #[test]
    fn seq_tracker_dedups_in_any_order() {
        let mut t = SeqTracker::default();
        assert!(!t.duplicate(1));
        assert!(!t.duplicate(2));
        assert!(t.duplicate(2), "immediate dup");
        assert!(t.duplicate(1), "late dup below watermark");
        assert!(!t.duplicate(4), "gap parks ahead");
        assert!(t.duplicate(4), "dup in ahead set");
        assert!(!t.duplicate(3), "gap closes");
        assert!(t.duplicate(3));
        assert!(t.duplicate(4), "absorbed into watermark");
        assert!(!t.duplicate(5));
    }

    #[test]
    fn ring_view_full_and_live_subsets() {
        let (eps, _) = Fabric::new(4);
        let full = RingView::full(&eps[1]);
        assert_eq!(full, RingView { pos: 1, m: 4, left: 0, right: 2 });
        // worker 2 lost: survivors re-form a 3-ring
        let live = [0usize, 1, 3];
        assert_eq!(
            RingView::from_live(0, &live),
            RingView { pos: 0, m: 3, left: 3, right: 1 }
        );
        assert_eq!(
            RingView::from_live(1, &live),
            RingView { pos: 1, m: 3, left: 0, right: 3 }
        );
        assert_eq!(
            RingView::from_live(3, &live),
            RingView { pos: 2, m: 3, left: 1, right: 0 }
        );
    }

    #[test]
    fn neighbors_modulo_n() {
        let (eps, _) = Fabric::new(3);
        assert_eq!(eps[0].right(), 1);
        assert_eq!(eps[2].right(), 0);
        assert_eq!(eps[0].left(), 2);
    }

    #[test]
    fn payload_clone_shares_and_make_mut_copies_only_when_shared() {
        let mut a = Payload::from_vec(vec![1.0, 2.0]);
        let b = a.clone();
        // shared → make_mut must copy, leaving the clone untouched
        a.make_mut()[0] = 9.0;
        assert_eq!(a, vec![9.0, 2.0]);
        assert_eq!(b, vec![1.0, 2.0]);
        // unique → make_mut mutates in place (no way to observe the
        // non-copy directly here; pool stats cover it below)
        let mut c = Payload::from_vec(vec![5.0]);
        c.make_mut()[0] = 6.0;
        assert_eq!(c.into_vec(), vec![6.0]);
    }

    #[test]
    fn pool_size_classes_serve_fitting_buffers_only() {
        let pool = BufferPool::new();
        let big = vec![1.0f32; 1000];
        let small = vec![2.0f32; 10];
        let huge = vec![3.0f32; 5000];
        // cold start: one allocation, capacity rounded to the class
        // ceiling (1024 for len 1000)
        drop(pool.payload_from_slice(&big));
        assert_eq!(pool.allocated(), 1);
        // a smaller request is served from the larger buffer's class
        drop(pool.payload_from_slice(&small));
        assert_eq!(pool.recycled(), 1, "small request reuses the big buffer");
        assert_eq!(pool.allocated(), 1);
        // a request the pooled buffer cannot fit must allocate, never
        // hand back an undersized buffer
        drop(pool.payload_from_slice(&huge));
        assert_eq!(pool.allocated(), 2, "oversized request is a fresh allocation");
        // both buffers now pooled: each class serves its own size again
        let a = pool.payload_from_slice(&big[..900]);
        let b = pool.payload_from_slice(&huge[..4000]);
        assert_eq!(pool.recycled(), 3);
        assert_eq!(pool.allocated(), 2);
        assert_eq!(a[0], 1.0);
        assert_eq!(b[0], 3.0);
    }

    #[test]
    fn timeline_is_opt_in_and_ordered_by_clock() {
        let _gate = crate::trace::recorder::test_gate();
        let stats = CommStats::default();
        stats.mark(EventKind::GradSend, 0, 0, 0, 4); // disabled → dropped
        assert!(stats.timeline().is_empty());
        stats.enable_timeline();
        stats.mark(EventKind::BwdStageDone, 1, 2, 7, 0);
        stats.mark(EventKind::GradSend, 1, 2, 7, 64);
        let tl = stats.timeline();
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].kind, EventKind::BwdStageDone);
        assert_eq!(tl[0].worker, 1);
        assert_eq!(tl[0].stage, 2);
        assert!(stats.first_ns(EventKind::GradSend) >= stats.first_ns(EventKind::BwdStageDone));
        assert_eq!(stats.first_ns(EventKind::ParamSend), None);
        // the structured view carries the step the legacy shape drops
        let evs = stats.trace_events();
        assert_eq!(evs.len(), 2);
        assert!(evs.iter().all(|e| e.step == 7));
        assert_eq!(evs[0].kind, crate::trace::TraceKind::Bwd);
        assert_eq!(evs[1].kind, crate::trace::TraceKind::GradSend);
    }

    #[test]
    fn enable_timeline_races_concurrent_marks_safely() {
        // Regression test for the enable ordering hazard: the flag used
        // to be stored *after* the reserve's lock was released, so a
        // mark racing enable could observe flag=on while the capacity
        // reservation was still pending.  With the store taken inside
        // the lock, marks serialize against enable; hammer it to prove
        // nothing panics, tears, or records a malformed event.
        let _gate = crate::trace::recorder::test_gate();
        let stats = Arc::new(CommStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let markers: Vec<_> = (0..4)
            .map(|w| {
                let stats = stats.clone();
                let stop = stop.clone();
                thread::spawn(move || {
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        stats.mark(EventKind::GradSend, w, w, n, 8);
                        n += 1;
                    }
                })
            })
            .collect();
        for _ in 0..100 {
            stats.enable_timeline();
            std::thread::yield_now();
        }
        stop.store(true, Ordering::Relaxed);
        for m in markers {
            m.join().expect("marker thread panicked");
        }
        let tl = stats.timeline();
        assert!(
            tl.iter()
                .all(|e| e.kind == EventKind::GradSend && e.bytes == 8 && e.worker < 4),
            "every recorded event is well-formed"
        );
    }

    #[test]
    fn pool_recycles_buffers_across_messages() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let data = vec![1.0f32; 128];
        for i in 0..10u64 {
            e0.send_copy(1, i, &data).unwrap();
            let got = e1.recv(0, i).unwrap();
            assert_eq!(got, data);
            drop(got); // last handle → buffer returns to the shared pool
        }
        let pool = e0.pool();
        assert_eq!(pool.allocated(), 1, "one cold-start allocation");
        assert_eq!(pool.recycled(), 9, "steady state recycles");
    }

    #[test]
    fn tags_disjoint() {
        let mut seen = std::collections::HashSet::new();
        // includes steps past 2^24 (the old packing collided there) and
        // up to the 32-bit step-field limit
        let steps = [0u64, 1, 2, 3, (1 << 24) - 1, 1 << 24, (1 << 24) + 5, (1 << 31), u32::MAX as u64];
        for &step in &steps {
            for stage in 0..4usize {
                assert!(seen.insert(tags::grad(step, stage)));
                assert!(seen.insert(tags::param(step, stage)));
                assert!(seen.insert(tags::ring(step, stage)));
                assert!(seen.insert(tags::act(step, stage, true)));
                assert!(seen.insert(tags::act(step, stage, false)));
                for mb in 1..=4usize {
                    assert!(seen.insert(tags::grad_part(step, stage, mb)));
                }
                for bucket in 0..4usize {
                    assert!(seen.insert(tags::grad_bucket(step, stage, bucket)));
                    for mb in 1..=4usize {
                        assert!(seen.insert(tags::grad_shard(step, stage, mb, bucket)));
                    }
                }
                for part in 0..3usize {
                    assert!(seen.insert(tags::ckpt(step, stage, part)));
                }
            }
            // ring phases used by the collectives (reduce 1000+rank,
            // broadcast 2000) stay clear of plain stage phases
            assert!(seen.insert(tags::ring(step, 1000)));
            assert!(seen.insert(tags::ring(step, 2000)));
            assert!(seen.insert(tags::loss(step)));
            assert!(seen.insert(tags::hb(step)));
        }
    }

    #[test]
    fn tags_unpack_round_trips_and_flags_control_plane() {
        let cases: &[(u64, u8, u64, u64)] = &[
            (tags::grad(5, 3), 1, 5, 3),
            (tags::param(1 << 30, 2), 3, 1 << 30, 2),
            (tags::loss(9), 4, 9, 0),
            (tags::hb(12), 9, 12, 0),
            (tags::ckpt(7, 2, 1), 10, 7, (2 << 8) | 1),
        ];
        for &(raw, ns, step, sub) in cases {
            let info = tags::unpack(raw);
            assert_eq!((info.ns, info.step, info.sub, info.raw), (ns, step, sub, raw));
        }
        assert!(tags::is_control(tags::hb(0)));
        assert!(tags::is_control(tags::ckpt(3, 1, 2)));
        assert!(!tags::is_control(tags::grad(0, 0)));
        assert!(!tags::is_control(tags::loss(0)));
        assert_eq!(tags::unpack(tags::hb(4)).ns_name(), "hb");
        assert_eq!(tags::unpack(tags::ckpt(4, 0, 0)).ns_name(), "ckpt");
    }
}
