//! Communication fabric: byte-counted point-to-point channels between
//! workers plus the collectives the paper compares (paper Sec. 4 / Tab 1).
//!
//! Every transfer is accounted (bytes, messages) in shared [`CommStats`];
//! the trainers' comm numbers in EXPERIMENTS.md come from here, not from
//! analytic formulas (those live in `sim::analytic` and are cross-checked).
//!
//! Determinism: `reduce_to_root` adds contributions in rank order, and the
//! cyclic ring accumulates in micro-batch order — both match the
//! single-process reference trainer bit-for-bit (DESIGN.md invariants).
//!
//! ## Zero-copy payloads and the buffer pool (DESIGN-PERF.md)
//!
//! Messages carry a [`Payload`] — a cheaply clonable (`Arc`) handle to an
//! immutable `f32` buffer.  Forwarding a received payload along a ring or
//! fanning one buffer out to N peers clones the handle, not the data.
//! Buffers obtained from the fabric's shared [`BufferPool`] return to the
//! pool when the last handle drops, so steady-state traffic recycles the
//! same allocations step after step.  The free lists are segregated by
//! power-of-two capacity class, so `take` is O(#classes) under the lock.
//!
//! [`bucketed`] adds the eager bucketed gradient reduction: per-stage
//! grad runs split into fixed buckets whose ring hops launch while
//! backprop is still running (the paper's balanced-communication claim,
//! made measurable by the opt-in [`CommStats`] timeline).

pub mod bucketed;
pub mod collectives;

use std::collections::{HashMap, VecDeque};
use std::ops::Deref;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

/// What a [`TimelineEvent`] records.  The set is deliberately small: just
/// enough to prove (in benches/tests) that the bucketed gradient
/// reduction *overlaps* backprop — a `GradSend` with a timestamp earlier
/// than the last `BwdStageDone` is the overlap, made visible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A gradient bucket partial left a worker.
    GradSend,
    /// A worker finished one stage's backward pass.
    BwdStageDone,
    /// Updated parameters left the optimizer owner.
    ParamSend,
}

/// One timestamped comm/compute event (`ns` is relative to the fabric's
/// creation instant, so events from all workers share one clock).
#[derive(Clone, Copy, Debug)]
pub struct TimelineEvent {
    pub ns: u64,
    pub kind: EventKind,
    pub worker: usize,
    pub stage: usize,
    pub bytes: u64,
}

/// Global transfer accounting, shared by all endpoints of a fabric, plus
/// an opt-in event timeline (disabled by default — `mark` is a no-op
/// until [`CommStats::enable_timeline`] runs, so the hot path pays one
/// relaxed atomic load).
#[derive(Debug)]
pub struct CommStats {
    pub bytes: AtomicU64,
    pub messages: AtomicU64,
    timeline_on: AtomicBool,
    epoch: Instant,
    timeline: Mutex<Vec<TimelineEvent>>,
}

impl Default for CommStats {
    fn default() -> Self {
        Self {
            bytes: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            timeline_on: AtomicBool::new(false),
            epoch: Instant::now(),
            timeline: Mutex::new(Vec::new()),
        }
    }
}

impl CommStats {
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Start recording `mark` events (reserves capacity so steady-state
    /// recording does not reallocate per event).
    pub fn enable_timeline(&self) {
        self.timeline.lock().expect("timeline poisoned").reserve(4096);
        self.timeline_on.store(true, Ordering::Release);
    }

    /// Record one event; no-op unless the timeline is enabled.
    pub fn mark(&self, kind: EventKind, worker: usize, stage: usize, bytes: u64) {
        if !self.timeline_on.load(Ordering::Acquire) {
            return;
        }
        let ns = self.epoch.elapsed().as_nanos() as u64;
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .push(TimelineEvent { ns, kind, worker, stage, bytes });
    }

    /// Snapshot of all recorded events (unsorted — workers interleave).
    pub fn timeline(&self) -> Vec<TimelineEvent> {
        self.timeline.lock().expect("timeline poisoned").clone()
    }

    /// Earliest timestamp of `kind`, if any was recorded.
    pub fn first_ns(&self, kind: EventKind) -> Option<u64> {
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.ns)
            .min()
    }

    /// Latest timestamp of `kind`, if any was recorded.
    pub fn last_ns(&self, kind: EventKind) -> Option<u64> {
        self.timeline
            .lock()
            .expect("timeline poisoned")
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.ns)
            .max()
    }
}

// ---------------------------------------------------------------- pool ----

/// Free lists are segregated by power-of-two capacity class: class `c`
/// holds buffers with capacity in `[2^c, 2^{c+1})`.  A request of `len`
/// elements is served from the first non-empty class ≥ `⌈log2 len⌉`, so
/// every hit fits without regrowing and `take` is O(#classes) instead of
/// the old O(#free buffers) first-fit scan under the lock.
const N_CLASSES: usize = usize::BITS as usize;

/// Class a buffer of `cap` elements files under (⌊log2 cap⌋).
fn class_of_capacity(cap: usize) -> usize {
    debug_assert!(cap > 0);
    usize::BITS as usize - 1 - cap.leading_zeros() as usize
}

/// Smallest class guaranteed to fit a request of `len` (⌈log2 len⌉).
fn class_for_len(len: usize) -> usize {
    if len <= 1 {
        0
    } else {
        usize::BITS as usize - (len - 1).leading_zeros() as usize
    }
}

/// Per-class free lists: index = capacity class, entries = idle buffers.
type FreeLists = Vec<Vec<Vec<f32>>>;

#[derive(Debug)]
struct PoolInner {
    free: Mutex<FreeLists>,
    /// Buffers served from the free lists (steady-state hits).
    recycled: AtomicU64,
    /// Buffers that had to be freshly allocated (cold-start misses).
    allocated: AtomicU64,
}

impl Default for PoolInner {
    fn default() -> Self {
        Self {
            free: Mutex::new((0..N_CLASSES).map(|_| Vec::new()).collect()),
            recycled: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
        }
    }
}

/// Fabric-wide recycle bin for message buffers.  `Clone` shares the pool.
#[derive(Clone, Debug, Default)]
pub struct BufferPool {
    inner: Arc<PoolInner>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty buffer with capacity ≥ `len`, recycled when possible.
    /// Served from the size-classed free lists (first non-empty class
    /// that guarantees a fit — O(#classes) under the lock); a miss
    /// allocates at the class ceiling so the new buffer recycles for any
    /// request of its class.  The `recycled`/`allocated` counters keep
    /// honestly tracking heap traffic: a hit never regrows, a miss is
    /// exactly one allocation.
    fn take(&self, len: usize) -> Vec<f32> {
        let c0 = class_for_len(len);
        {
            let mut free = self.inner.free.lock().expect("pool poisoned");
            for class in free[c0..].iter_mut() {
                if let Some(mut buf) = class.pop() {
                    debug_assert!(buf.capacity() >= len);
                    self.inner.recycled.fetch_add(1, Ordering::Relaxed);
                    buf.clear();
                    return buf;
                }
            }
        }
        self.inner.allocated.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(len.next_power_of_two())
    }

    /// Copy `src` into a pooled buffer and wrap it as a [`Payload`]
    /// (the buffer returns here when the payload's last handle drops).
    pub fn payload_from_slice(&self, src: &[f32]) -> Payload {
        let mut buf = self.take(src.len());
        buf.extend_from_slice(src);
        Payload(Arc::new(PayloadBuf {
            data: buf,
            pool: Arc::downgrade(&self.inner),
        }))
    }

    /// Buffers served from the free list so far.
    pub fn recycled(&self) -> u64 {
        self.inner.recycled.load(Ordering::Relaxed)
    }

    /// Buffers freshly allocated so far.
    pub fn allocated(&self) -> u64 {
        self.inner.allocated.load(Ordering::Relaxed)
    }
}

// ------------------------------------------------------------- payload ----

#[derive(Debug)]
struct PayloadBuf {
    data: Vec<f32>,
    /// Owning pool, if any; `Weak` so dropping the fabric frees buffers.
    pool: Weak<PoolInner>,
}

impl Drop for PayloadBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.upgrade() {
            let buf = std::mem::take(&mut self.data);
            if buf.capacity() > 0 {
                let class = class_of_capacity(buf.capacity());
                pool.free.lock().expect("pool poisoned")[class].push(buf);
            }
        }
    }
}

/// A message body: shared, immutable `f32` data.  `clone` copies the
/// handle, not the data — that is what makes ring forwarding and broadcast
/// fan-out zero-copy.
#[derive(Clone, Debug)]
pub struct Payload(Arc<PayloadBuf>);

impl Payload {
    /// Wrap an owned vector (not pooled — it is freed on last drop).
    pub fn from_vec(v: Vec<f32>) -> Self {
        Payload(Arc::new(PayloadBuf { data: v, pool: Weak::new() }))
    }

    /// Mutable access.  Free when this handle is unique (the common case:
    /// a received message has exactly one owner); falls back to one copy
    /// when the buffer is shared (e.g. a broadcast payload someone kept).
    pub fn make_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.0).is_none() {
            let copied = self.0.data.clone();
            self.0 = Arc::new(PayloadBuf { data: copied, pool: Weak::new() });
        }
        &mut Arc::get_mut(&mut self.0).expect("unique after copy").data
    }

    /// Extract the underlying vector: moves when unique, copies otherwise.
    /// The buffer is detached from its pool either way.
    pub fn into_vec(self) -> Vec<f32> {
        match Arc::try_unwrap(self.0) {
            Ok(mut buf) => {
                buf.pool = Weak::new(); // don't recycle — caller owns it now
                std::mem::take(&mut buf.data)
            }
            Err(shared) => shared.data.clone(),
        }
    }
}

impl Deref for Payload {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0.data
    }
}

impl From<Vec<f32>> for Payload {
    fn from(v: Vec<f32>) -> Self {
        Payload::from_vec(v)
    }
}

impl PartialEq<[f32]> for Payload {
    fn eq(&self, other: &[f32]) -> bool {
        **self == *other
    }
}

impl PartialEq<Vec<f32>> for Payload {
    fn eq(&self, other: &Vec<f32>) -> bool {
        **self == other[..]
    }
}

// ------------------------------------------------------------ endpoint ----

#[derive(Debug)]
struct Msg {
    from: usize,
    tag: u64,
    data: Payload,
}

/// One worker's endpoint: send to any peer, tagged blocking receive.
pub struct Endpoint {
    pub id: usize,
    pub n: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order arrivals parked until someone asks for them.
    parked: HashMap<(usize, u64), VecDeque<Payload>>,
    stats: Arc<CommStats>,
    pool: BufferPool,
}

impl Endpoint {
    /// Send `data` to `to` under `tag`.  f32 payloads only (params, grads,
    /// activations — everything the paper communicates).  Accepts a
    /// [`Payload`] (zero-copy hand-off / forward) or a plain `Vec<f32>`.
    pub fn send(&self, to: usize, tag: u64, data: impl Into<Payload>) {
        let data = data.into();
        assert_ne!(to, self.id, "self-send");
        self.stats
            .bytes
            .fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.txs[to]
            .send(Msg { from: self.id, tag, data })
            .expect("peer endpoint dropped");
    }

    /// Send a copy of `data`, staged through the fabric's buffer pool so
    /// steady-state sends allocate nothing.
    pub fn send_copy(&self, to: usize, tag: u64, data: &[f32]) {
        let payload = self.pool.payload_from_slice(data);
        self.send(to, tag, payload);
    }

    /// Blocking receive of the message sent by `from` under `tag`.
    pub fn recv(&mut self, from: usize, tag: u64) -> Payload {
        if let Some(q) = self.parked.get_mut(&(from, tag)) {
            if let Some(p) = q.pop_front() {
                return p;
            }
        }
        loop {
            let msg = self.rx.recv().expect("fabric closed");
            if msg.from == from && msg.tag == tag {
                return msg.data;
            }
            self.parked
                .entry((msg.from, msg.tag))
                .or_default()
                .push_back(msg.data);
        }
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    /// The fabric-wide buffer pool this endpoint stages copies through.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    pub fn right(&self) -> usize {
        (self.id + 1) % self.n
    }

    pub fn left(&self) -> usize {
        (self.id + self.n - 1) % self.n
    }
}

/// Build a fully-connected fabric of `n` endpoints.
pub struct Fabric;

impl Fabric {
    pub fn new(n: usize) -> (Vec<Endpoint>, Arc<CommStats>) {
        let stats = Arc::new(CommStats::default());
        let pool = BufferPool::new();
        let mut txs_all = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs_all.push(tx);
            rxs.push(rx);
        }
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(id, rx)| Endpoint {
                id,
                n,
                txs: txs_all.clone(),
                rx,
                parked: HashMap::new(),
                stats: stats.clone(),
                pool: pool.clone(),
            })
            .collect();
        (endpoints, stats)
    }
}

/// Tag namespaces so concurrent protocols on one fabric can't collide.
///
/// Layout (64 bits): `namespace(8) | step(32) | sub(24)`.  Steps are
/// masked to 32 bits — beyond any training run — and the sub field holds
/// protocol-specific addressing (stage, phase, micro-batch).  Nothing can
/// bleed across namespaces for any step < 2³² (tested below, including
/// steps ≥ 2²⁴ that overflowed the previous packing).
pub mod tags {
    const NS_SHIFT: u32 = 56;
    const STEP_SHIFT: u32 = 24;
    const STEP_MASK: u64 = (1 << 32) - 1;
    const SUB_MASK: u64 = (1 << 24) - 1;

    fn pack(ns: u64, step: u64, sub: u64) -> u64 {
        debug_assert!(step <= STEP_MASK, "step {step} exceeds 32-bit tag field");
        debug_assert!(sub <= SUB_MASK, "sub {sub:#x} exceeds 24-bit tag field");
        (ns << NS_SHIFT) | ((step & STEP_MASK) << STEP_SHIFT) | (sub & SUB_MASK)
    }

    /// grad fragment for (step, stage)
    pub fn grad(step: u64, stage: usize) -> u64 {
        pack(1, step, stage as u64)
    }

    /// per-micro-batch grad fragment for (step, stage, mb) — the
    /// unbucketed form of [`grad_shard`], kept for whole-run sharded
    /// sends (ZeRO's eager path uses `grad_shard`).
    pub fn grad_part(step: u64, stage: usize, mb: usize) -> u64 {
        debug_assert!(stage < 1 << 8 && mb < 1 << 16);
        pack(2, step, ((mb as u64) << 8) | stage as u64)
    }

    /// updated params for (step, stage)
    pub fn param(step: u64, stage: usize) -> u64 {
        pack(3, step, stage as u64)
    }

    /// scalar loss report for step
    pub fn loss(step: u64) -> u64 {
        pack(4, step, 0)
    }

    /// ring all-reduce phase p of step
    pub fn ring(step: u64, phase: usize) -> u64 {
        pack(5, step, phase as u64)
    }

    /// activation / activation-grad between pipeline stages
    pub fn act(step: u64, mb: usize, fwd: bool) -> u64 {
        let dir: u64 = if fwd { 0x1 } else { 0x2 };
        debug_assert!(mb < 1 << 16);
        pack(6, step, ((mb as u64) << 8) | dir)
    }

    /// gradient bucket partial for (step, stage, bucket) — the eager ring
    /// reduction launches one of these per bucket as backward stage runs
    /// complete (`comm::bucketed`).  Hard asserts (not debug): a field
    /// overflow would silently alias logically distinct messages, so the
    /// bound is enforced in release builds too — `comm::bucketed` clamps
    /// its bucket count to stay inside it.
    pub fn grad_bucket(step: u64, stage: usize, bucket: usize) -> u64 {
        assert!(stage < 1 << 8 && bucket < 1 << 16, "grad_bucket field overflow");
        pack(7, step, ((bucket as u64) << 8) | stage as u64)
    }

    /// per-micro-batch gradient bucket for (step, stage, mb, bucket) —
    /// ZeRO's eager sharded sends to the stage owner.  Hard asserts, same
    /// rationale as [`grad_bucket`].
    pub fn grad_shard(step: u64, stage: usize, mb: usize, bucket: usize) -> u64 {
        assert!(
            stage < 1 << 5 && mb < 1 << 5 && bucket < 1 << 14,
            "grad_shard field overflow"
        );
        pack(8, step, ((bucket as u64) << 10) | ((mb as u64) << 5) | stage as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn p2p_roundtrip_and_accounting() {
        let (mut eps, stats) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            let got = e1.recv(0, 7);
            assert_eq!(got, vec![1.0, 2.0, 3.0]);
            e1.send(0, 8, vec![4.0]);
        });
        e0.send(1, 7, vec![1.0, 2.0, 3.0]);
        let mut e0 = e0;
        assert_eq!(e0.recv(1, 8), vec![4.0]);
        h.join().unwrap();
        assert_eq!(stats.bytes(), 16);
        assert_eq!(stats.messages(), 2);
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 100, vec![1.0]);
        e0.send(1, 200, vec![2.0]);
        // receive in reverse order
        assert_eq!(e1.recv(0, 200), vec![2.0]);
        assert_eq!(e1.recv(0, 100), vec![1.0]);
    }

    #[test]
    fn parked_queue_is_fifo() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        // same (from, tag) three times, parked behind a different tag
        e0.send(1, 9, vec![1.0]);
        e0.send(1, 9, vec![2.0]);
        e0.send(1, 9, vec![3.0]);
        e0.send(1, 10, vec![99.0]);
        assert_eq!(e1.recv(0, 10), vec![99.0]); // parks all three tag-9 msgs
        assert_eq!(e1.recv(0, 9), vec![1.0]);
        assert_eq!(e1.recv(0, 9), vec![2.0]);
        assert_eq!(e1.recv(0, 9), vec![3.0]);
    }

    #[test]
    fn neighbors_modulo_n() {
        let (eps, _) = Fabric::new(3);
        assert_eq!(eps[0].right(), 1);
        assert_eq!(eps[2].right(), 0);
        assert_eq!(eps[0].left(), 2);
    }

    #[test]
    fn payload_clone_shares_and_make_mut_copies_only_when_shared() {
        let mut a = Payload::from_vec(vec![1.0, 2.0]);
        let b = a.clone();
        // shared → make_mut must copy, leaving the clone untouched
        a.make_mut()[0] = 9.0;
        assert_eq!(a, vec![9.0, 2.0]);
        assert_eq!(b, vec![1.0, 2.0]);
        // unique → make_mut mutates in place (no way to observe the
        // non-copy directly here; pool stats cover it below)
        let mut c = Payload::from_vec(vec![5.0]);
        c.make_mut()[0] = 6.0;
        assert_eq!(c.into_vec(), vec![6.0]);
    }

    #[test]
    fn pool_size_classes_serve_fitting_buffers_only() {
        let pool = BufferPool::new();
        let big = vec![1.0f32; 1000];
        let small = vec![2.0f32; 10];
        let huge = vec![3.0f32; 5000];
        // cold start: one allocation, capacity rounded to the class
        // ceiling (1024 for len 1000)
        drop(pool.payload_from_slice(&big));
        assert_eq!(pool.allocated(), 1);
        // a smaller request is served from the larger buffer's class
        drop(pool.payload_from_slice(&small));
        assert_eq!(pool.recycled(), 1, "small request reuses the big buffer");
        assert_eq!(pool.allocated(), 1);
        // a request the pooled buffer cannot fit must allocate, never
        // hand back an undersized buffer
        drop(pool.payload_from_slice(&huge));
        assert_eq!(pool.allocated(), 2, "oversized request is a fresh allocation");
        // both buffers now pooled: each class serves its own size again
        let a = pool.payload_from_slice(&big[..900]);
        let b = pool.payload_from_slice(&huge[..4000]);
        assert_eq!(pool.recycled(), 3);
        assert_eq!(pool.allocated(), 2);
        assert_eq!(a[0], 1.0);
        assert_eq!(b[0], 3.0);
    }

    #[test]
    fn timeline_is_opt_in_and_ordered_by_clock() {
        let stats = CommStats::default();
        stats.mark(EventKind::GradSend, 0, 0, 4); // disabled → dropped
        assert!(stats.timeline().is_empty());
        stats.enable_timeline();
        stats.mark(EventKind::BwdStageDone, 1, 2, 0);
        stats.mark(EventKind::GradSend, 1, 2, 64);
        let tl = stats.timeline();
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].kind, EventKind::BwdStageDone);
        assert_eq!(tl[0].worker, 1);
        assert_eq!(tl[0].stage, 2);
        assert!(stats.first_ns(EventKind::GradSend) >= stats.first_ns(EventKind::BwdStageDone));
        assert_eq!(stats.first_ns(EventKind::ParamSend), None);
    }

    #[test]
    fn pool_recycles_buffers_across_messages() {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let data = vec![1.0f32; 128];
        for i in 0..10u64 {
            e0.send_copy(1, i, &data);
            let got = e1.recv(0, i);
            assert_eq!(got, data);
            drop(got); // last handle → buffer returns to the shared pool
        }
        let pool = e0.pool();
        assert_eq!(pool.allocated(), 1, "one cold-start allocation");
        assert_eq!(pool.recycled(), 9, "steady state recycles");
    }

    #[test]
    fn tags_disjoint() {
        let mut seen = std::collections::HashSet::new();
        // includes steps past 2^24 (the old packing collided there) and
        // up to the 32-bit step-field limit
        let steps = [0u64, 1, 2, 3, (1 << 24) - 1, 1 << 24, (1 << 24) + 5, (1 << 31), u32::MAX as u64];
        for &step in &steps {
            for stage in 0..4usize {
                assert!(seen.insert(tags::grad(step, stage)));
                assert!(seen.insert(tags::param(step, stage)));
                assert!(seen.insert(tags::ring(step, stage)));
                assert!(seen.insert(tags::act(step, stage, true)));
                assert!(seen.insert(tags::act(step, stage, false)));
                for mb in 1..=4usize {
                    assert!(seen.insert(tags::grad_part(step, stage, mb)));
                }
                for bucket in 0..4usize {
                    assert!(seen.insert(tags::grad_bucket(step, stage, bucket)));
                    for mb in 1..=4usize {
                        assert!(seen.insert(tags::grad_shard(step, stage, mb, bucket)));
                    }
                }
            }
            // ring phases used by the collectives (reduce 1000+rank,
            // broadcast 2000) stay clear of plain stage phases
            assert!(seen.insert(tags::ring(step, 1000)));
            assert!(seen.insert(tags::ring(step, 2000)));
            assert!(seen.insert(tags::loss(step)));
        }
    }
}
