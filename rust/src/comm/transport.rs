//! Pluggable message transports for the comm fabric.
//!
//! [`Transport`] is the seam between the fabric's protocol layer
//! (tagged sends, deadline receives, per-sender seq dedup — all in
//! `comm::mod`) and the bytes underneath.  Two implementations:
//!
//! - [`ChannelTransport`] — the in-process `mpsc` channels every fabric
//!   used before this module existed.  Default, zero behavior change.
//! - [`WireTransport`] — real sockets (Unix-domain or loopback TCP) with
//!   length-prefixed CRC-validated frames ([`frame`]), one writer thread
//!   per directed edge, and a connection supervisor that reconnects with
//!   capped backoff, replays a bounded window of recent frames, and maps
//!   a peer that stays unreachable to the existing typed
//!   [`CommError::PeerGone`] / `Timeout` errors (decoded tags intact).
//!
//! ## What the supervisor guarantees vs. what dedup guarantees
//!
//! The supervisor guarantees *delivery effort*: a broken connection is
//! redialed (backoff 2 ms doubling to 200 ms, give-up after
//! [`WireConfig::connect_deadline`]), and on reconnect the last
//! [`WireConfig::replay_frames`] frames are retransmitted before new
//! traffic.  It does NOT guarantee exactly-once delivery — replay
//! re-sends frames the receiver may already have.  Exactly-once is the
//! receiver's job: every message carries the per-sender monotone `seq`
//! assigned by `Endpoint::send`, and the receiver's `SeqTracker` drops
//! duplicates before they can match or park.  The two layers compose:
//! supervisor = at-least-once, seq dedup = at-most-once, together =
//! exactly-once across disconnects.
//!
//! ## Topology and rendezvous
//!
//! Each directed edge `a → b` is one connection, dialed by `a` (writes
//! only) and accepted by `b` (reads only).  Worker `w` binds
//! `dir/peer-{w}.sock` (UDS) or an ephemeral loopback TCP port published
//! atomically as `dir/peer-{w}.port`; dialers poll the rendezvous dir
//! until the peer appears.  The first bytes on a fresh connection are a
//! hello (`CDPH`, protocol version, from/to worker ids) so a
//! mis-addressed or foreign connection is refused before any frame is
//! parsed.
//!
//! ## Scripted wire faults
//!
//! [`WireFaultPlan`] extends the in-process fault plan to the socket
//! layer: per-edge one-shot disconnects, truncated frames (half a frame
//! flushed, then the connection dropped), and stalls, keyed by the
//! 0-based index of the next data frame on that edge.  All three recover
//! through the reconnect + replay + dedup path above, so loss sequences
//! stay bit-identical to a clean run.
#![deny(missing_docs)]

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::{tags, BufferPool, CommError, Msg};

/// Poll slice for a writer thread's outbox (also bounds shutdown latency).
const WRITER_POLL: Duration = Duration::from_millis(25);
/// Socket read timeout slice — readers wake this often to check shutdown.
const READ_SLICE: Duration = Duration::from_millis(100);
/// Accept-loop poll interval (listeners run non-blocking).
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// First reconnect backoff; doubles per failed dial.
const RECONNECT_BACKOFF_START: Duration = Duration::from_millis(2);
/// Reconnect backoff ceiling.
const RECONNECT_BACKOFF_MAX: Duration = Duration::from_millis(200);
/// Connect give-up horizon once the transport is being torn down — a
/// drop must not block for the full `connect_deadline` on a dead peer.
const CLOSING_CONNECT_DEADLINE: Duration = Duration::from_millis(200);

// ------------------------------------------------------------ trait ----

/// Transport-level receive failures.  The protocol layer
/// (`Endpoint::recv_deadline`) turns these into the typed
/// [`CommError::Timeout`] / [`CommError::Closed`] with decoded tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvTimeoutErr {
    /// Nothing arrived inside the slice — retry/backoff upstream.
    Timeout,
    /// The transport can never produce another message.
    Closed,
}

/// The seam between the fabric's protocol layer and the bytes under it.
///
/// `send` is called with a fully formed [`Msg`] (seq already assigned,
/// stats already accounted); `recv_timeout` yields whole messages in
/// arrival order.  Implementations must preserve per-edge FIFO order on
/// the clean path; after faults they may redeliver (the protocol layer
/// dedups by seq) but must never corrupt or reorder within one
/// connection.
pub trait Transport: Send {
    /// Queue `msg` for `to`.  Errors with [`CommError::PeerGone`] when
    /// the peer is known unreachable (endpoint dropped, or the wire
    /// supervisor gave up reconnecting).
    fn send(&self, to: usize, msg: Msg) -> Result<(), CommError>;

    /// Next inbound message from any peer, waiting at most `timeout`.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Msg, RecvTimeoutErr>;
}

// -------------------------------------------------- channel transport ----

/// The in-process transport: one `mpsc` channel per endpoint, every
/// sender holds clones of all receivers' send halves.  This is exactly
/// the pre-`Transport` fabric, factored behind the trait — same types,
/// same error mapping, same FIFO guarantees.
pub struct ChannelTransport {
    txs: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
}

impl ChannelTransport {
    pub(crate) fn new(txs: Vec<Sender<Msg>>, inbox: Receiver<Msg>) -> Self {
        Self { txs, inbox }
    }
}

impl Transport for ChannelTransport {
    fn send(&self, to: usize, msg: Msg) -> Result<(), CommError> {
        self.txs[to].send(msg).map_err(|e| CommError::PeerGone {
            peer: to,
            tag: tags::unpack(e.0.tag),
        })
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Msg, RecvTimeoutErr> {
        self.inbox.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvTimeoutErr::Timeout,
            RecvTimeoutError::Disconnected => RecvTimeoutErr::Closed,
        })
    }
}

// -------------------------------------------------------- frame codec ----

/// The length-prefixed frame format [`WireTransport`] ships.
///
/// ```text
/// offset  size  field
///      0     4  magic  "CDPF"
///      4     4  body length in bytes (u32 LE, multiple of 4, bounded)
///      8     4  sender worker id (u32 LE)
///     12     8  seq (u64 LE)
///     20     8  tag (u64 LE)
///     28     4  CRC-32 (IEEE) over bytes 4..28 + body
///     32     …  body: f32 little-endian
/// ```
///
/// Every decode failure is a typed [`FrameError`] — never a panic, and
/// never a silent hang: a reader that hits one drops the connection,
/// which the sending side's supervisor repairs by reconnect + replay.
pub mod frame {
    /// Frame magic: the first four bytes of every data frame.
    pub const MAGIC: [u8; 4] = *b"CDPF";
    /// Fixed header length in bytes (see the module-level layout).
    pub const HEADER_LEN: usize = 32;
    /// Upper bound on a frame body — a corrupted length field must not
    /// make a reader wait for gigabytes that will never arrive.
    pub const MAX_BODY_BYTES: u32 = 1 << 28;
    /// Hello magic: the first four bytes after a fresh connect.
    pub const HELLO_MAGIC: [u8; 4] = *b"CDPH";
    /// Hello length: magic + version + from + to, all u32 LE.
    pub const HELLO_LEN: usize = 16;
    /// Wire protocol version carried in the hello.
    pub const PROTO_VERSION: u32 = 1;

    /// Typed frame decode failures.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum FrameError {
        /// The first four bytes are not [`MAGIC`] (or not [`HELLO_MAGIC`]
        /// for a hello) — stream desync or a foreign writer.
        BadMagic { got: [u8; 4] },
        /// Hello carried an unknown protocol version.
        BadVersion { got: u32 },
        /// The length field exceeds [`MAX_BODY_BYTES`].
        Oversized { len: u32, max: u32 },
        /// The length field is not a multiple of the f32 element size.
        UnalignedBody { len: u32 },
        /// Fewer bytes than the header + declared body.
        Truncated { need: usize, have: usize },
        /// The CRC over the header fields + body does not match.
        CrcMismatch { expect: u32, got: u32 },
    }

    impl std::fmt::Display for FrameError {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            match self {
                FrameError::BadMagic { got } => {
                    write!(f, "bad frame magic {got:02x?}")
                }
                FrameError::BadVersion { got } => {
                    write!(f, "unknown wire protocol version {got}")
                }
                FrameError::Oversized { len, max } => {
                    write!(f, "frame body length {len} exceeds cap {max}")
                }
                FrameError::UnalignedBody { len } => {
                    write!(f, "frame body length {len} not a multiple of 4")
                }
                FrameError::Truncated { need, have } => {
                    write!(f, "truncated frame: need {need} bytes, have {have}")
                }
                FrameError::CrcMismatch { expect, got } => {
                    write!(f, "frame CRC mismatch: header says {expect:#010x}, body hashes to {got:#010x}")
                }
            }
        }
    }

    impl std::error::Error for FrameError {}

    /// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) lookup table,
    /// built at compile time.
    const CRC_TABLE: [u32; 256] = {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        table
    };

    /// Incremental CRC-32 so the check covers header fields + body
    /// without materializing them contiguously.
    pub struct Crc32(u32);

    impl Crc32 {
        /// Fresh accumulator (standard 0xFFFFFFFF seed).
        pub fn new() -> Self {
            Crc32(0xFFFF_FFFF)
        }

        /// Fold `bytes` into the running checksum.
        pub fn update(&mut self, bytes: &[u8]) {
            for &b in bytes {
                self.0 = CRC_TABLE[((self.0 ^ b as u32) & 0xFF) as usize] ^ (self.0 >> 8);
            }
        }

        /// Final CRC-32 value (bit-inverted accumulator).
        pub fn finish(self) -> u32 {
            !self.0
        }
    }

    impl Default for Crc32 {
        fn default() -> Self {
            Self::new()
        }
    }

    /// One-shot CRC-32 of `bytes`.
    pub fn crc32(bytes: &[u8]) -> u32 {
        let mut c = Crc32::new();
        c.update(bytes);
        c.finish()
    }

    /// A decoded frame header.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct Header {
        /// Payload length in bytes (f32 count × 4).
        pub body_len: u32,
        /// Sending worker id.
        pub from: u32,
        /// Per-sender monotone sequence number (receiver-side dedup key).
        pub seq: u64,
        /// Protocol tag (`comm::tags`) the message matches on.
        pub tag: u64,
        /// CRC-32 over header fields + body.
        pub crc: u32,
    }

    fn u32_at(buf: &[u8], at: usize) -> u32 {
        u32::from_le_bytes([buf[at], buf[at + 1], buf[at + 2], buf[at + 3]])
    }

    fn u64_at(buf: &[u8], at: usize) -> u64 {
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[at..at + 8]);
        u64::from_le_bytes(b)
    }

    /// Encode one frame into `out` (cleared first; reused per writer so
    /// steady-state framing does not allocate).
    pub fn encode(from: u32, seq: u64, tag: u64, body: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&((body.len() * 4) as u32).to_le_bytes());
        out.extend_from_slice(&from.to_le_bytes());
        out.extend_from_slice(&seq.to_le_bytes());
        out.extend_from_slice(&tag.to_le_bytes());
        out.extend_from_slice(&[0u8; 4]); // CRC placeholder, patched below
        for v in body {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let crc = {
            let mut c = Crc32::new();
            c.update(&out[4..28]);
            c.update(&out[HEADER_LEN..]);
            c.finish()
        };
        out[28..32].copy_from_slice(&crc.to_le_bytes());
    }

    /// Validate + decode a frame header (magic, bounds, alignment).
    pub fn decode_header(buf: &[u8; HEADER_LEN]) -> Result<Header, FrameError> {
        if buf[0..4] != MAGIC {
            return Err(FrameError::BadMagic { got: [buf[0], buf[1], buf[2], buf[3]] });
        }
        let body_len = u32_at(buf, 4);
        if body_len > MAX_BODY_BYTES {
            return Err(FrameError::Oversized { len: body_len, max: MAX_BODY_BYTES });
        }
        if body_len % 4 != 0 {
            return Err(FrameError::UnalignedBody { len: body_len });
        }
        Ok(Header {
            body_len,
            from: u32_at(buf, 8),
            seq: u64_at(buf, 12),
            tag: u64_at(buf, 20),
            crc: u32_at(buf, 28),
        })
    }

    /// Check the declared CRC against the header fields + body bytes.
    pub fn check_body(h: &Header, body: &[u8]) -> Result<(), FrameError> {
        if body.len() != h.body_len as usize {
            return Err(FrameError::Truncated { need: h.body_len as usize, have: body.len() });
        }
        let mut c = Crc32::new();
        c.update(&h.body_len.to_le_bytes());
        c.update(&h.from.to_le_bytes());
        c.update(&h.seq.to_le_bytes());
        c.update(&h.tag.to_le_bytes());
        c.update(body);
        let got = c.finish();
        if got != h.crc {
            return Err(FrameError::CrcMismatch { expect: h.crc, got });
        }
        Ok(())
    }

    /// Decode a whole buffered frame (tests and tooling; the streaming
    /// readers use [`decode_header`] + [`check_body`] directly).
    pub fn decode(bytes: &[u8]) -> Result<(Header, &[u8]), FrameError> {
        if bytes.len() < HEADER_LEN {
            return Err(FrameError::Truncated { need: HEADER_LEN, have: bytes.len() });
        }
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&bytes[..HEADER_LEN]);
        let h = decode_header(&head)?;
        let need = HEADER_LEN + h.body_len as usize;
        if bytes.len() < need {
            return Err(FrameError::Truncated { need, have: bytes.len() });
        }
        let body = &bytes[HEADER_LEN..need];
        check_body(&h, body)?;
        Ok((h, body))
    }

    /// Encode the post-connect hello identifying the directed edge.
    pub fn encode_hello(from: u32, to: u32) -> [u8; HELLO_LEN] {
        let mut out = [0u8; HELLO_LEN];
        out[0..4].copy_from_slice(&HELLO_MAGIC);
        out[4..8].copy_from_slice(&PROTO_VERSION.to_le_bytes());
        out[8..12].copy_from_slice(&from.to_le_bytes());
        out[12..16].copy_from_slice(&to.to_le_bytes());
        out
    }

    /// Decode a hello into `(from, to)` worker ids.
    pub fn decode_hello(buf: &[u8; HELLO_LEN]) -> Result<(u32, u32), FrameError> {
        if buf[0..4] != HELLO_MAGIC {
            return Err(FrameError::BadMagic { got: [buf[0], buf[1], buf[2], buf[3]] });
        }
        let version = u32_at(buf, 4);
        if version != PROTO_VERSION {
            return Err(FrameError::BadVersion { got: version });
        }
        Ok((u32_at(buf, 8), u32_at(buf, 12)))
    }
}

// -------------------------------------------------------- wire faults ----

/// What a scripted wire fault does to its edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFaultKind {
    /// Drop the connection before shipping the frame; the supervisor
    /// reconnects and replays.
    Disconnect,
    /// Flush half the encoded frame, then drop the connection — the
    /// reader sees a truncated/corrupt stream and discards it.
    Truncate,
    /// Sleep before shipping the frame (a stalled peer, bounded by the
    /// receiver's deadline).
    Stall,
}

/// One scripted, one-shot fault on the directed edge `from → to`,
/// firing when the writer is about to ship data frame `at_frame`
/// (0-based count of frames delivered on that edge).
#[derive(Clone, Copy, Debug)]
pub struct WireFault {
    /// What happens when the fault fires.
    pub kind: WireFaultKind,
    /// Sending worker id of the faulted edge.
    pub from: usize,
    /// Receiving worker id of the faulted edge.
    pub to: usize,
    /// 0-based index of the data frame the fault fires on.
    pub at_frame: u64,
    /// Stall duration in milliseconds ([`WireFaultKind::Stall`] only).
    pub stall_ms: u64,
}

/// A set of scripted socket-layer faults, the wire analogue of the
/// in-process `FaultPlan`.  Spec strings round-trip through
/// [`WireFaultPlan::parse`] / [`WireFaultPlan::render`] so the launcher
/// can forward a plan to worker processes on the command line.
#[derive(Clone, Debug, Default)]
pub struct WireFaultPlan {
    /// The scripted faults, in declaration order.
    pub faults: Vec<WireFault>,
}

impl WireFaultPlan {
    /// Add a one-shot connection drop on `from → to` at `at_frame`.
    pub fn disconnect(mut self, from: usize, to: usize, at_frame: u64) -> Self {
        self.faults.push(WireFault {
            kind: WireFaultKind::Disconnect,
            from,
            to,
            at_frame,
            stall_ms: 0,
        });
        self
    }

    /// Add a truncated-frame fault (half a frame flushed, then dropped).
    pub fn truncate(mut self, from: usize, to: usize, at_frame: u64) -> Self {
        self.faults.push(WireFault {
            kind: WireFaultKind::Truncate,
            from,
            to,
            at_frame,
            stall_ms: 0,
        });
        self
    }

    /// Add a stall of `ms` milliseconds before shipping `at_frame`.
    pub fn stall(mut self, from: usize, to: usize, at_frame: u64, ms: u64) -> Self {
        self.faults.push(WireFault {
            kind: WireFaultKind::Stall,
            from,
            to,
            at_frame,
            stall_ms: ms,
        });
        self
    }

    /// True when no faults are scripted (the clean-run default).
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse a comma-separated spec: `disc:F:T:K`, `trunc:F:T:K`,
    /// `stall:F:T:K:MS` (edge F→T, 0-based frame index K).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = WireFaultPlan::default();
        for entry in spec.split(',').filter(|e| !e.trim().is_empty()) {
            let parts: Vec<&str> = entry.trim().split(':').collect();
            let num = |i: usize| -> Result<u64> {
                parts
                    .get(i)
                    .with_context(|| format!("wire fault {entry:?}: missing field {i}"))?
                    .parse::<u64>()
                    .with_context(|| format!("wire fault {entry:?}: field {i} not a number"))
            };
            let (from, to, at) = (num(1)? as usize, num(2)? as usize, num(3)?);
            ensure!(from != to, "wire fault {entry:?}: self-edge");
            plan = match parts[0] {
                "disc" => {
                    ensure!(parts.len() == 4, "disc takes 3 fields: {entry:?}");
                    plan.disconnect(from, to, at)
                }
                "trunc" => {
                    ensure!(parts.len() == 4, "trunc takes 3 fields: {entry:?}");
                    plan.truncate(from, to, at)
                }
                "stall" => {
                    ensure!(parts.len() == 5, "stall takes 4 fields: {entry:?}");
                    plan.stall(from, to, at, num(4)?)
                }
                other => bail!("unknown wire fault kind {other:?} in {entry:?}"),
            };
        }
        Ok(plan)
    }

    /// Inverse of [`WireFaultPlan::parse`].
    pub fn render(&self) -> String {
        self.faults
            .iter()
            .map(|f| match f.kind {
                WireFaultKind::Disconnect => {
                    format!("disc:{}:{}:{}", f.from, f.to, f.at_frame)
                }
                WireFaultKind::Truncate => {
                    format!("trunc:{}:{}:{}", f.from, f.to, f.at_frame)
                }
                WireFaultKind::Stall => {
                    format!("stall:{}:{}:{}:{}", f.from, f.to, f.at_frame, f.stall_ms)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

// ------------------------------------------------------- wire config ----

/// Which socket family carries the frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireKind {
    /// Unix-domain sockets under the rendezvous dir (unix only).
    Uds,
    /// Loopback TCP with ports published as rendezvous files.
    Tcp,
}

impl WireKind {
    /// Parse a `--transport` value ("uds" | "tcp").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uds" => Ok(WireKind::Uds),
            "tcp" => Ok(WireKind::Tcp),
            other => bail!("unknown transport {other:?} (expected \"uds\" or \"tcp\")"),
        }
    }

    /// Canonical lowercase name, the inverse of [`WireKind::parse`].
    pub fn name(self) -> &'static str {
        match self {
            WireKind::Uds => "uds",
            WireKind::Tcp => "tcp",
        }
    }
}

/// Configuration for one wire fabric (shared by every worker of a run).
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Socket flavor (Unix-domain or loopback TCP).
    pub kind: WireKind,
    /// Rendezvous directory: sockets / port files live here.  Created on
    /// bind if missing.
    pub dir: PathBuf,
    /// Fabric size (worker count).
    pub n: usize,
    /// Scripted socket-layer faults (empty by default).
    pub faults: WireFaultPlan,
    /// Give-up horizon for (re)connecting to a peer; after this the edge
    /// reports [`CommError::PeerGone`].
    pub connect_deadline: Duration,
    /// Frames kept per edge for post-reconnect redelivery.
    pub replay_frames: usize,
}

impl WireConfig {
    /// A clean-run config with default deadlines and no scripted faults.
    pub fn new(kind: WireKind, dir: impl Into<PathBuf>, n: usize) -> Self {
        Self {
            kind,
            dir: dir.into(),
            n,
            faults: WireFaultPlan::default(),
            connect_deadline: Duration::from_secs(10),
            replay_frames: 256,
        }
    }
}

// ---------------------------------------------------- wire transport ----

enum WireStream {
    #[cfg(unix)]
    Uds(UnixStream),
    Tcp(TcpStream),
}

impl WireStream {
    fn set_nonblocking(&self, on: bool) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            WireStream::Uds(s) => s.set_nonblocking(on),
            WireStream::Tcp(s) => s.set_nonblocking(on),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            WireStream::Uds(s) => s.set_read_timeout(d),
            WireStream::Tcp(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            #[cfg(unix)]
            WireStream::Uds(s) => s.read(buf),
            WireStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            #[cfg(unix)]
            WireStream::Uds(s) => s.write(buf),
            WireStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            WireStream::Uds(s) => s.flush(),
            WireStream::Tcp(s) => s.flush(),
        }
    }
}

enum WireListener {
    #[cfg(unix)]
    Uds(UnixListener),
    Tcp(TcpListener),
}

impl WireListener {
    fn set_nonblocking(&self, on: bool) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            WireListener::Uds(l) => l.set_nonblocking(on),
            WireListener::Tcp(l) => l.set_nonblocking(on),
        }
    }

    fn accept(&self) -> io::Result<WireStream> {
        match self {
            #[cfg(unix)]
            WireListener::Uds(l) => l.accept().map(|(s, _)| WireStream::Uds(s)),
            WireListener::Tcp(l) => l.accept().map(|(s, _)| WireStream::Tcp(s)),
        }
    }
}

fn sock_path(dir: &Path, worker: usize) -> PathBuf {
    dir.join(format!("peer-{worker}.sock"))
}

fn port_path(dir: &Path, worker: usize) -> PathBuf {
    dir.join(format!("peer-{worker}.port"))
}

fn bind_listener(kind: WireKind, dir: &Path, id: usize) -> Result<WireListener> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating rendezvous dir {}", dir.display()))?;
    match kind {
        #[cfg(unix)]
        WireKind::Uds => {
            let path = sock_path(dir, id);
            let _ = std::fs::remove_file(&path); // stale socket from a dead run
            let l = UnixListener::bind(&path)
                .with_context(|| format!("binding uds listener {}", path.display()))?;
            Ok(WireListener::Uds(l))
        }
        #[cfg(not(unix))]
        WireKind::Uds => bail!("uds transport requires unix"),
        WireKind::Tcp => {
            let l = TcpListener::bind(("127.0.0.1", 0)).context("binding tcp listener")?;
            let port = l.local_addr().context("tcp local addr")?.port();
            let tmp = dir.join(format!("peer-{id}.port.tmp"));
            let fin = port_path(dir, id);
            std::fs::write(&tmp, format!("{port}\n"))
                .with_context(|| format!("writing port file {}", tmp.display()))?;
            std::fs::rename(&tmp, &fin)
                .with_context(|| format!("publishing port file {}", fin.display()))?;
            Ok(WireListener::Tcp(l))
        }
    }
}

struct WriterCtx {
    me: usize,
    peer: usize,
    kind: WireKind,
    dir: PathBuf,
    connect_deadline: Duration,
    replay_cap: usize,
    /// Faults pre-filtered to this directed edge.
    faults: Vec<WireFault>,
    gone: Arc<AtomicBool>,
    closing: Arc<AtomicBool>,
}

fn dial(ctx: &WriterCtx) -> io::Result<WireStream> {
    match ctx.kind {
        #[cfg(unix)]
        WireKind::Uds => {
            let s = UnixStream::connect(sock_path(&ctx.dir, ctx.peer))?;
            Ok(WireStream::Uds(s))
        }
        #[cfg(not(unix))]
        WireKind::Uds => Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "uds transport requires unix",
        )),
        WireKind::Tcp => {
            let text = std::fs::read_to_string(port_path(&ctx.dir, ctx.peer))?;
            let port: u16 = text
                .trim()
                .parse()
                .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad port file"))?;
            let s = TcpStream::connect(("127.0.0.1", port))?;
            s.set_nodelay(true)?;
            Ok(WireStream::Tcp(s))
        }
    }
}

/// Dial + hello with capped exponential backoff.  `None` = the peer
/// stayed unreachable for the whole deadline — the edge is declared gone.
/// A transport being torn down shortens the horizon so drops stay fast.
fn connect_with_backoff(ctx: &WriterCtx) -> Option<WireStream> {
    let deadline = if ctx.closing.load(Ordering::Acquire) {
        CLOSING_CONNECT_DEADLINE.min(ctx.connect_deadline)
    } else {
        ctx.connect_deadline
    };
    let start = Instant::now();
    let mut backoff = RECONNECT_BACKOFF_START;
    loop {
        if let Ok(mut c) = dial(ctx) {
            let hello = frame::encode_hello(ctx.me as u32, ctx.peer as u32);
            if c.write_all(&hello).is_ok() && c.flush().is_ok() {
                return Some(c);
            }
        }
        if start.elapsed() + backoff > deadline {
            return None;
        }
        thread::sleep(backoff);
        backoff = (backoff * 2).min(RECONNECT_BACKOFF_MAX);
    }
}

fn write_frame(conn: &mut Option<WireStream>, buf: &[u8]) -> io::Result<()> {
    let c = conn.as_mut().expect("connection present");
    c.write_all(buf)?;
    c.flush()
}

/// Ship one frame, repairing the connection as needed.  On reconnect the
/// replay window goes out first (receiver seq-dedup makes redelivery
/// idempotent).  `false` = the supervisor gave up (connect deadline).
fn deliver(
    ctx: &WriterCtx,
    conn: &mut Option<WireStream>,
    replay: &VecDeque<Msg>,
    msg: &Msg,
    buf: &mut Vec<u8>,
) -> bool {
    loop {
        if conn.is_none() {
            let Some(c) = connect_with_backoff(ctx) else {
                return false;
            };
            *conn = Some(c);
            // the supervisor (re-)established this directed edge
            crate::trace::instant(
                crate::trace::TraceKind::Reconnect,
                crate::trace::Fields {
                    worker: ctx.me as u32,
                    stage: ctx.peer as u32,
                    ..crate::trace::Fields::default()
                },
            );
            let mut replay_ok = true;
            for m in replay.iter() {
                frame::encode(m.from as u32, m.seq, m.tag, &m.data, buf);
                if write_frame(conn, buf).is_err() {
                    replay_ok = false;
                    break;
                }
            }
            if !replay_ok {
                *conn = None;
                continue;
            }
        }
        frame::encode(msg.from as u32, msg.seq, msg.tag, &msg.data, buf);
        if write_frame(conn, buf).is_ok() {
            // one framed message on the wire (header + body + CRC)
            crate::trace::instant(
                crate::trace::TraceKind::FrameSend,
                crate::trace::Fields {
                    worker: ctx.me as u32,
                    stage: ctx.peer as u32,
                    step: super::tags::unpack(msg.tag).step,
                    bytes: buf.len() as u64,
                    ..crate::trace::Fields::default()
                },
            );
            return true;
        }
        *conn = None;
    }
}

/// One directed edge's writer: drains the outbox, applies scripted wire
/// faults, frames and ships under the reconnect supervisor.  Exits when
/// the outbox closes (transport drop, after draining) or the supervisor
/// gives up (marks the peer gone; queued frames are discarded and later
/// sends fail fast with `PeerGone`).
fn writer_loop(ctx: WriterCtx, outbox: Receiver<Msg>) {
    let mut conn: Option<WireStream> = None;
    let mut replay: VecDeque<Msg> = VecDeque::new();
    let mut fired = vec![false; ctx.faults.len()];
    let mut delivered: u64 = 0;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let msg = match outbox.recv_timeout(WRITER_POLL) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return, // queue drained
        };
        for (k, f) in ctx.faults.iter().enumerate() {
            if fired[k] || f.at_frame != delivered {
                continue;
            }
            fired[k] = true;
            match f.kind {
                WireFaultKind::Stall => thread::sleep(Duration::from_millis(f.stall_ms)),
                WireFaultKind::Disconnect => conn = None,
                WireFaultKind::Truncate => {
                    if conn.is_some() {
                        frame::encode(msg.from as u32, msg.seq, msg.tag, &msg.data, &mut buf);
                        let half = buf.len() / 2;
                        let _ = write_frame(&mut conn, &buf[..half]);
                    }
                    conn = None;
                }
            }
        }
        if !deliver(&ctx, &mut conn, &replay, &msg, &mut buf) {
            ctx.gone.store(true, Ordering::Release);
            return;
        }
        delivered += 1;
        replay.push_back(msg);
        while replay.len() > ctx.replay_cap {
            replay.pop_front();
        }
    }
}

/// Fill `buf` from the stream, tolerating read-timeout slices (each one
/// re-checks the shutdown flag).  `false` = EOF, error, or shutdown —
/// the caller drops the connection either way.
fn read_full(stream: &mut WireStream, buf: &mut [u8], stop: &AtomicBool) -> bool {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Acquire) {
            return false;
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return false,
            Ok(k) => filled += k,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(_) => return false,
        }
    }
    true
}

/// One accepted connection's reader: validates the hello, then streams
/// frames into the shared inbox.  Any decode failure (bad magic,
/// oversized length, truncation, CRC mismatch) drops the connection —
/// typed-and-contained, never a panic or a wedged parse — and the
/// sending side's supervisor reconnects + replays.
fn reader_loop(
    mut stream: WireStream,
    feed: Sender<Msg>,
    pool: BufferPool,
    me: usize,
    n: usize,
    stop: Arc<AtomicBool>,
) {
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    if stream.set_read_timeout(Some(READ_SLICE)).is_err() {
        return;
    }
    let mut hello = [0u8; frame::HELLO_LEN];
    if !read_full(&mut stream, &mut hello, &stop) {
        return;
    }
    let from = match frame::decode_hello(&hello) {
        Ok((from, to)) if to as usize == me && (from as usize) < n && from as usize != me => {
            from as usize
        }
        _ => return, // mis-addressed or foreign connection: refuse it
    };
    let mut header = [0u8; frame::HEADER_LEN];
    let mut body: Vec<u8> = Vec::new();
    loop {
        if !read_full(&mut stream, &mut header, &stop) {
            return;
        }
        let h = match frame::decode_header(&header) {
            Ok(h) => h,
            Err(_) => return,
        };
        if h.from as usize != from {
            return; // frames must match the hello identity
        }
        body.clear();
        body.resize(h.body_len as usize, 0);
        if !read_full(&mut stream, &mut body, &stop) {
            return;
        }
        if frame::check_body(&h, &body).is_err() {
            return;
        }
        let data = pool.payload_from_le_bytes(&body);
        let (tag, body_len) = (h.tag, h.body_len as u64);
        if feed.send(Msg { from, seq: h.seq, tag: h.tag, data }).is_err() {
            return;
        }
        // one framed message accepted off the wire
        crate::trace::instant(
            crate::trace::TraceKind::FrameRecv,
            crate::trace::Fields {
                worker: me as u32,
                stage: from as u32,
                step: super::tags::unpack(tag).step,
                bytes: frame::HEADER_LEN as u64 + body_len,
                ..crate::trace::Fields::default()
            },
        );
    }
}

fn listen_loop(
    listener: WireListener,
    feed: Sender<Msg>,
    pool: BufferPool,
    me: usize,
    n: usize,
    stop: Arc<AtomicBool>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok(stream) => {
                let feed = feed.clone();
                let pool = pool.clone();
                let stop = stop.clone();
                let spawned = thread::Builder::new()
                    .name(format!("wire-read-{me}"))
                    .spawn(move || reader_loop(stream, feed, pool, me, n, stop));
                if let Ok(h) = spawned {
                    readers.lock().expect("reader registry poisoned").push(h);
                }
            }
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
    }
}

struct PeerHandle {
    outbox: Sender<Msg>,
    gone: Arc<AtomicBool>,
    writer: Option<JoinHandle<()>>,
}

/// A socket-backed [`Transport`]: one listener + accept loop feeding a
/// shared inbox, one supervised writer thread per directed outgoing
/// edge.  See the module docs for the topology, rendezvous, and the
/// supervisor/dedup split of guarantees.
pub struct WireTransport {
    peers: Vec<Option<PeerHandle>>,
    inbox: Receiver<Msg>,
    shutdown: Arc<AtomicBool>,
    closing: Arc<AtomicBool>,
    listener: Option<JoinHandle<()>>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WireTransport {
    /// Bind worker `id`'s listener under the rendezvous dir and start
    /// the per-peer writer supervisors.  Dials are lazy: the first frame
    /// to a peer establishes the directed connection, with backoff while
    /// the peer is still coming up.
    pub fn bind(id: usize, cfg: &WireConfig, pool: BufferPool) -> Result<Self> {
        ensure!(cfg.n >= 2, "wire fabric needs at least 2 workers, got {}", cfg.n);
        ensure!(id < cfg.n, "worker id {id} out of range for n={}", cfg.n);
        let listener = bind_listener(cfg.kind, &cfg.dir, id)?;
        let (feed, inbox) = channel();
        let shutdown = Arc::new(AtomicBool::new(false));
        let closing = Arc::new(AtomicBool::new(false));
        let readers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let listener_thread = {
            let pool = pool.clone();
            let stop = shutdown.clone();
            let readers = readers.clone();
            let n = cfg.n;
            thread::Builder::new()
                .name(format!("wire-accept-{id}"))
                .spawn(move || listen_loop(listener, feed, pool, id, n, stop, readers))
                .context("spawning wire accept thread")?
        };
        let mut peers: Vec<Option<PeerHandle>> = Vec::with_capacity(cfg.n);
        for p in 0..cfg.n {
            if p == id {
                peers.push(None);
                continue;
            }
            let (outbox_tx, outbox) = channel();
            let gone = Arc::new(AtomicBool::new(false));
            let ctx = WriterCtx {
                me: id,
                peer: p,
                kind: cfg.kind,
                dir: cfg.dir.clone(),
                connect_deadline: cfg.connect_deadline,
                replay_cap: cfg.replay_frames.max(1),
                faults: cfg
                    .faults
                    .faults
                    .iter()
                    .filter(|f| f.from == id && f.to == p)
                    .copied()
                    .collect(),
                gone: gone.clone(),
                closing: closing.clone(),
            };
            let writer = thread::Builder::new()
                .name(format!("wire-send-{id}-{p}"))
                .spawn(move || writer_loop(ctx, outbox))
                .context("spawning wire writer thread")?;
            peers.push(Some(PeerHandle { outbox: outbox_tx, gone, writer: Some(writer) }));
        }
        Ok(Self {
            peers,
            inbox,
            shutdown,
            closing,
            listener: Some(listener_thread),
            readers,
        })
    }
}

impl Transport for WireTransport {
    fn send(&self, to: usize, msg: Msg) -> Result<(), CommError> {
        let peer = self.peers[to].as_ref().expect("self-send rejected by Endpoint");
        if peer.gone.load(Ordering::Acquire) {
            return Err(CommError::PeerGone { peer: to, tag: tags::unpack(msg.tag) });
        }
        peer.outbox.send(msg).map_err(|e| CommError::PeerGone {
            peer: to,
            tag: tags::unpack(e.0.tag),
        })
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Msg, RecvTimeoutErr> {
        self.inbox.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvTimeoutErr::Timeout,
            RecvTimeoutError::Disconnected => RecvTimeoutErr::Closed,
        })
    }
}

impl Drop for WireTransport {
    fn drop(&mut self) {
        self.closing.store(true, Ordering::Release);
        // Writers drain their queues (flushing in-flight frames), then
        // exit when the outbox sender drops.
        for slot in &mut self.peers {
            if let Some(mut ph) = slot.take() {
                drop(ph.outbox);
                if let Some(w) = ph.writer.take() {
                    let _ = w.join();
                }
            }
        }
        self.shutdown.store(true, Ordering::Release);
        if let Some(l) = self.listener.take() {
            let _ = l.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut reg = self.readers.lock().expect("reader registry poisoned");
            reg.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn crc32_known_vector() {
        // the standard CRC-32/ISO-HDLC check value
        assert_eq!(frame::crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(frame::crc32(b""), 0);
    }

    #[test]
    fn frame_round_trips_tagged_payloads() {
        testing::check("frame_round_trip", 200, |g| {
            let from = g.usize_in(0, 31) as u32;
            let seq = g.u64() >> 1;
            let tag = tags::grad_shard(
                g.usize_in(0, 1000) as u64,
                g.usize_in(0, 7),
                g.usize_in(0, 7),
                g.usize_in(0, 15),
            );
            let len = g.usize_in(0, 300);
            let mut body = g.vec_f32(len, -1e6, 1e6);
            // exercise special bit patterns too
            if !body.is_empty() && g.bool() {
                body[0] = f32::NAN;
            }
            let mut buf = Vec::new();
            frame::encode(from, seq, tag, &body, &mut buf);
            let (h, got) = frame::decode(&buf).expect("clean frame decodes");
            assert_eq!((h.from, h.seq, h.tag), (from, seq, tag));
            assert_eq!(got.len(), body.len() * 4);
            let decoded: Vec<f32> = got
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            for (a, b) in decoded.iter().zip(body.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "bit-exact body round trip");
            }
        });
    }

    #[test]
    fn truncated_frame_is_a_typed_error() {
        let mut buf = Vec::new();
        frame::encode(1, 7, tags::loss(3), &[1.0, 2.0, 3.0], &mut buf);
        // header cut short
        let err = frame::decode(&buf[..10]).unwrap_err();
        assert!(matches!(err, frame::FrameError::Truncated { have: 10, .. }), "{err}");
        // body cut short
        let err = frame::decode(&buf[..buf.len() - 4]).unwrap_err();
        assert!(matches!(err, frame::FrameError::Truncated { .. }), "{err}");
    }

    #[test]
    fn bit_flipped_body_is_a_crc_mismatch() {
        let mut buf = Vec::new();
        frame::encode(2, 9, tags::grad(5, 1), &[4.0, 5.0], &mut buf);
        let last = buf.len() - 1;
        buf[last] ^= 0x10;
        let err = frame::decode(&buf).unwrap_err();
        assert!(matches!(err, frame::FrameError::CrcMismatch { .. }), "{err}");
    }

    #[test]
    fn oversized_length_header_is_rejected_before_reading_the_body() {
        let mut buf = Vec::new();
        frame::encode(0, 1, tags::loss(0), &[1.0], &mut buf);
        buf[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = frame::decode(&buf).unwrap_err();
        assert!(
            matches!(err, frame::FrameError::Oversized { len: u32::MAX, .. }),
            "{err}"
        );
        // unaligned length is also typed, not a wedge
        buf[4..8].copy_from_slice(&3u32.to_le_bytes());
        let err = frame::decode(&buf).unwrap_err();
        assert!(matches!(err, frame::FrameError::UnalignedBody { len: 3 }), "{err}");
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut buf = Vec::new();
        frame::encode(0, 1, tags::loss(0), &[], &mut buf);
        buf[0] = b'X';
        let err = frame::decode(&buf).unwrap_err();
        assert!(matches!(err, frame::FrameError::BadMagic { .. }), "{err}");
    }

    #[test]
    fn hello_round_trips_and_rejects_bad_versions() {
        let h = frame::encode_hello(3, 0);
        assert_eq!(frame::decode_hello(&h).unwrap(), (3, 0));
        let mut bad = h;
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(matches!(
            frame::decode_hello(&bad).unwrap_err(),
            frame::FrameError::BadVersion { got: 99 }
        ));
        let mut wrong = h;
        wrong[0] = b'Z';
        assert!(matches!(
            frame::decode_hello(&wrong).unwrap_err(),
            frame::FrameError::BadMagic { .. }
        ));
    }

    #[test]
    fn wire_fault_plan_parses_and_renders() {
        let spec = "disc:0:1:5,trunc:2:0:3,stall:1:0:2:200";
        let plan = WireFaultPlan::parse(spec).unwrap();
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.faults[0].kind, WireFaultKind::Disconnect);
        assert_eq!((plan.faults[0].from, plan.faults[0].to), (0, 1));
        assert_eq!(plan.faults[2].stall_ms, 200);
        assert_eq!(plan.render(), spec);
        assert!(WireFaultPlan::parse("").unwrap().is_empty());
        assert!(WireFaultPlan::parse("bogus:0:1:2").is_err());
        assert!(WireFaultPlan::parse("disc:0:0:1").is_err(), "self-edge rejected");
        assert!(WireFaultPlan::parse("disc:0:1").is_err(), "missing field");
        assert!(WireFaultPlan::parse("stall:0:1:2").is_err(), "stall needs ms");
    }

    #[test]
    fn wire_kind_parses() {
        assert_eq!(WireKind::parse("uds").unwrap(), WireKind::Uds);
        assert_eq!(WireKind::parse("tcp").unwrap(), WireKind::Tcp);
        assert!(WireKind::parse("carrier-pigeon").is_err());
        assert_eq!(WireKind::Uds.name(), "uds");
        assert_eq!(WireKind::Tcp.name(), "tcp");
    }
}
