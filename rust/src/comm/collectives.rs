//! Collectives over [`Endpoint`]s, SPMD-style: every member calls the same
//! function; the implementation routes by rank.
//!
//! - [`reduce_to_root`] + [`broadcast`] — the DP baseline's synchronous
//!   all-reduce (O(log N) steps in theory; we implement the rank-ordered
//!   flat tree, whose *deterministic* sum order matches the reference
//!   trainer bit-for-bit).
//! - [`ring_allreduce`] — the bandwidth-optimal ring [Patarasuk & Yuan]:
//!   2(N−1) phases of point-to-point chunk exchange.  This is the pattern
//!   CDP amortizes across the whole training step.
//!
//! All sends stage through the fabric's buffer pool ([`Endpoint::send_copy`])
//! and the broadcast fans one pooled payload out to every peer by handle
//! clone, so in steady state the collectives allocate nothing per step.
//!
//! Every receive runs against the endpoint's deadline; a lost peer turns a
//! collective into a [`CommError`] carrying the missing rank and tag
//! instead of a hang (DESIGN-ROBUSTNESS.md).

use super::{tags, CommError, Endpoint};
use crate::tensor::ops::add_into;

/// Sum `data` from all ranks into the root (rank-ordered, deterministic).
/// Non-roots return their input unchanged.
pub fn reduce_to_root(
    ep: &mut Endpoint,
    root: usize,
    step: u64,
    data: &mut [f32],
) -> Result<(), CommError> {
    if ep.id == root {
        // fixed order 0, 1, ..., n-1 (skipping root's own, added first)
        for from in 0..ep.n {
            if from == root {
                continue;
            }
            let part = ep.recv(from, tags::ring(step, 1000 + from))?;
            add_into(data, &part);
        }
    } else {
        ep.send_copy(root, tags::ring(step, 1000 + ep.id), data)?;
    }
    Ok(())
}

/// Broadcast root's `data` to everyone.  The root copies `data` into one
/// pooled payload and fans the *handle* out — N−1 sends, one copy.
pub fn broadcast(
    ep: &mut Endpoint,
    root: usize,
    step: u64,
    data: &mut [f32],
) -> Result<(), CommError> {
    if ep.id == root {
        let payload = ep.pool().payload_from_slice(data);
        for to in 0..ep.n {
            if to != root {
                ep.send(to, tags::ring(step, 2000), payload.clone())?;
            }
        }
    } else {
        let got = ep.recv(root, tags::ring(step, 2000))?;
        data.copy_from_slice(&got);
    }
    Ok(())
}

/// Flat all-reduce (reduce to root then broadcast), averaging by 1/n.
pub fn allreduce_mean(
    ep: &mut Endpoint,
    step: u64,
    data: &mut [f32],
) -> Result<(), CommError> {
    reduce_to_root(ep, 0, step, data)?;
    if ep.id == 0 {
        let inv = 1.0 / ep.n as f32;
        for v in data.iter_mut() {
            *v *= inv;
        }
    }
    broadcast(ep, 0, step, data)
}

/// Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather,
/// 2(N−1) point-to-point phases, each moving len/N elements.
/// Sum order differs per chunk (rotation), so results are deterministic
/// but not bit-identical to the rank-ordered tree — use for throughput,
/// not for golden comparisons.
pub fn ring_allreduce(
    ep: &mut Endpoint,
    step: u64,
    data: &mut [f32],
) -> Result<(), CommError> {
    let n = ep.n;
    if n == 1 {
        return Ok(());
    }
    let len = data.len();
    let chunk = |c: usize| -> std::ops::Range<usize> {
        let base = len / n;
        let rem = len % n;
        let start = c * base + c.min(rem);
        let size = base + usize::from(c < rem);
        start..start + size
    };
    let me = ep.id;
    // reduce-scatter: phase p, send chunk (me - p) mod n to right neighbor
    for p in 0..n - 1 {
        let send_c = (me + n - p) % n;
        let recv_c = (me + n - p - 1) % n;
        ep.send_copy(ep.right(), tags::ring(step, p), &data[chunk(send_c)])?;
        let part = ep.recv(ep.left(), tags::ring(step, p))?;
        add_into(&mut data[chunk(recv_c)], &part);
    }
    // all-gather: circulate the completed chunks
    for p in 0..n - 1 {
        let send_c = (me + 1 + n - p) % n;
        let recv_c = (me + n - p) % n;
        ep.send_copy(ep.right(), tags::ring(step, n + p), &data[chunk(send_c)])?;
        let part = ep.recv(ep.left(), tags::ring(step, n + p))?;
        data[chunk(recv_c)].copy_from_slice(&part);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;
    use std::thread;

    fn run_spmd<F>(n: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&mut Endpoint) -> Vec<f32> + Send + Sync + Clone + 'static,
    {
        let (eps, _) = Fabric::new(n);
        let mut handles = Vec::new();
        for mut ep in eps {
            let f = f.clone();
            handles.push(thread::spawn(move || f(&mut ep)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn flat_allreduce_means() {
        let out = run_spmd(4, |ep| {
            let mut data = vec![(ep.id + 1) as f32; 3];
            allreduce_mean(ep, 0, &mut data).unwrap();
            data
        });
        for o in out {
            assert_eq!(o, vec![2.5, 2.5, 2.5]); // mean(1,2,3,4)
        }
    }

    #[test]
    fn ring_allreduce_sums_all_ranks() {
        for n in [2usize, 3, 4, 5] {
            let out = run_spmd(n, move |ep| {
                // len deliberately not divisible by n
                let mut data: Vec<f32> =
                    (0..10).map(|k| (ep.id * 10 + k) as f32).collect();
                ring_allreduce(ep, 0, &mut data).unwrap();
                data
            });
            let want: Vec<f32> = (0..10)
                .map(|k| (0..n).map(|r| (r * 10 + k) as f32).sum())
                .collect();
            for o in out {
                let diff: f32 =
                    o.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
                assert!(diff < 1e-4, "n={n}: {o:?} vs {want:?}");
            }
        }
    }

    #[test]
    fn ring_n1_is_noop() {
        let (mut eps, stats) = Fabric::new(1);
        let mut data = vec![1.0, 2.0];
        ring_allreduce(&mut eps[0], 0, &mut data).unwrap();
        assert_eq!(data, vec![1.0, 2.0]);
        assert_eq!(stats.bytes(), 0);
    }

    #[test]
    fn reduce_is_rank_ordered() {
        // Use values whose f32 sum depends on order to verify the fixed
        // order (0 + 1 + 2): (a + b) + c != a + (b + c) for these.
        let vals = [1.0e8f32, -1.0e8, 3.1];
        let expect = ((vals[0] + vals[1]) + vals[2]).to_bits();
        let out = run_spmd(3, move |ep| {
            let mut data = vec![vals[ep.id]];
            reduce_to_root(ep, 0, 0, &mut data).unwrap();
            data
        });
        assert_eq!(out[0][0].to_bits(), expect);
    }

    #[test]
    fn repeated_allreduce_recycles_buffers() {
        // After warmup, further allreduce rounds should be served almost
        // entirely from the pool.
        let (eps, _) = Fabric::new(3);
        let pool = eps[0].pool().clone();
        let mut handles = Vec::new();
        for mut ep in eps {
            handles.push(thread::spawn(move || {
                let mut data = vec![ep.id as f32; 256];
                for step in 0..20u64 {
                    allreduce_mean(&mut ep, step, &mut data).unwrap();
                }
            }));
        }
        handles.into_iter().for_each(|h| h.join().unwrap());
        assert!(
            pool.recycled() > pool.allocated(),
            "pool should serve steady-state rounds: recycled {} vs allocated {}",
            pool.recycled(),
            pool.allocated()
        );
    }
}
