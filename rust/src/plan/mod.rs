//! `plan` — the profiler-driven auto-planner (ROADMAP item 2; OSDP /
//! PipeDream's profile → search → execute loop).
//!
//! A [`Plan`] is a complete, serializable training configuration: which
//! coordinator to run ([`TrainerKind`]), under which update rule and
//! communication variant, at which stage partition, bucket size and
//! precision — plus the predicted per-micro-batch step time and peak
//! per-worker memory the search scored it with.  [`search`] enumerates
//! the candidate space against a measured [`ModelProfile`] and a memory
//! budget, scoring each candidate with the measured-cost-calibrated
//! analytic model (DESIGN-PERF.md §Auto-planner); when nothing fits the
//! budget it returns the typed [`PlanError::NoFeasiblePlan`] naming the
//! cheapest infeasible candidate.
//!
//! Serialization follows the checkpoint discipline
//! ([`crate::parallel::Checkpoint`]): versioned magic, little-endian
//! fields via [`crate::util::binio`], an FNV-1a64 trailer, tmp-file +
//! rename saves, typed errors on magic/version/checksum mismatch.
//!
//! ## Wire format (version 1)
//!
//! ```text
//! magic      8   b"CDPPLAN1"
//! version    u32 (= 1)
//! model      u32 len + UTF-8
//! trainer    u32 len + UTF-8      single|multi|zero|pipeline
//! rule       u32 len + UTF-8      dp|cdp_v1|cdp_v2
//! variant    u32 len + UTF-8      none|ring|barrier|broadcast|cyclic|gpipe|1f1b
//! n_stages   u32
//! layers_per_stage u32
//! bucket_elems u64
//! precision  u32 len + UTF-8      f32|bf16
//! predicted_step_ns   u64         f64 bits (per micro-batch)
//! predicted_peak_bytes u64        per worker
//! checksum   u64                  FNV-1a64 of all preceding bytes
//! ```

pub mod search;

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use crate::memsim::{LayerProfile, MemoryCurve};
use crate::parallel::{rule_by_name, Rule};
use crate::runtime::Precision;
use crate::util::binio::{fnv1a64, ByteReader, ByteWriter};

pub use crate::profile::ModelProfile;
pub use search::{partition_balanced, search, Candidate, RankedPlans, SearchSpace};

const MAGIC: &[u8; 8] = b"CDPPLAN1";
const FORMAT_VERSION: u32 = 1;

/// Which coordinator executes the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainerKind {
    /// `coordinator::single::RefTrainer` — one host thread, N micro-batches.
    Single,
    /// `coordinator::multi` — one worker thread per micro-batch.
    Multi,
    /// `coordinator::zero` — multi with ZeRO-sharded optimizer state.
    Zero,
    /// `coordinator::pipeline` — one simulated device per stage.
    Pipeline,
}

impl TrainerKind {
    /// CLI/report name (`--trainer` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            TrainerKind::Single => "single",
            TrainerKind::Multi => "multi",
            TrainerKind::Zero => "zero",
            TrainerKind::Pipeline => "pipeline",
        }
    }

    /// Parse a CLI/serialized name.
    pub fn parse(v: &str) -> Result<Self> {
        match v {
            "single" => Ok(TrainerKind::Single),
            "multi" => Ok(TrainerKind::Multi),
            "zero" => Ok(TrainerKind::Zero),
            "pipeline" => Ok(TrainerKind::Pipeline),
            other => anyhow::bail!("unknown trainer `{other}` (single|multi|zero|pipeline)"),
        }
    }
}

/// Trainer-specific schedule variant (comm pattern / state flow /
/// pipeline schedule).  `None` for the single trainer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Single trainer: no variant dimension.
    None,
    /// Multi: cyclic ring reduction (the paper's balanced p2p pattern).
    Ring,
    /// Multi: all-to-owner barrier reduction.
    Barrier,
    /// ZeRO: owner broadcasts updated params each step.
    Broadcast,
    /// ZeRO: cyclic parameter flow (overlapped with backward).
    Cyclic,
    /// Pipeline: GPipe schedule (all forwards, then all backwards).
    GPipe,
    /// Pipeline: one-forward-one-backward (PipeDream-flavored).
    OneFOneB,
}

impl Variant {
    /// CLI/report name (matches the coordinators' own vocabularies).
    pub fn name(self) -> &'static str {
        match self {
            Variant::None => "none",
            Variant::Ring => "ring",
            Variant::Barrier => "barrier",
            Variant::Broadcast => "broadcast",
            Variant::Cyclic => "cyclic",
            Variant::GPipe => "gpipe",
            Variant::OneFOneB => "1f1b",
        }
    }

    /// Parse a CLI/serialized name.
    pub fn parse(v: &str) -> Result<Self> {
        match v {
            "none" => Ok(Variant::None),
            "ring" => Ok(Variant::Ring),
            "barrier" => Ok(Variant::Barrier),
            "broadcast" => Ok(Variant::Broadcast),
            "cyclic" => Ok(Variant::Cyclic),
            "gpipe" => Ok(Variant::GPipe),
            "1f1b" | "one_f_one_b" => Ok(Variant::OneFOneB),
            other => anyhow::bail!(
                "unknown schedule variant `{other}` \
                 (none|ring|barrier|broadcast|cyclic|gpipe|1f1b)"
            ),
        }
    }
}

/// A complete training configuration plus the scores the search gave it.
/// See the module docs for the wire format.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    /// Model label the plan was searched for (informational).
    pub model: String,
    /// Executing coordinator.
    pub trainer: TrainerKind,
    /// Update rule (DP / CDP-v1 / CDP-v2).
    pub rule: Rule,
    /// Trainer-specific schedule variant.
    pub variant: Variant,
    /// Stage partition: contiguous stage count N (= workers for multi/
    /// zero, devices for pipeline, micro-batches everywhere — the square
    /// schedule).
    pub n_stages: u32,
    /// Residual layers per stage of the partition (0 = keep the
    /// manifest's own partition).
    pub layers_per_stage: u32,
    /// Gradient bucket size, elements.
    pub bucket_elems: u64,
    /// Storage precision the backend should run at.
    pub precision: Precision,
    /// Predicted step time per micro-batch, ns (model-based).
    pub predicted_step_ns: f64,
    /// Predicted peak per-worker memory, bytes.
    pub predicted_peak_bytes: u64,
}

impl Plan {
    /// Compact one-line label (`multi/ring/cdp_v2 k4 b16384 f32`).
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{} k{} b{} {}",
            self.trainer.name(),
            self.variant.name(),
            self.rule.name(),
            self.n_stages,
            self.bucket_elems,
            self.precision.name()
        )
    }

    /// Serialize (see the wire format in the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::with_capacity(128 + self.model.len());
        w.bytes(MAGIC);
        w.u32(FORMAT_VERSION);
        w.str(&self.model);
        w.str(self.trainer.name());
        w.str(self.rule.name());
        w.str(self.variant.name());
        w.u32(self.n_stages);
        w.u32(self.layers_per_stage);
        w.u64(self.bucket_elems);
        w.str(self.precision.name());
        w.u64(self.predicted_step_ns.to_bits());
        w.u64(self.predicted_peak_bytes);
        let sum = fnv1a64(w.as_slice());
        w.u64(sum);
        w.finish()
    }

    /// Deserialize + integrity-check; magic/version/checksum mismatches
    /// and unknown enum names are typed errors.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let magic = r.bytes(8).context("plan header")?;
        anyhow::ensure!(magic == MAGIC, "not a CDP plan (bad magic {magic:02x?})");
        let version = r.u32()?;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "plan format version {version} unsupported (this build reads {FORMAT_VERSION})"
        );
        let model = r.str()?;
        let trainer = TrainerKind::parse(&r.str()?)?;
        let rule = rule_by_name(&r.str()?)?;
        let variant = Variant::parse(&r.str()?)?;
        let n_stages = r.u32()?;
        let layers_per_stage = r.u32()?;
        let bucket_elems = r.u64()?;
        let precision = Precision::parse(&r.str()?)?;
        let predicted_step_ns = f64::from_bits(r.u64()?);
        let predicted_peak_bytes = r.u64()?;
        let want_sum = fnv1a64(r.consumed());
        let got_sum = r.u64().context("plan checksum")?;
        anyhow::ensure!(
            want_sum == got_sum,
            "plan checksum mismatch (file {got_sum:#018x}, computed {want_sum:#018x}) — \
             truncated or corrupt"
        );
        anyhow::ensure!(r.remaining() == 0, "trailing bytes after plan");
        Ok(Self {
            model,
            trainer,
            rule,
            variant,
            n_stages,
            layers_per_stage,
            bucket_elems,
            precision,
            predicted_step_ns,
            predicted_peak_bytes,
        })
    }

    /// Write to a file (tmp sibling + rename, like checkpoints).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("plan.tmp");
        std::fs::write(&tmp, self.to_bytes()).with_context(|| format!("write plan {tmp:?}"))?;
        std::fs::rename(&tmp, path).with_context(|| format!("rename plan into {path:?}"))?;
        Ok(())
    }

    /// Read + validate a plan file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path).with_context(|| format!("read plan {path:?}"))?;
        Self::from_bytes(&bytes)
    }
}

/// Typed search failures.
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// Every candidate's predicted peak memory exceeds the budget.  The
    /// cheapest (lowest-memory) infeasible candidate is named so the user
    /// knows how far off the budget is.
    NoFeasiblePlan {
        /// The user-supplied budget, bytes.
        budget_bytes: u64,
        /// Label of the lowest-memory candidate that still did not fit.
        cheapest: String,
        /// That candidate's predicted peak bytes.
        cheapest_bytes: u64,
    },
    /// The search space or profile was degenerate (no candidates).
    EmptySearchSpace,
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::NoFeasiblePlan { budget_bytes, cheapest, cheapest_bytes } => write!(
                f,
                "no plan fits the {budget_bytes}-byte memory budget: cheapest candidate \
                 `{cheapest}` still needs {cheapest_bytes} bytes"
            ),
            PlanError::EmptySearchSpace => {
                write!(f, "planner search space is empty (degenerate profile?)")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Parse a human memory budget: a plain byte count, or a number with a
/// `K`/`M`/`G` (or `KiB`/`MiB`/`GiB`/`KB`/`MB`/`GB`) suffix — all binary
/// multiples of 1024.
pub fn parse_mem_budget(s: &str) -> Result<u64> {
    let t = s.trim();
    let lower = t.to_ascii_lowercase();
    let (digits, mult) = if let Some(p) = lower.strip_suffix("kib").or(lower.strip_suffix("kb")) {
        (p, 1u64 << 10)
    } else if let Some(p) = lower.strip_suffix("mib").or(lower.strip_suffix("mb")) {
        (p, 1u64 << 20)
    } else if let Some(p) = lower.strip_suffix("gib").or(lower.strip_suffix("gb")) {
        (p, 1u64 << 30)
    } else if let Some(p) = lower.strip_suffix('k') {
        (p, 1u64 << 10)
    } else if let Some(p) = lower.strip_suffix('m') {
        (p, 1u64 << 20)
    } else if let Some(p) = lower.strip_suffix('g') {
        (p, 1u64 << 30)
    } else if let Some(p) = lower.strip_suffix('b') {
        (p, 1)
    } else {
        (lower.as_str(), 1)
    };
    let n: f64 = digits
        .trim()
        .parse()
        .with_context(|| format!("invalid memory budget `{s}` (e.g. 512MiB, 2GiB, 1073741824)"))?;
    anyhow::ensure!(n > 0.0, "memory budget must be positive (got `{s}`)");
    Ok((n * mult as f64) as u64)
}

/// Peak live activation bytes of a per-layer profile, via the memsim
/// curve (forward stashes in layer order, backward releases in reverse).
/// This is how `memsim::profiles` feed the planner's budget check.
pub fn peak_act_from_layers(layers: &[LayerProfile]) -> u64 {
    MemoryCurve::from_layers(layers).peak().ceil() as u64
}

/// The planner's feasibility predicate, exposed for tests: a candidate
/// fits iff its predicted peak is within the budget.
pub fn fits_budget(peak_bytes: u64, budget_bytes: u64) -> bool {
    peak_bytes <= budget_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plan() -> Plan {
        Plan {
            model: "native_mlp".into(),
            trainer: TrainerKind::Multi,
            rule: Rule::CdpV2,
            variant: Variant::Ring,
            n_stages: 4,
            layers_per_stage: 2,
            bucket_elems: 16_384,
            precision: Precision::F32,
            predicted_step_ns: 123_456.75,
            predicted_peak_bytes: 1 << 20,
        }
    }

    #[test]
    fn round_trips_bit_exact() {
        let p = sample_plan();
        let q = Plan::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn corruption_and_truncation_are_typed_errors() {
        let p = sample_plan();
        let mut b = p.to_bytes();
        let mid = b.len() / 2;
        b[mid] ^= 0xFF;
        assert!(Plan::from_bytes(&b).is_err(), "bit flip must fail the checksum");
        let b = p.to_bytes();
        assert!(Plan::from_bytes(&b[..b.len() - 3]).is_err(), "truncation must fail");
        let mut b = p.to_bytes();
        b[0] = b'X';
        let err = Plan::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("magic"), "bad magic names itself: {err}");
    }

    #[test]
    fn mem_budget_parses_suffixes() {
        assert_eq!(parse_mem_budget("1024").unwrap(), 1024);
        assert_eq!(parse_mem_budget("4096B").unwrap(), 4096);
        assert_eq!(parse_mem_budget("512KiB").unwrap(), 512 << 10);
        assert_eq!(parse_mem_budget("512kb").unwrap(), 512 << 10);
        assert_eq!(parse_mem_budget("2MiB").unwrap(), 2 << 20);
        assert_eq!(parse_mem_budget("3G").unwrap(), 3 << 30);
        assert_eq!(parse_mem_budget("1.5m").unwrap(), (1.5 * 1048576.0) as u64);
        assert!(parse_mem_budget("chunky").is_err());
        assert!(parse_mem_budget("-5MiB").is_err());
    }

    #[test]
    fn trainer_and_variant_names_round_trip() {
        for t in [TrainerKind::Single, TrainerKind::Multi, TrainerKind::Zero, TrainerKind::Pipeline]
        {
            assert_eq!(TrainerKind::parse(t.name()).unwrap(), t);
        }
        for v in [
            Variant::None,
            Variant::Ring,
            Variant::Barrier,
            Variant::Broadcast,
            Variant::Cyclic,
            Variant::GPipe,
            Variant::OneFOneB,
        ] {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
        }
    }
}
