//! The planner's search: enumerate partition × schedule × shard × bucket
//! × precision candidates and score each with the measured-cost-calibrated
//! analytic model (DESIGN-PERF.md §Auto-planner).
//!
//! ## Cost model
//!
//! Scores are **predicted host wall time per micro-batch, ns** — the
//! planner optimizes what this repo actually runs (thread-parallel
//! simulated workers on one host), not an idealized cluster.  All inputs
//! come from a [`ModelProfile`]:
//!
//! - compute: Σ per-layer fwd+bwd ns (`layer_costs_ns`) + fused-SGD ns,
//!   scaled by the measured bf16 ratio when the candidate runs bf16;
//! - comm: bottleneck-link bytes / measured fabric bandwidth + per-message
//!   latency × bucket-message count, with the **communication-step factor
//!   taken from [`table1_rows`]** (log₂N for synchronized DP reductions,
//!   1 for cyclic) so the planner's ordering agrees with `sim::analytic`
//!   by construction;
//! - cyclic rules earn an overlap credit (gradient buckets hide behind
//!   the backward pass) that grows with the bucket count — one bucket
//!   cannot overlap, many buckets approach full overlap;
//! - thread-parallel trainers (multi, zero) divide worker wall time by
//!   `min(N, host_threads) × η`, where η is the parallel efficiency
//!   observed by the profiler's single-vs-multi calibration runs;
//!   serial trainers (single, pipeline-simulation) are scaled by the
//!   measured-vs-raw single-step calibration factor.
//!
//! Peak per-worker memory mirrors the implementations, not the paper's
//! idealized table: the arena keeps 4 parameter-sized buffers (θ, grads,
//! momentum, next-θ), ZeRO shards three of them, pipeline devices hold
//! 1/N of each plus their activation stash.  Candidates over the budget
//! are kept in the ranking (marked infeasible) so the table explains
//! *why* a cheaper-but-slower plan won.

use crate::comm::bucketed::effective_bucket_elems;
use crate::parallel::Rule;
use crate::runtime::Precision;
use crate::sim::analytic::table1_rows;

use super::{fits_budget, ModelProfile, Plan, PlanError, TrainerKind, Variant};

/// Balanced contiguous partition of `costs` into `k` segments minimizing
/// the bottleneck (max segment sum) — classic linear-partition DP,
/// O(k·n²), exact.  Returns `(ends, bottleneck)` where `ends[i]` is the
/// exclusive end index of segment `i` (`ends.len() == min(k, n)`).
pub fn partition_balanced(costs: &[f64], k: usize) -> (Vec<usize>, f64) {
    let n = costs.len();
    if n == 0 || k == 0 {
        return (Vec::new(), 0.0);
    }
    let k = k.min(n);
    let mut pre = vec![0.0f64; n + 1];
    for (i, c) in costs.iter().enumerate() {
        pre[i + 1] = pre[i] + c;
    }
    let seg = |a: usize, b: usize| pre[b] - pre[a]; // cost of [a, b)

    // dp[j][i] = minimal bottleneck splitting the first i layers into j
    // segments; cut[j][i] = start of the j-th segment in that optimum.
    let mut dp = vec![vec![f64::INFINITY; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for i in j..=n {
            for p in (j - 1)..i {
                let cand = dp[j - 1][p].max(seg(p, i));
                if cand < dp[j][i] {
                    dp[j][i] = cand;
                    cut[j][i] = p;
                }
            }
        }
    }

    let mut ends = vec![0usize; k];
    let mut i = n;
    for j in (1..=k).rev() {
        ends[j - 1] = i;
        i = cut[j][i];
    }
    (ends, dp[k][n])
}

/// The candidate dimensions the search enumerates.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Stage counts to try (must divide the layer count to be executable
    /// on a uniform residual MLP; [`SearchSpace::for_profile`] emits the
    /// divisors).
    pub stage_counts: Vec<usize>,
    /// Gradient bucket sizes, elements.
    pub bucket_elems: Vec<u64>,
    /// Storage precisions (bf16 only offered when the profile measured
    /// its step ratio).
    pub precisions: Vec<Precision>,
    /// Coordinators in play.
    pub trainers: Vec<TrainerKind>,
}

impl SearchSpace {
    /// The default space for a profile: every stage count dividing the
    /// layer count (≤ 64), two bucket sizes spanning the eager-overlap
    /// trade-off, f32 (+ bf16 iff measured), all four trainers.
    pub fn for_profile(p: &ModelProfile) -> Self {
        let l = p.layer_costs_ns.len().max(1);
        let mut stage_counts: Vec<usize> =
            (1..=l.min(64)).filter(|k| l % k == 0).collect();
        let k0 = p.n_stages();
        if k0 >= 1 && !stage_counts.contains(&k0) {
            // The profiled partition is always executable; keep it even
            // when it does not divide a refined layer count.
            stage_counts.push(k0);
            stage_counts.sort_unstable();
        }
        let precisions = if (p.bf16_step_ratio - 1.0).abs() > f64::EPSILON {
            vec![Precision::F32, Precision::Bf16]
        } else {
            vec![Precision::F32]
        };
        Self {
            stage_counts,
            bucket_elems: vec![4096, 65536],
            precisions,
            trainers: vec![
                TrainerKind::Single,
                TrainerKind::Multi,
                TrainerKind::Zero,
                TrainerKind::Pipeline,
            ],
        }
    }

    fn is_degenerate(&self) -> bool {
        self.stage_counts.is_empty()
            || self.bucket_elems.is_empty()
            || self.precisions.is_empty()
            || self.trainers.is_empty()
    }
}

/// One scored candidate: the executable [`Plan`] plus the score
/// decomposition the ranked table shows.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// The executable configuration (carries the headline predictions).
    pub plan: Plan,
    /// Whether `predicted_peak_bytes` fits the budget.
    pub feasible: bool,
    /// Predicted per-micro-batch compute ns (fwd+bwd+SGD share).
    pub compute_ns: f64,
    /// Predicted per-micro-batch effective comm ns (after overlap credit).
    pub comm_ns: f64,
    /// Bottleneck segment cost of the balanced partition at this stage
    /// count, ns (the pipeline's slowest stage).
    pub bottleneck_ns: f64,
    /// Pipeline bubble fraction ((N−1)/(m+N−1)); 0 for non-pipeline.
    pub bubble_fraction: f64,
}

/// The search result: candidates sorted feasible-first, then by predicted
/// step time, then label (deterministic).
#[derive(Clone, Debug)]
pub struct RankedPlans {
    /// Model label the search ran for.
    pub model: String,
    /// The memory budget candidates were checked against, bytes.
    pub budget_bytes: u64,
    /// All scored candidates, best first.
    pub candidates: Vec<Candidate>,
}

impl RankedPlans {
    /// The winning candidate.  [`search`] only returns a `RankedPlans`
    /// when at least one candidate is feasible, so this is it.
    pub fn winner(&self) -> &Candidate {
        &self.candidates[0]
    }

    /// Human-readable ranked table (for `--plan auto` logging).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "ranked plans for {} (budget {} B, {} candidates)\n",
            self.model,
            self.budget_bytes,
            self.candidates.len()
        ));
        out.push_str(
            "rank | plan                                 | pred us/mb | peak KiB | comm us | bubble | fits\n",
        );
        for (i, c) in self.candidates.iter().enumerate() {
            out.push_str(&format!(
                "{:4} | {:36} | {:10.1} | {:8} | {:7.1} | {:6.2} | {}\n",
                i + 1,
                c.plan.label(),
                c.plan.predicted_step_ns / 1_000.0,
                c.plan.predicted_peak_bytes / 1024,
                c.comm_ns / 1_000.0,
                c.bubble_fraction,
                if c.feasible { "yes" } else { "NO" }
            ));
        }
        out
    }

    /// The ranked table as JSON (for `cdp plan`).  Hand-rolled like the
    /// bench harness — no serde in the dependency set.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push('{');
        out.push_str(&format!("\"model\":\"{}\",", json_escape(&self.model)));
        out.push_str(&format!("\"budget_bytes\":{},", self.budget_bytes));
        out.push_str(&format!(
            "\"winner\":\"{}\",",
            json_escape(&self.winner().plan.label())
        ));
        out.push_str("\"candidates\":[");
        for (i, c) in self.candidates.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let p = &c.plan;
            out.push_str(&format!(
                "{{\"label\":\"{}\",\"trainer\":\"{}\",\"variant\":\"{}\",\"rule\":\"{}\",\
                 \"n_stages\":{},\"layers_per_stage\":{},\"bucket_elems\":{},\"precision\":\"{}\",\
                 \"predicted_step_ns\":{:.1},\"predicted_peak_bytes\":{},\"feasible\":{},\
                 \"compute_ns\":{:.1},\"comm_ns\":{:.1},\"bottleneck_ns\":{:.1},\"bubble\":{:.4}}}",
                json_escape(&p.label()),
                p.trainer.name(),
                p.variant.name(),
                p.rule.name(),
                p.n_stages,
                p.layers_per_stage,
                p.bucket_elems,
                p.precision.name(),
                p.predicted_step_ns,
                p.predicted_peak_bytes,
                c.feasible,
                c.compute_ns,
                c.comm_ns,
                c.bottleneck_ns,
                c.bubble_fraction,
            ));
        }
        out.push_str("]}");
        out
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Calibrated constants derived once per search from the profile.
struct Ctx<'a> {
    p: &'a ModelProfile,
    /// f32 per-micro-batch fwd+bwd chain, ns (Σ layer costs).
    chain_ns: f64,
    /// f32 per-micro-batch backward total, ns (the overlap window).
    bwd_ns: f64,
    /// f32 full-model fused-SGD sweep, ns.
    sgd_ns: f64,
    /// Mean stage-boundary activation bytes.
    bnd: f64,
    /// Activation stash floor excluding boundary stashes (the input
    /// micro-batch itself).
    act_base: f64,
    /// Ψ_P, bytes.
    psi: f64,
    /// Fabric bandwidth, bytes/ns (0.0 = unprobed ⇒ byte time omitted).
    bw: f64,
    /// Fabric per-hop latency, ns.
    lat: f64,
    /// Host hardware threads.
    threads: f64,
    /// Observed parallel efficiency of the thread-parallel trainers.
    eta: f64,
    /// Measured-vs-raw calibration for host-serial trainers.
    c_serial: f64,
}

impl<'a> Ctx<'a> {
    fn new(p: &'a ModelProfile) -> Self {
        let layer_sum: f64 = p.layer_costs_ns.iter().sum();
        let chain_ns = if layer_sum > 0.0 { layer_sum } else { p.chain_ns() };
        let sgd_ns = p.sgd_total_ns();
        let bnd = p.mean_boundary_bytes() as f64;
        let k0 = p.n_stages().max(1) as f64;
        let act_base =
            (p.peak_act_bytes as f64 - (k0 - 1.0) * bnd).max(bnd.max(1.0));
        let threads = p.host_threads.max(1) as f64;

        // Parallel efficiency η: observed single/multi speedup over the
        // ideal min(N, threads) at the profiled stage count.
        let eta = if p.single_step_ns > 0.0 && p.multi_step_ns > 0.0 {
            let sigma = p.single_step_ns / p.multi_step_ns;
            let ideal = k0.min(threads).max(1.0);
            (sigma / ideal).clamp(0.05, 1.25)
        } else {
            0.7
        };

        // Serial calibration: measured single-trainer step over the raw
        // model's prediction for the profiled partition.
        let m0 = p.n_microbatches.max(1) as f64;
        let raw_single = m0 * chain_ns + sgd_ns;
        let c_serial = if p.single_step_ns > 0.0 && raw_single > 0.0 {
            (p.single_step_ns / raw_single).clamp(0.2, 5.0)
        } else {
            1.0
        };

        Self {
            p,
            chain_ns,
            bwd_ns: p.bwd_total_ns(),
            sgd_ns,
            bnd,
            act_base,
            psi: p.psi_p_bytes as f64,
            bw: p.bw_bytes_per_ns,
            lat: p.hop_latency_ns,
            threads,
            eta,
            c_serial,
        }
    }

    fn prec_factor(&self, prec: Precision) -> f64 {
        match prec {
            Precision::F32 => 1.0,
            Precision::Bf16 => self.p.bf16_step_ratio,
        }
    }

    /// Predicted peak live activation bytes at stage count k (input stash
    /// plus one boundary stash per cut).
    fn act_bytes(&self, k: usize) -> f64 {
        self.act_base + (k.saturating_sub(1)) as f64 * self.bnd
    }

    /// Gradient bucket messages one worker emits per step at stage count
    /// k and the requested bucket size.
    fn total_buckets(&self, k: usize, bucket_elems: u64) -> f64 {
        let stage_elems = ((self.psi / 4.0) / k as f64).ceil().max(1.0) as usize;
        let be = effective_bucket_elems(bucket_elems as usize, stage_elems).max(1);
        (k * stage_elems.div_ceil(be)) as f64
    }

    fn bytes_ns(&self, bytes: f64) -> f64 {
        if self.bw > 0.0 {
            bytes / self.bw
        } else {
            0.0
        }
    }
}

/// Comm-step factor from Table 1 — the calibration hook that makes the
/// planner's ordering agree with `sim::analytic` by construction.
fn table1_steps(k: usize, implementation: &str) -> f64 {
    table1_rows(k)
        .iter()
        .find(|r| r.implementation == implementation)
        .map(|r| r.max_comm_steps)
        .unwrap_or(1.0)
        .max(0.0)
}

struct Score {
    per_mb_ns: f64,
    peak_bytes: f64,
    compute_ns: f64,
    comm_ns: f64,
    bubble: f64,
}

/// Score one candidate.  See the module docs for the model.
fn score(
    ctx: &Ctx<'_>,
    trainer: TrainerKind,
    variant: Variant,
    rule: &Rule,
    k: usize,
    bucket_elems: u64,
    prec: Precision,
) -> Score {
    let n = k as f64;
    let f = ctx.prec_factor(prec);
    let cyclic = !matches!(rule, Rule::Dp);
    let act = ctx.act_bytes(k);

    match trainer {
        TrainerKind::Single => {
            // One host thread runs N micro-batches then one SGD sweep.
            let m = n.max(1.0);
            let per_mb = ctx.c_serial * f * (ctx.chain_ns + ctx.sgd_ns / m);
            Score {
                per_mb_ns: per_mb,
                peak_bytes: 4.0 * ctx.psi + act,
                compute_ns: per_mb,
                comm_ns: 0.0,
                bubble: 0.0,
            }
        }
        TrainerKind::Multi | TrainerKind::Zero => {
            let zero = trainer == TrainerKind::Zero;
            // Per-worker compute: one chain plus this worker's SGD share.
            // The barrier variant funnels the full update through the
            // owner — the bottleneck worker pays the whole sweep.
            let sgd_share = if variant == Variant::Barrier {
                ctx.sgd_ns
            } else {
                ctx.sgd_ns / n
            };
            let compute = f * (ctx.chain_ns + sgd_share);

            // Bottleneck-link bytes: ring/cyclic spread 2(N−1)/N·Ψ per
            // link; the barrier owner serializes 2(N−1)·Ψ.
            let bytes = if variant == Variant::Barrier {
                2.0 * (n - 1.0) * ctx.psi
            } else {
                2.0 * (n - 1.0) / n * ctx.psi
            };
            let steps_row = match (zero, cyclic) {
                (false, false) => "Multi-GPU DP",
                (false, true) => "Multi-GPU + Cyclic",
                (true, false) => "ZeRO-DP",
                (true, true) => "ZeRO-DP + Cyclic",
            };
            let steps = table1_steps(k, steps_row);
            let buckets = ctx.total_buckets(k, bucket_elems);
            let comm_raw = ctx.bytes_ns(bytes) + steps * buckets * ctx.lat;

            // Overlap credit: cyclic rules hide bucketed reduction behind
            // the backward pass; one bucket cannot overlap at all.
            let comm_eff = if cyclic && buckets >= 2.0 {
                let credit = f * ctx.bwd_ns * (1.0 - 1.0 / buckets);
                (comm_raw - credit).max(0.15 * comm_raw)
            } else {
                comm_raw
            };

            let wall_worker = compute + comm_eff;
            let per_mb = wall_worker / (n.min(ctx.threads) * ctx.eta);
            let peak = if zero {
                // Full gathered params + this worker's 3 sharded states.
                ctx.psi + 3.0 * ctx.psi / n + act
            } else {
                // Full replica: θ, grads, momentum, next-θ.
                4.0 * ctx.psi + act
            };
            Score {
                per_mb_ns: per_mb,
                peak_bytes: peak,
                compute_ns: compute / (n.min(ctx.threads) * ctx.eta),
                comm_ns: comm_eff / (n.min(ctx.threads) * ctx.eta),
                bubble: 0.0,
            }
        }
        TrainerKind::Pipeline => {
            // The pipeline coordinator simulates its devices on one host
            // thread: host wall = all device work, no parallel speedup.
            // The bubble is recorded for the table but not charged —
            // idle simulated devices cost no host time.
            let m = n; // square schedule: m micro-batches = N devices
            let compute = ctx.c_serial * f * (ctx.chain_ns + ctx.sgd_ns / m);
            let hops = 2.0 * (n - 1.0); // fwd act + bwd grad-act per mb
            let comm = ctx.bytes_ns(hops * ctx.bnd) + hops * ctx.lat;
            let bubble = if n > 1.0 { (n - 1.0) / (m + n - 1.0) } else { 0.0 };
            // Per-device: 1/N of the 4 arena buffers, one extra θ version
            // per device for cyclic rules, plus the activation stash
            // (GPipe keeps all m in flight, 1F1B caps at (N+1)/2).
            let versions = if cyclic { 1.0 } else { 0.0 };
            let stash_factor = if variant == Variant::OneFOneB {
                (n + 1.0) / 2.0 / n
            } else {
                m / n
            };
            let peak = (4.0 + versions) * ctx.psi / n + stash_factor * act;
            Score {
                per_mb_ns: compute + comm,
                peak_bytes: peak,
                compute_ns: compute,
                comm_ns: comm,
                bubble,
            }
        }
    }
}

/// Run the search: enumerate the space, score each candidate against the
/// profile, rank.  Errors: [`PlanError::EmptySearchSpace`] when the space
/// or profile is degenerate, [`PlanError::NoFeasiblePlan`] (naming the
/// cheapest infeasible candidate) when nothing fits `budget_bytes`.
pub fn search(
    p: &ModelProfile,
    budget_bytes: u64,
    space: &SearchSpace,
) -> Result<RankedPlans, PlanError> {
    if space.is_degenerate() || p.layer_costs_ns.is_empty() || p.n_stages() == 0 {
        return Err(PlanError::EmptySearchSpace);
    }
    let ctx = Ctx::new(p);
    let l = p.layer_costs_ns.len();
    let mut cands: Vec<Candidate> = Vec::new();

    for &k in &space.stage_counts {
        if k == 0 || k > l {
            continue;
        }
        let (_, bottleneck) = partition_balanced(&p.layer_costs_ns, k);
        let lps = if l % k == 0 { (l / k) as u32 } else { 0 };
        for &prec in &space.precisions {
            for &trainer in &space.trainers {
                // (variant, rule, bucket-sensitive) combos per trainer.
                let combos: Vec<(Variant, Rule, bool)> = match trainer {
                    TrainerKind::Single => vec![
                        (Variant::None, Rule::Dp, false),
                        (Variant::None, Rule::CdpV2, false),
                    ],
                    TrainerKind::Multi if k >= 2 => vec![
                        (Variant::Barrier, Rule::Dp, true),
                        (Variant::Ring, Rule::CdpV1, true),
                        (Variant::Ring, Rule::CdpV2, true),
                    ],
                    TrainerKind::Zero if k >= 2 => vec![
                        (Variant::Broadcast, Rule::Dp, true),
                        (Variant::Cyclic, Rule::CdpV2, true),
                    ],
                    TrainerKind::Pipeline if k >= 2 => vec![
                        (Variant::GPipe, Rule::Dp, false),
                        (Variant::OneFOneB, Rule::CdpV1, false),
                    ],
                    _ => Vec::new(),
                };
                for (variant, rule, bucketed) in combos {
                    let buckets: &[u64] = if bucketed {
                        &space.bucket_elems
                    } else {
                        &space.bucket_elems[..1]
                    };
                    for &b in buckets {
                        let s = score(&ctx, trainer, variant, &rule, k, b, prec);
                        let plan = Plan {
                            model: p.model.clone(),
                            trainer,
                            rule: rule.clone(),
                            variant,
                            n_stages: k as u32,
                            layers_per_stage: lps,
                            bucket_elems: b,
                            precision: prec,
                            predicted_step_ns: s.per_mb_ns,
                            predicted_peak_bytes: s.peak_bytes.ceil() as u64,
                        };
                        cands.push(Candidate {
                            feasible: fits_budget(plan.predicted_peak_bytes, budget_bytes),
                            plan,
                            compute_ns: s.compute_ns,
                            comm_ns: s.comm_ns,
                            bottleneck_ns: bottleneck,
                            bubble_fraction: s.bubble,
                        });
                    }
                }
            }
        }
    }

    if cands.is_empty() {
        return Err(PlanError::EmptySearchSpace);
    }
    cands.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then(a.plan.predicted_step_ns.total_cmp(&b.plan.predicted_step_ns))
            .then_with(|| a.plan.label().cmp(&b.plan.label()))
    });
    if !cands[0].feasible {
        let cheapest = cands
            .iter()
            .min_by_key(|c| c.plan.predicted_peak_bytes)
            .expect("non-empty");
        return Err(PlanError::NoFeasiblePlan {
            budget_bytes,
            cheapest: cheapest.plan.label(),
            cheapest_bytes: cheapest.plan.predicted_peak_bytes,
        });
    }
    Ok(RankedPlans { model: p.model.clone(), budget_bytes, candidates: cands })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::StageProfile;

    /// A hand-built profile with explicit compute/comm weights.
    fn synth(
        k0: usize,
        layers: usize,
        layer_ns: f64,
        sgd_ns: f64,
        bnd: u64,
        psi: u64,
        bw: f64,
        lat: f64,
    ) -> ModelProfile {
        assert_eq!(layers % k0, 0);
        let lps = layers / k0;
        let stages: Vec<StageProfile> = (0..k0)
            .map(|j| StageProfile {
                stage: j,
                fwd_ns: 0.4 * layer_ns * lps as f64,
                bwd_ns: 0.6 * layer_ns * lps as f64,
                sgd_ns: sgd_ns / k0 as f64,
                boundary_bytes: if j + 1 < k0 { bnd } else { 0 },
                param_bytes: psi / k0 as u64,
                grad_buckets: 1,
                grad_bucket_bytes: psi / k0 as u64,
                act_bytes: bnd,
            })
            .collect();
        ModelProfile {
            model: "synthetic".into(),
            stages,
            microbatch: 8,
            n_microbatches: k0,
            psi_p_bytes: psi,
            peak_act_bytes: bnd * k0 as u64,
            layer_costs_ns: vec![layer_ns; layers],
            bw_bytes_per_ns: bw,
            hop_latency_ns: lat,
            bf16_step_ratio: 1.0,
            single_step_ns: 0.0,
            multi_step_ns: 0.0,
            host_threads: 8,
            calib_steps: 2,
            alloc_per_step: 0,
        }
    }

    #[test]
    fn partition_covers_and_balances() {
        let (ends, b) = partition_balanced(&[3.0, 1.0, 1.0, 1.0], 2);
        assert_eq!(ends, vec![1, 4]);
        assert_eq!(b, 3.0);
        let (ends, b) = partition_balanced(&[1.0, 1.0, 1.0, 1.0], 2);
        assert_eq!(*ends.last().unwrap(), 4);
        assert_eq!(b, 2.0);
        // k >= n: every layer its own segment.
        let (ends, b) = partition_balanced(&[2.0, 5.0], 7);
        assert_eq!(ends, vec![1, 2]);
        assert_eq!(b, 5.0);
        // Degenerate inputs.
        assert_eq!(partition_balanced(&[], 3).0.len(), 0);
        assert_eq!(partition_balanced(&[1.0], 0).0.len(), 0);
    }

    #[test]
    fn partition_matches_brute_force_on_small_cases() {
        crate::testing::check("partition-optimal", 40, |g| {
            let n = g.usize_in(2, 7);
            let k = g.usize_in(1, 3.min(n));
            let costs: Vec<f64> =
                (0..n).map(|_| g.f32_in(0.5, 10.0) as f64).collect();
            let (ends, got) = partition_balanced(&costs, k);
            assert_eq!(ends.len(), k);
            assert_eq!(*ends.last().unwrap(), n);
            for w in ends.windows(2) {
                assert!(w[0] < w[1], "segments must be non-empty and ordered");
            }
            // Brute force: enumerate all cut positions.
            let mut best = f64::INFINITY;
            let cuts = k - 1;
            let mut idx = vec![0usize; cuts];
            fn rec(
                costs: &[f64],
                cuts: usize,
                start: usize,
                idx: &mut Vec<usize>,
                d: usize,
                best: &mut f64,
            ) {
                if d == cuts {
                    let mut prev = 0usize;
                    let mut bott = 0.0f64;
                    for &c in idx.iter() {
                        let s: f64 = costs[prev..c].iter().sum();
                        bott = bott.max(s);
                        prev = c;
                    }
                    let s: f64 = costs[prev..].iter().sum();
                    bott = bott.max(s);
                    *best = best.min(bott);
                    return;
                }
                for c in start..costs.len() - (cuts - d - 1) {
                    idx[d] = c;
                    rec(costs, cuts, c + 1, idx, d + 1, best);
                }
            }
            rec(&costs, cuts, 1, &mut idx, 0, &mut best);
            assert!(
                (got - best).abs() < 1e-9 * best.max(1.0),
                "dp {got} vs brute {best} for {costs:?} k={k}"
            );
        });
    }

    #[test]
    fn ring_cyclic_beats_barrier_dp_when_comm_dominates() {
        // Huge gradients over a slow, laggy fabric; trivial compute.
        let p = synth(4, 8, 1_000.0, 1_000.0, 1 << 10, 64 << 20, 0.05, 5_000.0);
        let space = SearchSpace::for_profile(&p);
        let ranked = search(&p, u64::MAX, &space).unwrap();
        let find = |t: TrainerKind, v: Variant, r: &str| {
            ranked
                .candidates
                .iter()
                .find(|c| {
                    c.plan.trainer == t
                        && c.plan.variant == v
                        && c.plan.rule.name() == r
                        && c.plan.n_stages == 4
                        && c.plan.bucket_elems == space.bucket_elems[0]
                        && c.plan.precision == Precision::F32
                })
                .unwrap()
        };
        let ring = find(TrainerKind::Multi, Variant::Ring, "cdp_v2");
        let barrier = find(TrainerKind::Multi, Variant::Barrier, "dp");
        assert!(
            ring.plan.predicted_step_ns < barrier.plan.predicted_step_ns,
            "cyclic ring {} must beat barrier dp {}",
            ring.plan.predicted_step_ns,
            barrier.plan.predicted_step_ns
        );
        // Same ordering for ZeRO: cyclic flow beats broadcast.
        let zc = find(TrainerKind::Zero, Variant::Cyclic, "cdp_v2");
        let zb = find(TrainerKind::Zero, Variant::Broadcast, "dp");
        assert!(zc.plan.predicted_step_ns < zb.plan.predicted_step_ns);
    }

    #[test]
    fn zero_shards_optimizer_state() {
        let p = synth(4, 8, 1_000.0, 400.0, 1 << 10, 8 << 20, 10.0, 100.0);
        let ranked = search(&p, u64::MAX, &SearchSpace::for_profile(&p)).unwrap();
        let peak = |t: TrainerKind| {
            ranked
                .candidates
                .iter()
                .filter(|c| c.plan.trainer == t && c.plan.n_stages == 4)
                .map(|c| c.plan.predicted_peak_bytes)
                .min()
                .unwrap()
        };
        assert!(
            peak(TrainerKind::Zero) < peak(TrainerKind::Multi),
            "ZeRO must shard optimizer state below the full replica"
        );
        assert!(
            peak(TrainerKind::Pipeline) < peak(TrainerKind::Multi),
            "pipeline devices hold 1/N of the arena"
        );
    }

    #[test]
    fn over_budget_is_a_typed_error_naming_the_cheapest() {
        let p = synth(2, 4, 1_000.0, 400.0, 1 << 10, 1 << 20, 10.0, 100.0);
        match search(&p, 1, &SearchSpace::for_profile(&p)) {
            Err(PlanError::NoFeasiblePlan { budget_bytes, cheapest, cheapest_bytes }) => {
                assert_eq!(budget_bytes, 1);
                assert!(!cheapest.is_empty());
                assert!(cheapest_bytes > 1);
            }
            other => panic!("expected NoFeasiblePlan, got {other:?}"),
        }
    }

    #[test]
    fn budget_excludes_full_replicas_but_keeps_sharded() {
        // Budget sized between the sharded and replicated footprints at
        // k=4: ZeRO/pipeline fit, single/multi (4Ψ) do not.
        let psi: u64 = 8 << 20;
        let p = synth(4, 8, 1_000.0, 400.0, 1 << 10, psi, 10.0, 100.0);
        let budget = 3 * psi; // < 4Ψ, > Ψ(1+3/4)+act and > 5Ψ/4+stash
        let ranked = search(&p, budget, &SearchSpace::for_profile(&p)).unwrap();
        let w = ranked.winner();
        assert!(w.feasible);
        assert!(
            matches!(w.plan.trainer, TrainerKind::Zero | TrainerKind::Pipeline),
            "winner {} must be a sharded trainer under a 3Ψ budget",
            w.plan.label()
        );
        // Infeasible candidates stay in the table, marked.
        assert!(ranked.candidates.iter().any(|c| !c.feasible));
    }

    #[test]
    fn ranked_output_is_renderable_and_json() {
        let p = synth(2, 4, 1_000.0, 400.0, 1 << 10, 1 << 20, 10.0, 100.0);
        let ranked = search(&p, u64::MAX, &SearchSpace::for_profile(&p)).unwrap();
        let table = ranked.render();
        assert!(table.contains(&ranked.winner().plan.label()));
        let json = ranked.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"winner\":"));
        assert!(json.contains("\"candidates\":["));
        // Deterministic: same inputs, same ranking.
        let again = search(&p, u64::MAX, &SearchSpace::for_profile(&p)).unwrap();
        let labels: Vec<String> =
            ranked.candidates.iter().map(|c| c.plan.label()).collect();
        let labels2: Vec<String> =
            again.candidates.iter().map(|c| c.plan.label()).collect();
        assert_eq!(labels, labels2);
    }
}
