//! Single-process reference trainer.
//!
//! Executes the update rules (paper Eq. DP / CDP-v1 / CDP-v2) exactly, in
//! the canonical order: for training step t, micro-batches i = 1..N each
//! run fwd through stages 1..N at their θ̂ versions, then bwd N..1; the
//! gradients accumulate in micro-batch order; one averaged SGD-momentum
//! update per stage commits the step.  This is both the numeric oracle for
//! the threaded trainers and the paper's "Single-GPU" setting (§4.1): the
//! activation-memory difference between DP and CDP on one device is
//! measured by `memsim` over the same schedule this trainer realizes.
//!
//! Hot-path layout (DESIGN-PERF.md): parameters, momentum and gradient
//! sums live in flat arenas; each micro-batch's backward writes into one
//! persistent model-wide scratch run that the grad buffer accumulates
//! from.  After warm-up a training step performs no host-side allocation
//! for parameter or gradient state.

use anyhow::Result;

use super::{version_id, ExecMode, StepLog};
use crate::data::{DataSource, MicroBatch};
use crate::metrics::Metrics;
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{GradBuffer, ParamStore, Rule};
use crate::runtime::{Act, BundleRuntime, Executor};
use crate::tensor::{HostTensor, Tensor};

pub struct RefTrainer<'rt> {
    pub rt: &'rt BundleRuntime,
    pub store: ParamStore,
    pub data: DataSource,
    pub rule: Rule,
    pub lr: f32,
    pub metrics: Metrics,
    grads: GradBuffer,
    /// Per-micro-batch gradient scratch (model-wide flat run, reused).
    gmb: Vec<f32>,
    /// Execution boundary.  Defaults to [`ExecMode::HostLiteral`]: this
    /// trainer *is* the reference oracle, and the host/literal path is
    /// the reference semantics.  [`Self::new_with_mode`] opts into the
    /// device-resident path, which the equivalence tests hold
    /// bit-identical to the oracle.
    exec: Executor,
}

impl<'rt> RefTrainer<'rt> {
    pub fn new(rt: &'rt BundleRuntime, rule: Rule) -> Result<Self> {
        Self::new_with_mode(rt, rule, ExecMode::HostLiteral)
    }

    pub fn new_with_mode(
        rt: &'rt BundleRuntime,
        rule: Rule,
        mode: ExecMode,
    ) -> Result<Self> {
        let layout = ArenaLayout::from_manifest(&rt.manifest);
        let flat = rt.init_params_flat()?;
        let store = ParamStore::from_flat(layout.clone(), flat);
        Ok(Self::assemble(rt, rule, store, mode))
    }

    /// With explicit initial params (equivalence tests inject these).
    pub fn with_params(
        rt: &'rt BundleRuntime,
        rule: Rule,
        init: Vec<Vec<Tensor>>,
    ) -> Self {
        Self::assemble(rt, rule, ParamStore::new(init), ExecMode::HostLiteral)
    }

    fn assemble(
        rt: &'rt BundleRuntime,
        rule: Rule,
        store: ParamStore,
        mode: ExecMode,
    ) -> Self {
        let n_mb = rt.manifest.n_microbatches;
        let layout = store.layout().clone();
        Self {
            rt,
            store,
            data: DataSource::from_manifest(&rt.manifest),
            rule,
            lr: rt.manifest.lr,
            metrics: Metrics::new(),
            grads: GradBuffer::new(layout.clone(), n_mb),
            gmb: layout.zeros(),
            exec: Executor::new(mode, rt.manifest.n_stages),
        }
    }

    pub fn mode(&self) -> ExecMode {
        self.exec.mode()
    }

    /// Stage-level parameter uploads performed by the device store
    /// (`None` on the host path) — the bench's ≤1-per-θ-version metric.
    pub fn device_param_uploads(&self) -> Option<u64> {
        self.exec.device_store().map(|s| s.param_uploads())
    }

    /// One micro-batch's fwd+bwd at the rule-selected parameter versions,
    /// gradients written into `gmb` (model-wide flat run).  `lits[stage]`
    /// are the pre-uploaded literals for *this* micro-batch's θ̂ versions
    /// (DESIGN.md §Perf-L3: parameters are uploaded once per
    /// (stage, version) per training step, not once per micro-batch).
    fn run_microbatch(
        &self,
        t: u64,
        i: usize,
        lits: &[&Vec<xla::Literal>],
        gmb: &mut [f32],
    ) -> Result<f32> {
        let n = self.rt.manifest.n_stages;
        let layout = self.store.layout();
        let mb = self.data.microbatch(t, (i - 1) as u64);
        let (x0, targets): (HostTensor, _) = match &mb {
            MicroBatch::Lm { tokens, targets } => {
                (HostTensor::I32(tokens.clone()), targets.clone())
            }
            MicroBatch::Class { x, labels } => {
                (HostTensor::F32(x.clone()), labels.clone())
            }
        };

        // forward chain, stashing stage inputs (the remat unit)
        let mut inputs: Vec<HostTensor> = vec![x0];
        for j in 0..n - 1 {
            let y = self.rt.stage_fwd_lits(j, lits[j], &inputs[j])?;
            inputs.push(HostTensor::F32(y));
        }

        // backward chain, straight into the arena scratch
        let last = n - 1;
        let x_last = inputs[last].as_f32().expect("loss stage input is f32");
        let (loss, mut gx) = self.rt.last_bwd_lits_into(
            lits[last],
            x_last,
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        for j in (1..last).rev() {
            let x = inputs[j].as_f32().unwrap();
            gx = self.rt.mid_bwd_lits_into(
                j,
                lits[j],
                x,
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
        }
        if n > 1 {
            self.rt.first_bwd_lits_into(
                lits[0],
                &inputs[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
        }
        Ok(loss)
    }

    /// Run one full training step (N micro-batches + update).
    pub fn step(&mut self) -> Result<StepLog> {
        match self.exec.mode() {
            ExecMode::HostLiteral => self.step_host(),
            ExecMode::DeviceResident => self.step_device(),
        }
    }

    /// One micro-batch on the device path: resident parameter buffers,
    /// device-side activation stash, grads into `gmb`.
    fn run_microbatch_dev(&mut self, t: u64, i: usize, gmb: &mut [f32]) -> Result<f32> {
        let n = self.rt.manifest.n_stages;
        let rt = self.rt;
        let layout = self.store.layout().clone();
        let mb = self.data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match mb {
            MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
            MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
        };

        // forward chain; the stash holds device activations
        let mut acts: Vec<Act> = Vec::with_capacity(n);
        acts.push(self.exec.input(rt, x0)?);
        for j in 0..n - 1 {
            let ver = version_id(&self.rule, self.store.step(), i, j, n);
            let flat = self.store.select(&self.rule, i, j);
            let y = self.exec.fwd(rt, j, ver, flat, &acts[j])?;
            acts.push(y);
        }

        // backward chain, grads straight into the arena scratch
        let last = n - 1;
        let ver = version_id(&self.rule, self.store.step(), i, last, n);
        let flat = self.store.select(&self.rule, i, last);
        let (loss, mut gx) = self.exec.last_bwd(
            rt,
            ver,
            flat,
            &acts[last],
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        for j in (1..last).rev() {
            let ver = version_id(&self.rule, self.store.step(), i, j, n);
            let flat = self.store.select(&self.rule, i, j);
            gx = self.exec.mid_bwd(
                rt,
                j,
                ver,
                flat,
                &acts[j],
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
        }
        if n > 1 {
            let ver = version_id(&self.rule, self.store.step(), i, 0, n);
            let flat = self.store.select(&self.rule, i, 0);
            self.exec.first_bwd(
                rt,
                ver,
                flat,
                &acts[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
        }
        Ok(loss)
    }

    /// Device-resident training step: identical schedule and numerics to
    /// [`Self::step_host`] (the loss sequence is bit-identical — tested),
    /// but parameters upload once per (stage, θ-version) instead of the
    /// per-step literal rebuilds.
    fn step_device(&mut self) -> Result<StepLog> {
        let n = self.rt.manifest.n_stages;
        let n_mb = self.rt.manifest.n_microbatches;
        let t = self.store.step();
        let lr = self.lr;

        let mut loss_sum = 0f64;
        let mut gmb = std::mem::take(&mut self.gmb);
        for i in 1..=n_mb {
            let loss = match self.run_microbatch_dev(t, i, &mut gmb) {
                Ok(l) => l,
                Err(e) => {
                    self.gmb = gmb; // restore scratch before bailing
                    return Err(e);
                }
            };
            loss_sum += loss as f64;
            self.grads.add_all_flat(i, &gmb);
        }
        self.gmb = gmb;
        self.grads.average();

        // fused device SGD per stage; the result installs as the
        // resident θ_{t+1} and mirrors into the store's next slot
        for j in 0..n {
            let rt = self.rt;
            let g = self.grads.stage(j);
            let (cur, moms, next) = self.store.update_parts(j);
            self.exec.sgd(rt, j, t, cur, moms, g, lr, next)?;
        }
        self.grads.reset();
        self.store.commit_step();

        let loss = loss_sum / n_mb as f64;
        self.metrics.record("loss", t as f64, loss);
        Ok(StepLog { step: t, loss })
    }

    /// Host/literal training step — the reference-oracle path.
    fn step_host(&mut self) -> Result<StepLog> {
        let n = self.rt.manifest.n_stages;
        let n_mb = self.rt.manifest.n_microbatches;
        let t = self.store.step();

        // Upload each needed (stage, version) exactly once for this step.
        let mut fresh_lits: Vec<Option<Vec<xla::Literal>>> = (0..n).map(|_| None).collect();
        let mut stale_lits: Vec<Option<Vec<xla::Literal>>> = (0..n).map(|_| None).collect();
        for i in 1..=n_mb {
            for j in 0..n {
                use crate::parallel::update_rule::Version;
                match self.rule.version(i, j + 1, n) {
                    Version::Fresh if fresh_lits[j].is_none() => {
                        fresh_lits[j] =
                            Some(self.rt.param_literals_flat(j, self.store.fresh(j))?);
                    }
                    Version::Stale if stale_lits[j].is_none() => {
                        stale_lits[j] =
                            Some(self.rt.param_literals_flat(j, self.store.stale(j))?);
                    }
                    _ => {}
                }
            }
        }

        // CDP_NO_LITCACHE=1 disables the cache (per-micro-batch re-upload),
        // used by the §Perf A/B measurement in EXPERIMENTS.md.
        let no_cache = std::env::var_os("CDP_NO_LITCACHE").is_some();
        let mut loss_sum = 0f64;
        let mut gmb = std::mem::take(&mut self.gmb);
        for i in 1..=n_mb {
            use crate::parallel::update_rule::Version;
            let rebuilt: Vec<Vec<xla::Literal>>;
            let lits: Vec<&Vec<xla::Literal>> = if no_cache {
                rebuilt = (0..n)
                    .map(|j| {
                        let p = match self.rule.version(i, j + 1, n) {
                            Version::Fresh => self.store.fresh(j),
                            Version::Stale => self.store.stale(j),
                        };
                        self.rt.param_literals_flat(j, p)
                    })
                    .collect::<Result<_>>()?;
                rebuilt.iter().collect()
            } else {
                (0..n)
                    .map(|j| match self.rule.version(i, j + 1, n) {
                        Version::Fresh => fresh_lits[j].as_ref().unwrap(),
                        Version::Stale => stale_lits[j].as_ref().unwrap(),
                    })
                    .collect()
            };
            let loss = match self.run_microbatch(t, i, &lits, &mut gmb) {
                Ok(l) => l,
                Err(e) => {
                    self.gmb = gmb; // restore scratch before bailing
                    return Err(e);
                }
            };
            loss_sum += loss as f64;
            self.grads.add_all_flat(i, &gmb);
        }
        self.gmb = gmb;
        self.grads.average();

        // SGD per stage: θ_t (cur) → θ_{t+1} (next slot), then rotate.
        for j in 0..n {
            let rt = self.rt;
            let lr = self.lr;
            let g = self.grads.stage(j);
            let (cur, moms, next) = self.store.update_parts(j);
            rt.sgd_update_flat(j, cur, moms, g, lr, next)?;
        }
        self.grads.reset();
        self.store.commit_step();

        let loss = loss_sum / n_mb as f64;
        self.metrics.record("loss", t as f64, loss);
        Ok(StepLog { step: t, loss })
    }

    pub fn train(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        (0..steps).map(|_| self.step()).collect()
    }

    /// Classification accuracy on the held-out split (eval micro-batches).
    pub fn accuracy(&self, n_batches: u64) -> Result<f64> {
        let n = self.rt.manifest.n_stages;
        let mut correct = 0usize;
        let mut total = 0usize;
        for k in 0..n_batches {
            let mb = self.data.eval_microbatch(k);
            let MicroBatch::Class { x, labels } = mb else {
                anyhow::bail!("accuracy() needs a classification bundle")
            };
            let mut a = HostTensor::F32(x);
            for j in 0..n - 1 {
                let y = self.rt.stage_fwd_flat(j, self.store.fresh(j), &a)?;
                a = HostTensor::F32(y);
            }
            let logits =
                self.rt.predict_flat(self.store.fresh(n - 1), a.as_f32().unwrap())?;
            let classes = logits.shape[1];
            for (b, lbl) in labels.data.iter().enumerate() {
                let row = &logits.data[b * classes..(b + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                if pred as i32 == *lbl {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Evaluation loss on held-out LM data (fwd only, fresh params).
    pub fn eval_loss(&self, n_batches: u64) -> Result<f64> {
        let n = self.rt.manifest.n_stages;
        let mut sum = 0f64;
        for k in 0..n_batches {
            let mb = self.data.eval_microbatch(k);
            let MicroBatch::Lm { tokens, targets } = mb else {
                anyhow::bail!("eval_loss() needs an LM bundle")
            };
            let mut a = HostTensor::I32(tokens);
            for j in 0..n - 1 {
                let y = self.rt.stage_fwd_flat(j, self.store.fresh(j), &a)?;
                a = HostTensor::F32(y);
            }
            let loss = self.rt.last_fwd_loss_flat(
                self.store.fresh(n - 1),
                a.as_f32().unwrap(),
                &targets,
            )?;
            sum += loss as f64;
        }
        Ok(sum / n_batches as f64)
    }
}
