//! Single-process reference trainer.
//!
//! Executes the update rules (paper Eq. DP / CDP-v1 / CDP-v2) exactly, in
//! the canonical order: for training step t, micro-batches i = 1..N each
//! run fwd through stages 1..N at their θ̂ versions, then bwd N..1; the
//! gradients accumulate in micro-batch order; one averaged SGD-momentum
//! update per stage commits the step.  This is both the numeric oracle for
//! the threaded trainers and the paper's "Single-GPU" setting (§4.1): the
//! activation-memory difference between DP and CDP on one device is
//! measured by `memsim` over the same schedule this trainer realizes.
//!
//! Generic over [`Backend`]: the same schedule drives the pure-Rust
//! native kernels or the XLA bundle, through the one executor surface.
//! Per-(stage, θ-version) parameter preparation (literal/buffer caching
//! on XLA) lives behind that surface, keyed by the version ids this
//! trainer annotates every call with.
//!
//! Hot-path layout (DESIGN-PERF.md): parameters, momentum and gradient
//! sums live in flat arenas; each micro-batch's backward writes into one
//! persistent model-wide scratch run that the grad buffer accumulates
//! from.  After warm-up a training step performs no host-side allocation
//! for parameter or gradient state.

use anyhow::Result;

use super::{version_id, ExecMode, StepLog};
use crate::data::{DataSource, MicroBatch};
use crate::metrics::Metrics;
use crate::parallel::arena::{AlignedBuf, ArenaLayout};
use crate::parallel::{Checkpoint, GradBuffer, ParamStore, Rule};
use crate::runtime::Backend;
use crate::tensor::{HostTensor, Tensor};
use crate::trace::{self, Fields, TraceKind};

pub struct RefTrainer<'rt, B: Backend> {
    pub rt: &'rt B,
    pub store: ParamStore,
    pub data: DataSource,
    pub rule: Rule,
    pub lr: f32,
    pub metrics: Metrics,
    grads: GradBuffer,
    /// Per-micro-batch gradient scratch (model-wide flat run, reused;
    /// aligned so the vectorized kernels write on full SIMD lanes).
    gmb: AlignedBuf,
    /// Execution state behind the backend boundary.  Defaults to
    /// [`ExecMode::HostLiteral`]: this trainer *is* the reference oracle,
    /// and the host path is the reference semantics.
    /// [`Self::new_with_mode`] opts into the device-resident path (XLA),
    /// which the equivalence tests hold bit-identical to the oracle.
    exec: B::Exec,
}

impl<'rt, B: Backend> RefTrainer<'rt, B> {
    pub fn new(rt: &'rt B, rule: Rule) -> Result<Self> {
        Self::new_with_mode(rt, rule, ExecMode::HostLiteral)
    }

    pub fn new_with_mode(rt: &'rt B, rule: Rule, mode: ExecMode) -> Result<Self> {
        let layout = ArenaLayout::from_manifest(rt.manifest());
        let flat = rt.init_params_flat()?;
        let store = ParamStore::from_flat(layout.clone(), flat);
        Ok(Self::assemble(rt, rule, store, mode))
    }

    /// Build the reference trainer from a planner [`crate::plan::Plan`].
    /// The backend must already match the plan's partition and precision
    /// (see `NativeBackend::repartitioned`); only the rule applies here —
    /// the single trainer has no comm variant or bucket dimension.
    pub fn from_plan(rt: &'rt B, plan: &crate::plan::Plan) -> Result<Self> {
        Self::new(rt, plan.rule.clone())
    }

    /// With explicit initial params (equivalence tests inject these).
    pub fn with_params(rt: &'rt B, rule: Rule, init: Vec<Vec<Tensor>>) -> Self {
        Self::assemble(rt, rule, ParamStore::new(init), ExecMode::HostLiteral)
    }

    /// Resume from a θ-version-boundary checkpoint.  The continuation is
    /// bit-identical to the uninterrupted run: the restored step counter
    /// re-derives the data stream (`microbatch_seed` is pure in
    /// `(seed, step, mb)`), and the three arenas are the complete
    /// optimizer state (DESIGN-ROBUSTNESS.md).
    pub fn resume(rt: &'rt B, rule: Rule, ck: Checkpoint) -> Result<Self> {
        Self::resume_with_mode(rt, rule, ck, ExecMode::HostLiteral)
    }

    pub fn resume_with_mode(
        rt: &'rt B,
        rule: Rule,
        ck: Checkpoint,
        mode: ExecMode,
    ) -> Result<Self> {
        let layout = ArenaLayout::from_manifest(rt.manifest());
        let store = ck.into_store(layout, &rule)?;
        trace::instant(
            TraceKind::CkptResume,
            Fields { step: store.step(), ..Fields::default() },
        );
        Ok(Self::assemble(rt, rule, store, mode))
    }

    /// Snapshot the trainer at its current θ-version boundary (between
    /// [`Self::step`] calls — never mid-step).
    pub fn checkpoint(&self) -> Checkpoint {
        trace::instant(
            TraceKind::CkptSave,
            Fields { step: self.store.step(), ..Fields::default() },
        );
        Checkpoint::capture(&self.store, &self.rule)
    }

    fn assemble(rt: &'rt B, rule: Rule, store: ParamStore, mode: ExecMode) -> Self {
        // Spawn the kernel worker pool before the first step, so one-time
        // thread/stack setup never lands inside a timed or
        // allocation-counted training step.  Parallelism composition
        // (DESIGN-PERF.md §Kernel architecture): this single-threaded
        // trainer gets its parallelism *inside* the kernels — the matmuls
        // and the backend's SGD partition across the pool; trainers that
        // already run stages on their own threads keep the pool for
        // whichever stage grabs it first and the rest fall back to the
        // bit-identical serial path.
        crate::util::par::warm();
        let n_mb = rt.manifest().n_microbatches;
        let layout = store.layout().clone();
        Self {
            rt,
            store,
            data: DataSource::from_manifest(rt.manifest()),
            rule,
            lr: rt.manifest().lr,
            metrics: Metrics::new(),
            grads: GradBuffer::new(layout.clone(), n_mb),
            gmb: layout.zeros_aligned(),
            exec: rt.executor(mode),
        }
    }

    pub fn mode(&self) -> ExecMode {
        self.rt.exec_mode(&self.exec)
    }

    /// Stage-level parameter uploads performed by the backend's device
    /// store (`None` on paths without one) — the bench's ≤1-per-θ-version
    /// metric.
    pub fn device_param_uploads(&self) -> Option<u64> {
        self.rt.param_uploads(&self.exec)
    }

    /// One micro-batch's fwd+bwd at the rule-selected parameter versions,
    /// gradients written into `gmb` (model-wide flat run).  Every call is
    /// annotated with its θ-version id, so the backend prepares each
    /// (stage, version) at most once however many micro-batches share it.
    fn run_microbatch(&mut self, t: u64, i: usize, gmb: &mut [f32]) -> Result<f32> {
        let n = self.rt.manifest().n_stages;
        let rt = self.rt;
        let layout = self.store.layout().clone();
        let mb = self.data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match mb {
            MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
            MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
        };

        // forward chain, stashing stage inputs (the remat unit)
        let mut acts: Vec<B::Act> = Vec::with_capacity(n);
        acts.push(rt.input(&mut self.exec, x0)?);
        for j in 0..n - 1 {
            let ver = version_id(&self.rule, self.store.step(), i, j, n);
            let flat = self.store.select(&self.rule, i, j);
            let t_fwd = trace::start();
            let y = rt.fwd(&mut self.exec, j, ver, flat, &acts[j])?;
            trace::span(
                TraceKind::Fwd,
                t_fwd,
                Fields { stage: j as u32, step: t, version: ver, ..Fields::default() },
            );
            // stage j's output is stashed until stage j+1's backward
            trace::instant(
                TraceKind::ActAlloc,
                Fields {
                    stage: j as u32,
                    step: t,
                    bytes: rt.manifest().stages[j].act_bytes,
                    ..Fields::default()
                },
            );
            acts.push(y);
        }
        let free_act = |j: usize| {
            // stage j's backward consumed stage j−1's stashed output (the
            // raw input at j == 0 was never counted by ActAlloc)
            if j > 0 {
                trace::instant(
                    TraceKind::ActFree,
                    Fields {
                        stage: (j - 1) as u32,
                        step: t,
                        bytes: rt.manifest().stages[j - 1].act_bytes,
                        ..Fields::default()
                    },
                );
            }
        };

        // backward chain, grads straight into the arena scratch
        let last = n - 1;
        let ver = version_id(&self.rule, self.store.step(), i, last, n);
        let flat = self.store.select(&self.rule, i, last);
        let t_bwd = trace::start();
        let (loss, mut gx) = rt.last_bwd(
            &mut self.exec,
            ver,
            flat,
            &acts[last],
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        trace::span(
            TraceKind::Bwd,
            t_bwd,
            Fields { stage: last as u32, step: t, version: ver, ..Fields::default() },
        );
        free_act(last);
        for j in (1..last).rev() {
            let ver = version_id(&self.rule, self.store.step(), i, j, n);
            let flat = self.store.select(&self.rule, i, j);
            let t_bwd = trace::start();
            gx = rt.mid_bwd(
                &mut self.exec,
                j,
                ver,
                flat,
                &acts[j],
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
            trace::span(
                TraceKind::Bwd,
                t_bwd,
                Fields { stage: j as u32, step: t, version: ver, ..Fields::default() },
            );
            free_act(j);
        }
        if n > 1 {
            let ver = version_id(&self.rule, self.store.step(), i, 0, n);
            let flat = self.store.select(&self.rule, i, 0);
            let t_bwd = trace::start();
            rt.first_bwd(
                &mut self.exec,
                ver,
                flat,
                &acts[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
            trace::span(
                TraceKind::Bwd,
                t_bwd,
                Fields { stage: 0, step: t, version: ver, ..Fields::default() },
            );
        }
        Ok(loss)
    }

    /// Run one full training step (N micro-batches + update).
    pub fn step(&mut self) -> Result<StepLog> {
        let n = self.rt.manifest().n_stages;
        let n_mb = self.rt.manifest().n_microbatches;
        let t = self.store.step();
        let lr = self.lr;
        let t_step = trace::start();
        trace::instant(TraceKind::StepBegin, Fields { step: t, ..Fields::default() });

        let mut loss_sum = 0f64;
        let mut gmb = std::mem::take(&mut self.gmb);
        for i in 1..=n_mb {
            let loss = match self.run_microbatch(t, i, &mut gmb) {
                Ok(l) => l,
                Err(e) => {
                    self.gmb = gmb; // restore scratch before bailing
                    return Err(e);
                }
            };
            loss_sum += loss as f64;
            self.grads.add_all_flat(i, &gmb);
        }
        self.gmb = gmb;
        self.grads.average();

        // fused SGD per stage: θ_t (cur) → θ_{t+1} (next slot), then
        // rotate; the XLA device path additionally installs the result
        // as the resident next version
        for j in 0..n {
            let rt = self.rt;
            let g = self.grads.stage(j);
            let t_sgd = trace::start();
            let (cur, moms, next) = self.store.update_parts(j);
            rt.sgd(&mut self.exec, j, t, cur, moms, g, lr, next)?;
            trace::span(
                TraceKind::Sgd,
                t_sgd,
                Fields { stage: j as u32, step: t, ..Fields::default() },
            );
        }
        self.grads.reset();
        self.store.commit_step();

        let loss = loss_sum / n_mb as f64;
        self.metrics.record("loss", t as f64, loss);
        trace::loss(0, t, loss);
        trace::span(TraceKind::StepEnd, t_step, Fields { step: t, ..Fields::default() });
        Ok(StepLog { step: t, loss })
    }

    pub fn train(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        (0..steps).map(|_| self.step()).collect()
    }

    /// Classification accuracy on the held-out split (eval micro-batches).
    pub fn accuracy(&self, n_batches: u64) -> Result<f64> {
        let n = self.rt.manifest().n_stages;
        let mut correct = 0usize;
        let mut total = 0usize;
        for k in 0..n_batches {
            let mb = self.data.eval_microbatch(k);
            let MicroBatch::Class { x, labels } = mb else {
                anyhow::bail!("accuracy() needs a classification bundle")
            };
            let mut a = HostTensor::F32(x);
            for j in 0..n - 1 {
                let y = self.rt.stage_fwd_flat(j, self.store.fresh(j), &a)?;
                a = HostTensor::F32(y);
            }
            let logits = self.rt.predict_flat(
                self.store.fresh(n - 1),
                a.as_f32()
                    .ok_or_else(|| anyhow::anyhow!("eval stage chain produced non-f32 acts"))?,
            )?;
            let classes = logits.shape[1];
            for (b, lbl) in labels.data.iter().enumerate() {
                let row = &logits.data[b * classes..(b + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                if pred as i32 == *lbl {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Evaluation loss on held-out LM data (fwd only, fresh params).
    pub fn eval_loss(&self, n_batches: u64) -> Result<f64> {
        let n = self.rt.manifest().n_stages;
        let mut sum = 0f64;
        for k in 0..n_batches {
            let mb = self.data.eval_microbatch(k);
            let MicroBatch::Lm { tokens, targets } = mb else {
                anyhow::bail!("eval_loss() needs an LM bundle")
            };
            let mut a = HostTensor::I32(tokens);
            for j in 0..n - 1 {
                let y = self.rt.stage_fwd_flat(j, self.store.fresh(j), &a)?;
                a = HostTensor::F32(y);
            }
            let loss = self.rt.last_fwd_loss_flat(
                self.store.fresh(n - 1),
                a.as_f32()
                    .ok_or_else(|| anyhow::anyhow!("eval stage chain produced non-f32 acts"))?,
                &targets,
            )?;
            sum += loss as f64;
        }
        Ok(sum / n_batches as f64)
    }
}
