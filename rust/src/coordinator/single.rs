//! Single-process reference trainer.
//!
//! Executes the update rules (paper Eq. DP / CDP-v1 / CDP-v2) exactly, in
//! the canonical order: for training step t, micro-batches i = 1..N each
//! run fwd through stages 1..N at their θ̂ versions, then bwd N..1; the
//! gradients accumulate in micro-batch order; one averaged SGD-momentum
//! update per stage commits the step.  This is both the numeric oracle for
//! the threaded trainers and the paper's "Single-GPU" setting (§4.1): the
//! activation-memory difference between DP and CDP on one device is
//! measured by `memsim` over the same schedule this trainer realizes.

use anyhow::Result;

use super::StepLog;
use crate::data::{DataSource, MicroBatch};
use crate::metrics::Metrics;
use crate::parallel::{GradBuffer, ParamStore, Rule};
use crate::runtime::BundleRuntime;
use crate::tensor::{HostTensor, Tensor};

pub struct RefTrainer<'rt> {
    pub rt: &'rt BundleRuntime,
    pub store: ParamStore,
    pub data: DataSource,
    pub rule: Rule,
    pub lr: f32,
    pub metrics: Metrics,
    grads: GradBuffer,
}

impl<'rt> RefTrainer<'rt> {
    pub fn new(rt: &'rt BundleRuntime, rule: Rule) -> Result<Self> {
        let init = rt.init_params()?;
        let n_mb = rt.manifest.n_microbatches;
        let grads = GradBuffer::from_params(&init, n_mb);
        Ok(Self {
            rt,
            store: ParamStore::new(init),
            data: DataSource::from_manifest(&rt.manifest),
            rule,
            lr: rt.manifest.lr,
            metrics: Metrics::new(),
            grads,
        })
    }

    /// With explicit initial params (equivalence tests inject these).
    pub fn with_params(
        rt: &'rt BundleRuntime,
        rule: Rule,
        init: Vec<Vec<Tensor>>,
    ) -> Self {
        let n_mb = rt.manifest.n_microbatches;
        let grads = GradBuffer::from_params(&init, n_mb);
        Self {
            rt,
            store: ParamStore::new(init),
            data: DataSource::from_manifest(&rt.manifest),
            rule,
            lr: rt.manifest.lr,
            metrics: Metrics::new(),
            grads,
        }
    }

    /// One micro-batch's fwd+bwd at the rule-selected parameter versions.
    /// `lits[stage]` are the pre-uploaded literals for *this* micro-batch's
    /// θ̂ versions (DESIGN.md §Perf-L3: parameters are uploaded once per
    /// (stage, version) per training step, not once per micro-batch).
    fn run_microbatch(
        &self,
        t: u64,
        i: usize,
        lits: &[&Vec<xla::Literal>],
    ) -> Result<(f32, Vec<Vec<Tensor>>)> {
        let n = self.rt.manifest.n_stages;
        let mb = self.data.microbatch(t, (i - 1) as u64);
        let (x0, targets): (HostTensor, _) = match &mb {
            MicroBatch::Lm { tokens, targets } => {
                (HostTensor::I32(tokens.clone()), targets.clone())
            }
            MicroBatch::Class { x, labels } => {
                (HostTensor::F32(x.clone()), labels.clone())
            }
        };

        // forward chain, stashing stage inputs (the remat unit)
        let mut inputs: Vec<HostTensor> = vec![x0];
        for j in 0..n - 1 {
            let y = self.rt.stage_fwd_lits(j, lits[j], &inputs[j])?;
            inputs.push(HostTensor::F32(y));
        }

        // backward chain
        let mut grads: Vec<Vec<Tensor>> = vec![Vec::new(); n];
        let last = n - 1;
        let x_last = inputs[last].as_f32().expect("loss stage input is f32");
        let (loss, mut gx, gp) = self.rt.last_bwd_lits(lits[last], x_last, &targets)?;
        grads[last] = gp;
        for j in (1..last).rev() {
            let x = inputs[j].as_f32().unwrap();
            let (gx_new, gp) = self.rt.mid_bwd_lits(j, lits[j], x, &gx)?;
            grads[j] = gp;
            gx = gx_new;
        }
        if n > 1 {
            grads[0] = self.rt.first_bwd_lits(lits[0], &inputs[0], &gx)?;
        }
        Ok((loss, grads))
    }

    /// Run one full training step (N micro-batches + update).
    pub fn step(&mut self) -> Result<StepLog> {
        let n = self.rt.manifest.n_stages;
        let n_mb = self.rt.manifest.n_microbatches;
        let t = self.store.step();

        // Upload each needed (stage, version) exactly once for this step.
        let mut fresh_lits: Vec<Option<Vec<xla::Literal>>> = (0..n).map(|_| None).collect();
        let mut stale_lits: Vec<Option<Vec<xla::Literal>>> = (0..n).map(|_| None).collect();
        for i in 1..=n_mb {
            for j in 0..n {
                use crate::parallel::update_rule::Version;
                match self.rule.version(i, j + 1, n) {
                    Version::Fresh if fresh_lits[j].is_none() => {
                        fresh_lits[j] =
                            Some(self.rt.param_literals(self.store.fresh(j))?);
                    }
                    Version::Stale if stale_lits[j].is_none() => {
                        stale_lits[j] =
                            Some(self.rt.param_literals(self.store.stale(j))?);
                    }
                    _ => {}
                }
            }
        }

        // CDP_NO_LITCACHE=1 disables the cache (per-micro-batch re-upload),
        // used by the §Perf A/B measurement in EXPERIMENTS.md.
        let no_cache = std::env::var_os("CDP_NO_LITCACHE").is_some();
        let mut loss_sum = 0f64;
        for i in 1..=n_mb {
            use crate::parallel::update_rule::Version;
            let rebuilt: Vec<Vec<xla::Literal>>;
            let lits: Vec<&Vec<xla::Literal>> = if no_cache {
                rebuilt = (0..n)
                    .map(|j| {
                        let p = match self.rule.version(i, j + 1, n) {
                            Version::Fresh => self.store.fresh(j),
                            Version::Stale => self.store.stale(j),
                        };
                        self.rt.param_literals(p)
                    })
                    .collect::<Result<_>>()?;
                rebuilt.iter().collect()
            } else {
                (0..n)
                    .map(|j| match self.rule.version(i, j + 1, n) {
                        Version::Fresh => fresh_lits[j].as_ref().unwrap(),
                        Version::Stale => stale_lits[j].as_ref().unwrap(),
                    })
                    .collect()
            };
            let (loss, grads) = self.run_microbatch(t, i, &lits)?;
            loss_sum += loss as f64;
            for (j, g) in grads.into_iter().enumerate() {
                self.grads.add(j, i, &g);
            }
        }
        let averaged = self.grads.take_averaged();

        // SGD per stage on a copy of θ_t, then commit (θ_t → θ_{t−1}).
        let mut new_params: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        for j in 0..n {
            let mut p = self.store.fresh(j).clone();
            let rt = self.rt;
            let lr = self.lr;
            let (_cur, moms) = self.store.stage_mut(j);
            rt.sgd_update(j, &mut p, moms, &averaged[j], lr)?;
            new_params.push(p);
        }
        self.store.commit_step(new_params);

        let loss = loss_sum / n_mb as f64;
        self.metrics.record("loss", t as f64, loss);
        Ok(StepLog { step: t, loss })
    }

    pub fn train(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        (0..steps).map(|_| self.step()).collect()
    }

    /// Classification accuracy on the held-out split (eval micro-batches).
    pub fn accuracy(&self, n_batches: u64) -> Result<f64> {
        let n = self.rt.manifest.n_stages;
        let mut correct = 0usize;
        let mut total = 0usize;
        for k in 0..n_batches {
            let mb = self.data.eval_microbatch(k);
            let MicroBatch::Class { x, labels } = mb else {
                anyhow::bail!("accuracy() needs a classification bundle")
            };
            let mut a = HostTensor::F32(x);
            for j in 0..n - 1 {
                let y = self.rt.stage_fwd(j, self.store.fresh(j), &a)?;
                a = HostTensor::F32(y);
            }
            let logits =
                self.rt.predict(self.store.fresh(n - 1), a.as_f32().unwrap())?;
            let classes = logits.shape[1];
            for (b, lbl) in labels.data.iter().enumerate() {
                let row = &logits.data[b * classes..(b + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                if pred as i32 == *lbl {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Evaluation loss on held-out LM data (fwd only, fresh params).
    pub fn eval_loss(&self, n_batches: u64) -> Result<f64> {
        let n = self.rt.manifest.n_stages;
        let mut sum = 0f64;
        for k in 0..n_batches {
            let mb = self.data.eval_microbatch(k);
            let MicroBatch::Lm { tokens, targets } = mb else {
                anyhow::bail!("eval_loss() needs an LM bundle")
            };
            let mut a = HostTensor::I32(tokens);
            for j in 0..n - 1 {
                let y = self.rt.stage_fwd(j, self.store.fresh(j), &a)?;
                a = HostTensor::F32(y);
            }
            let loss = self.rt.last_fwd_loss(
                self.store.fresh(n - 1),
                a.as_f32().unwrap(),
                &targets,
            )?;
            sum += loss as f64;
        }
        Ok(sum / n_batches as f64)
    }
}
