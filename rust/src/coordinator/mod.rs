//! Trainers: the paper's parallelism settings, each as a coordinator that
//! drives the AOT artifacts through a schedule + update rule.
//!
//! - [`single`]   — single-process reference (exact update-rule numerics;
//!                  also the "Single-GPU DP/CDP" setting of paper §4.1).
//! - [`multi`]    — N worker threads, full replicas: Multi-GPU DP with the
//!                  barrier all-reduce vs CDP with the balanced ring (§4.2).
//! - [`zero`]     — ZeRO-DP state sharding: broadcast vs cyclic p2p
//!                  hand-off of the model states (§4.4).
//! - [`pipeline`] — pipeline engine over stages: GPipe and 1F1B schedules;
//!                  CDP-v1 under PP reproduces PipeDream-2BW (§4.3).
//!
//! All trainers share the invariant: same bundle + same rule + same steps
//! ⇒ same loss sequence as [`single::RefTrainer`] (bit-for-bit for
//! rank-ordered reductions; tested in rust/tests/).

pub mod multi;
pub mod pipeline;
pub mod single;
pub mod zero;

use std::sync::Arc;

use crate::runtime::BundleRuntime;

pub use crate::runtime::ExecMode;

/// Thread-shareable runtime handle.
///
/// SAFETY: the `xla` crate's wrappers hold raw pointers without Send/Sync,
/// but the underlying PJRT C++ objects are documented thread-safe for
/// compilation-free use: `PjRtLoadedExecutable::Execute` may be called
/// concurrently, and each call here constructs its own `Literal`s.  We
/// never share a Literal across threads, never mutate an executable, and
/// compile everything before spawning workers.  The same contract covers
/// the device-resident path: `PjRtClient` buffer creation and
/// `execute_b` are thread-safe, and every `PjRtBuffer`/`DeviceTensor` is
/// created, used and dropped by exactly one worker thread (each worker
/// owns its `DeviceParamStore`; buffers never cross threads).
pub struct SharedRuntime(pub Arc<BundleRuntime>);

unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl Clone for SharedRuntime {
    fn clone(&self) -> Self {
        SharedRuntime(self.0.clone())
    }
}

impl std::ops::Deref for SharedRuntime {
    type Target = BundleRuntime;

    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

/// Per-step training record common to all trainers.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: u64,
    /// Mean loss over the N micro-batches (at their θ̂ versions).
    pub loss: f64,
}

/// θ-version id the [`crate::runtime::DeviceParamStore`] caches under for
/// (micro-batch `i`, `stage`) at training step `step`: the commit step
/// that produced the selected θ.  Fresh ⇒ `step`, stale ⇒ `step − 1`;
/// the saturation encodes the θ_{−1} := θ_0 bootstrap — at step 0 both
/// versions resolve to id 0, i.e. the *same* resident buffers.
pub(crate) fn version_id(
    rule: &crate::parallel::Rule,
    step: u64,
    i: usize,
    stage: usize,
    n_stages: usize,
) -> u64 {
    use crate::parallel::Version;
    match rule.version(i, stage + 1, n_stages) {
        Version::Fresh => step,
        Version::Stale => step.saturating_sub(1),
    }
}
