//! Trainers: the paper's parallelism settings, each as a coordinator that
//! drives an execution [`Backend`] through a schedule + update rule.
//!
//! - [`single`]   — single-process reference (exact update-rule numerics;
//!                  also the "Single-GPU DP/CDP" setting of paper §4.1).
//! - [`multi`]    — N worker threads, full replicas: Multi-GPU DP with the
//!                  barrier all-reduce vs CDP with the balanced ring (§4.2).
//! - [`zero`]     — ZeRO-DP state sharding: broadcast vs cyclic p2p
//!                  hand-off of the model states (§4.4).
//! - [`pipeline`] — pipeline engine over stages: GPipe and 1F1B schedules;
//!                  CDP-v1 under PP reproduces PipeDream-2BW (§4.3).
//!
//! Every trainer is generic over [`Backend`] (DESIGN-PERF.md §Backend
//! boundary): the schedule logic is written once and runs on the pure-
//! Rust `NativeBackend` or (feature `xla`) the PJRT `BundleRuntime`.
//!
//! All trainers share the invariant: same bundle + same rule + same steps
//! ⇒ same loss sequence as [`single::RefTrainer`] (bit-for-bit for
//! rank-ordered reductions; tested in rust/tests/).

pub mod multi;
pub mod pipeline;
pub mod single;
pub mod zero;

use std::sync::Arc;

use crate::runtime::Backend;

pub use crate::runtime::ExecMode;

/// Thread-shareable backend handle.
///
/// Send/Sync derive from `B` (via the `Arc`), never from this wrapper:
/// the multi-worker trainers bound `B: Send + Sync`, the native backend
/// is plain-old-data and qualifies automatically, and the XLA
/// `BundleRuntime` carries its own `unsafe impl` with the PJRT
/// thread-safety justification next to the raw-pointer wrappers it
/// vouches for (`runtime::bundle`).  A future backend holding
/// genuinely thread-bound state is therefore rejected by the compiler
/// instead of being silently shared across workers.
pub struct SharedBackend<B: Backend>(pub Arc<B>);

impl<B: Backend> Clone for SharedBackend<B> {
    fn clone(&self) -> Self {
        SharedBackend(self.0.clone())
    }
}

impl<B: Backend> std::ops::Deref for SharedBackend<B> {
    type Target = B;

    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

/// A shared handle is itself a [`Backend`] (delegating through the
/// `Arc`), so call sites can hand `&SharedBackend<B>` anywhere a generic
/// `&B: Backend` is expected — deref coercion does not fire in generic
/// argument positions, this impl is what keeps the pre-split call shapes
/// (`RefTrainer::new(&shared, …)`, `pipeline::train(&shared, …)`)
/// compiling.
#[allow(clippy::too_many_arguments)]
impl<B: Backend> Backend for SharedBackend<B> {
    type Act = B::Act;
    type Exec = B::Exec;

    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn manifest(&self) -> &crate::model::Manifest {
        self.0.manifest()
    }

    fn init_params_flat(&self) -> anyhow::Result<Vec<f32>> {
        self.0.init_params_flat()
    }

    fn executor(&self, mode: ExecMode) -> Self::Exec {
        self.0.executor(mode)
    }

    fn exec_mode(&self, exec: &Self::Exec) -> ExecMode {
        self.0.exec_mode(exec)
    }

    fn param_uploads(&self, exec: &Self::Exec) -> Option<u64> {
        self.0.param_uploads(exec)
    }

    fn input(
        &self,
        exec: &mut Self::Exec,
        x: crate::tensor::HostTensor,
    ) -> anyhow::Result<Self::Act> {
        self.0.input(exec, x)
    }

    fn fwd(
        &self,
        exec: &mut Self::Exec,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
    ) -> anyhow::Result<Self::Act> {
        self.0.fwd(exec, stage, version, flat, x)
    }

    fn last_bwd(
        &self,
        exec: &mut Self::Exec,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
        targets: &crate::tensor::IntTensor,
        gdst: &mut [f32],
    ) -> anyhow::Result<(f32, Self::Act)> {
        self.0.last_bwd(exec, version, flat, x, targets, gdst)
    }

    fn mid_bwd(
        &self,
        exec: &mut Self::Exec,
        stage: usize,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
        gy: &Self::Act,
        gdst: &mut [f32],
    ) -> anyhow::Result<Self::Act> {
        self.0.mid_bwd(exec, stage, version, flat, x, gy, gdst)
    }

    fn first_bwd(
        &self,
        exec: &mut Self::Exec,
        version: u64,
        flat: &[f32],
        x: &Self::Act,
        gy: &Self::Act,
        gdst: &mut [f32],
    ) -> anyhow::Result<()> {
        self.0.first_bwd(exec, version, flat, x, gy, gdst)
    }

    fn sgd(
        &self,
        exec: &mut Self::Exec,
        stage: usize,
        version: u64,
        cur: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        self.0.sgd(exec, stage, version, cur, moms, grads, lr, out)
    }

    fn stage_fwd_flat(
        &self,
        stage: usize,
        flat: &[f32],
        x: &crate::tensor::HostTensor,
    ) -> anyhow::Result<crate::tensor::Tensor> {
        self.0.stage_fwd_flat(stage, flat, x)
    }

    fn last_fwd_loss_flat(
        &self,
        flat: &[f32],
        x: &crate::tensor::Tensor,
        targets: &crate::tensor::IntTensor,
    ) -> anyhow::Result<f32> {
        self.0.last_fwd_loss_flat(flat, x, targets)
    }

    fn predict_flat(
        &self,
        flat: &[f32],
        x: &crate::tensor::Tensor,
    ) -> anyhow::Result<crate::tensor::Tensor> {
        self.0.predict_flat(flat, x)
    }

    fn sgd_update_flat(
        &self,
        stage: usize,
        params: &[f32],
        moms: &mut [f32],
        grads: &[f32],
        lr: f32,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        self.0.sgd_update_flat(stage, params, moms, grads, lr, out)
    }
}

/// The pre-split name for the shared XLA runtime handle (tests, benches
/// and examples constructed `SharedRuntime(Arc::new(rt))`; the tuple
/// constructor still works through the alias).
#[cfg(feature = "xla")]
pub type SharedRuntime = SharedBackend<crate::runtime::BundleRuntime>;

/// Per-step training record common to all trainers.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: u64,
    /// Mean loss over the N micro-batches (at their θ̂ versions).
    pub loss: f64,
}

/// Execute a planner [`Plan`] on the backend it was searched for: pick
/// the coordinator from [`TrainerKind`], map the plan's [`Variant`] onto
/// that coordinator's own vocabulary, and train `steps` steps.
///
/// The backend must already realize the plan's *partition and precision*
/// (for synthetic native bundles, `NativeBackend::repartitioned` +
/// `with_precision` — the CLI's `--plan` path does this); this function
/// validates the stage count and refuses a mismatched backend rather
/// than silently training a different configuration.
///
/// [`Plan`]: crate::plan::Plan
/// [`TrainerKind`]: crate::plan::TrainerKind
/// [`Variant`]: crate::plan::Variant
pub fn execute_plan<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    plan: &crate::plan::Plan,
    steps: usize,
) -> anyhow::Result<Vec<StepLog>> {
    use crate::plan::{TrainerKind, Variant};

    anyhow::ensure!(
        rt.manifest().n_stages == plan.n_stages as usize,
        "backend has {} stages but plan `{}` wants {} — repartition the \
         backend before executing the plan",
        rt.manifest().n_stages,
        plan.label(),
        plan.n_stages
    );
    match plan.trainer {
        TrainerKind::Single => {
            anyhow::ensure!(
                plan.variant == Variant::None,
                "single trainer takes no schedule variant, plan `{}` has `{}`",
                plan.label(),
                plan.variant.name()
            );
            let mut t = single::RefTrainer::from_plan(&rt, plan)?;
            t.train(steps)
        }
        TrainerKind::Multi => {
            let pattern = match plan.variant {
                Variant::Ring => multi::CommPattern::Ring,
                Variant::Barrier => multi::CommPattern::Barrier,
                v => anyhow::bail!(
                    "plan variant `{}` is not a multi comm pattern (ring|barrier)",
                    v.name()
                ),
            };
            let rep = multi::train_with(
                rt,
                plan.rule.clone(),
                pattern,
                steps,
                multi::MultiOpts::from_plan(plan),
            )?;
            Ok(rep.logs)
        }
        TrainerKind::Zero => {
            let flow = match plan.variant {
                Variant::Broadcast => zero::StateFlow::Broadcast,
                Variant::Cyclic => zero::StateFlow::Cyclic,
                v => anyhow::bail!(
                    "plan variant `{}` is not a ZeRO state flow (broadcast|cyclic)",
                    v.name()
                ),
            };
            let rep = zero::train_with(
                rt,
                plan.rule.clone(),
                flow,
                steps,
                zero::ZeroOpts::from_plan(plan),
            )?;
            Ok(rep.logs)
        }
        TrainerKind::Pipeline => {
            let sched = match plan.variant {
                Variant::GPipe => pipeline::PipeSchedule::GPipe,
                Variant::OneFOneB => pipeline::PipeSchedule::OneFOneB,
                v => anyhow::bail!(
                    "plan variant `{}` is not a pipeline schedule (gpipe|1f1b)",
                    v.name()
                ),
            };
            let rep = pipeline::train_with(
                &rt,
                plan.rule.clone(),
                sched,
                steps,
                pipeline::PipeOpts::from_plan(plan),
            )?;
            Ok(rep.logs)
        }
    }
}

/// θ-version id a backend's per-version caches key under for
/// (micro-batch `i`, `stage`) at training step `step`: the commit step
/// that produced the selected θ.  Fresh ⇒ `step`, stale ⇒ `step − 1`;
/// the saturation encodes the θ_{−1} := θ_0 bootstrap — at step 0 both
/// versions resolve to id 0, i.e. the *same* cached entry.
pub(crate) fn version_id(
    rule: &crate::parallel::Rule,
    step: u64,
    i: usize,
    stage: usize,
    n_stages: usize,
) -> u64 {
    use crate::parallel::Version;
    match rule.version(i, stage + 1, n_stages) {
        Version::Fresh => step,
        Version::Stale => step.saturating_sub(1),
    }
}
