//! Pipeline-parallel engine (paper §4.3): one device per stage, micro-
//! batches flowing through — the setting in which CDP specializes to
//! PipeDream-2BW (rule CDP-v1) and improves on it (rule CDP-v2).
//!
//! A dependency-driven list scheduler builds the timetable:
//!
//! - **GPipe**: all forwards drain before any backward (synchronous rule,
//!   full bubble).
//! - **1F1B** (PipeDream): a device alternates fwd/bwd in steady state,
//!   preferring backwards once available — smaller activation stash,
//!   same bubble as GPipe for M = N but bounded memory.
//!
//! The engine *executes* the timetable against an execution [`Backend`]
//! (real numerics, single host thread — the devices are memory/comm
//! ledgers, per DESIGN.md substitution #1) and measures: bubble fraction,
//! per-device peak activation stash, inter-stage activation traffic,
//! parameter versions held, and the eager-reduction overlap (which
//! gradient buckets could launch before the step's final backward op —
//! everything except the last-finishing stage's buckets, per the
//! timetable).  Losses match the reference trainer bit-for-bit for the
//! same rule.
//!
//! On XLA, execution is device-resident by default;
//! `PipeOpts`/`CDP_EXEC_MODE` selects the host/literal path — losses are
//! bit-identical either way (the native backend has one path).
//!
//! ## Robustness (DESIGN-ROBUSTNESS.md)
//!
//! The engine runs on a single host thread with *simulated* devices, so
//! there is no comm fabric to inject faults into — its fault lane is
//! kill/resume: [`PipeOpts::checkpoint_at`] captures a [`Checkpoint`] at
//! a θ-version boundary and [`resume_with`] continues bit-identically.

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::{version_id, ExecMode, StepLog};
use crate::cluster::DeviceMem;
use crate::comm::bucketed::{bucket_elems_from_env, effective_bucket_elems};
use crate::data::{DataSource, MicroBatch};
use crate::metrics::Metrics;
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{Checkpoint, GradBuffer, ParamStore, Rule};
use crate::runtime::{Activation, Backend};
use crate::tensor::HostTensor;
use crate::trace::{self, Fields, TraceKind};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeSchedule {
    GPipe,
    OneFOneB,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum PipeOp {
    Fwd { mb: usize, stage: usize },
    Bwd { mb: usize, stage: usize },
}

/// Knobs for [`train_with`]; [`Default`] is the production configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipeOpts {
    pub mode: ExecMode,
    /// Gradient bucket granularity for the overlap accounting (elements).
    pub bucket_elems: usize,
    /// Capture a checkpoint at the θ-version boundary after this step.
    pub checkpoint_at: Option<u64>,
}

impl Default for PipeOpts {
    fn default() -> Self {
        Self {
            mode: ExecMode::from_env(ExecMode::DeviceResident),
            bucket_elems: bucket_elems_from_env(),
            checkpoint_at: None,
        }
    }
}

impl PipeOpts {
    /// Options for executing a planner [`crate::plan::Plan`]: the plan's
    /// bucket size, defaults everywhere else (rule and schedule are
    /// passed to [`train_with`] by [`crate::coordinator::execute_plan`]).
    pub fn from_plan(plan: &crate::plan::Plan) -> Self {
        Self { bucket_elems: plan.bucket_elems as usize, ..Self::default() }
    }
}

pub struct PipelineReport {
    pub logs: Vec<StepLog>,
    /// Fraction of device-time-slots idle during a steady training step.
    pub bubble_fraction: f64,
    /// Peak activation-stash bytes per device (max over devices).
    pub peak_stash_bytes: u64,
    /// Total inter-stage activation + activation-grad traffic.
    pub act_comm_bytes: u64,
    /// Parameter versions a device must retain (1 for GPipe/DP, 2 for CDP).
    pub param_versions: usize,
    /// Gradient buckets per step across all stages.
    pub grad_buckets: usize,
    /// Fraction of those buckets whose reduction launches before the
    /// step's final backward op completes (timetable-derived: a stage's
    /// buckets are ready at its last backward; only the last-finishing
    /// stage's buckets cannot overlap).
    pub eager_bucket_fraction: f64,
    pub metrics: Metrics,
    /// Captured at the [`PipeOpts::checkpoint_at`] boundary, if any.
    pub checkpoint: Option<Checkpoint>,
}

/// Build one training step's timetable via greedy list scheduling.
/// Returns rows of (time, device, op); `makespan` slots total.
fn build_timetable(
    n: usize,
    m: usize,
    sched: PipeSchedule,
) -> Result<Vec<(usize, usize, PipeOp)>> {
    let mut done: HashMap<PipeOp, usize> = HashMap::new(); // op → finish time
    let mut out = Vec::new();
    let mut t = 0usize;
    // per-device FIFO preference: pending ops become ready when deps done
    while done.len() < 2 * n * m {
        let mut scheduled_any = false;
        for dev in 0..n {
            // candidate ops for this device at time t, in policy order
            let mut cands: Vec<PipeOp> = Vec::new();
            match sched {
                PipeSchedule::GPipe => {
                    for mb in 0..m {
                        cands.push(PipeOp::Bwd { mb, stage: dev });
                    }
                    for mb in 0..m {
                        cands.push(PipeOp::Fwd { mb, stage: dev });
                    }
                    // GPipe: bwd only after ALL fwds of the step completed
                    let all_fwd_done = (0..m)
                        .all(|mb| (0..n).all(|s| done.contains_key(&PipeOp::Fwd { mb, stage: s })));
                    if !all_fwd_done {
                        cands.retain(|op| matches!(op, PipeOp::Fwd { .. }));
                    }
                }
                PipeSchedule::OneFOneB => {
                    // prefer backward when ready (1F1B steady state)
                    for mb in 0..m {
                        cands.push(PipeOp::Bwd { mb, stage: dev });
                    }
                    for mb in 0..m {
                        cands.push(PipeOp::Fwd { mb, stage: dev });
                    }
                }
            }
            let ready = |op: &PipeOp, done: &HashMap<PipeOp, usize>| -> bool {
                if done.contains_key(op) {
                    return false;
                }
                match *op {
                    PipeOp::Fwd { mb, stage } => {
                        stage == 0
                            || done
                                .get(&PipeOp::Fwd { mb, stage: stage - 1 })
                                .map(|f| *f <= t)
                                .unwrap_or(false)
                    }
                    PipeOp::Bwd { mb, stage } => {
                        let fwd_ok = done
                            .get(&PipeOp::Fwd { mb, stage })
                            .map(|f| *f <= t)
                            .unwrap_or(false);
                        let up_ok = stage == n - 1
                            || done
                                .get(&PipeOp::Bwd { mb, stage: stage + 1 })
                                .map(|f| *f <= t)
                                .unwrap_or(false);
                        fwd_ok && up_ok
                    }
                }
            };
            if let Some(op) = cands.iter().find(|op| ready(op, &done)).copied() {
                done.insert(op, t + 1);
                out.push((t, dev, op));
                scheduled_any = true;
            }
        }
        t += 1;
        if !scheduled_any && t > 10 * n * m + 16 {
            anyhow::bail!(
                "pipeline scheduler wedged at t={t} (n={n}, m={m}, {sched:?}): \
                 {} of {} ops placed",
                done.len(),
                2 * n * m
            );
        }
    }
    Ok(out)
}

pub fn train<B: Backend>(
    rt: &B,
    rule: Rule,
    sched: PipeSchedule,
    steps: usize,
) -> Result<PipelineReport> {
    train_with(rt, rule, sched, steps, PipeOpts::default())
}

pub fn train_with<B: Backend>(
    rt: &B,
    rule: Rule,
    sched: PipeSchedule,
    steps: usize,
    opts: PipeOpts,
) -> Result<PipelineReport> {
    run(rt, rule, sched, steps, opts, None)
}

/// Continue a run from a θ-version-boundary checkpoint: step `ck.step`
/// onward is bit-identical to the uninterrupted run that produced it.
pub fn resume_with<B: Backend>(
    rt: &B,
    rule: Rule,
    sched: PipeSchedule,
    steps: usize,
    opts: PipeOpts,
    ck: Checkpoint,
) -> Result<PipelineReport> {
    run(rt, rule, sched, steps, opts, Some(ck))
}

fn run<B: Backend>(
    rt: &B,
    rule: Rule,
    sched: PipeSchedule,
    steps: usize,
    opts: PipeOpts,
    resume: Option<Checkpoint>,
) -> Result<PipelineReport> {
    let n = rt.manifest().n_stages;
    let m = rt.manifest().n_microbatches;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let mut store = match resume {
        Some(ck) => ck.into_store(layout.clone(), &rule)?,
        None => ParamStore::from_flat(layout.clone(), rt.init_params_flat()?),
    };
    let t0 = store.step();
    if t0 > 0 {
        trace::instant(TraceKind::CkptResume, Fields { step: t0, ..Fields::default() });
    }
    let mut grads = GradBuffer::new(layout.clone(), m);
    let mut exec = rt.executor(opts.mode);
    // Warm the kernel pool before the timed loop; this trainer is
    // single-threaded, so every stage op in the software pipeline gets
    // the pool's full width inside its kernels (DESIGN-PERF.md §Kernel
    // architecture).
    crate::util::par::warm();
    // per-op gradient scratch: one stage run at a time, reused
    let mut gop = layout.zeros_aligned();
    let data = DataSource::from_manifest(rt.manifest());
    let mut metrics = Metrics::new();
    let mut devices: Vec<DeviceMem> = (0..n).map(|_| DeviceMem::unbounded()).collect();
    let mut logs = Vec::new();

    let timetable = build_timetable(n, m, sched)?;
    let makespan = timetable.iter().map(|(t, _, _)| t + 1).max().unwrap_or(0);
    let bubble = 1.0 - (2 * n * m) as f64 / (makespan * n) as f64;

    // Eager-reduction overlap, derived from the timetable: stage s's
    // gradient buckets are final at its last backward op; every bucket
    // belonging to a stage that finishes before the step's overall last
    // backward can have its reduction launched while backprop continues.
    let mut last_bwd_of_stage = vec![0usize; n];
    for &(t, _, op) in &timetable {
        if let PipeOp::Bwd { stage, .. } = op {
            last_bwd_of_stage[stage] = last_bwd_of_stage[stage].max(t + 1);
        }
    }
    let overall_last_bwd = last_bwd_of_stage.iter().copied().max().unwrap_or(0);
    let mut grad_buckets = 0usize;
    let mut eager_buckets = 0usize;
    for (s, last) in last_bwd_of_stage.iter().enumerate() {
        let nb = layout
            .n_buckets(s, effective_bucket_elems(opts.bucket_elems, layout.stage_len(s)));
        grad_buckets += nb;
        if *last < overall_last_bwd {
            eager_buckets += nb;
        }
    }
    let eager_bucket_fraction = if grad_buckets > 0 {
        eager_buckets as f64 / grad_buckets as f64
    } else {
        0.0
    };

    let mut act_comm: u64 = 0;
    let mut checkpoint = None;

    for step in t0..t0 + steps as u64 {
        let t_step = trace::start();
        trace::instant(TraceKind::StepBegin, Fields { step, ..Fields::default() });
        // per-(mb) in-flight state
        let mut inputs: HashMap<(usize, usize), B::Act> = HashMap::new(); // (mb, stage) → stashed input
        let mut gxs: HashMap<usize, B::Act> = HashMap::new(); // mb → current cotangent
        let mut losses: Vec<f64> = vec![0.0; m];
        let mut targets_of: HashMap<usize, crate::tensor::IntTensor> = HashMap::new();

        // seed stage-0 inputs
        for mb in 0..m {
            let b = data.microbatch(step, mb as u64);
            let (x0, tgt) = match b {
                MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
                MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
            };
            inputs.insert((mb, 0), rt.input(&mut exec, x0)?);
            targets_of.insert(mb, tgt);
        }

        for &(_t, dev, op) in &timetable {
            match op {
                PipeOp::Fwd { mb, stage } => {
                    devices[dev]
                        .alloc("stash", rt.manifest().stages[stage].act_bytes)
                        .with_context(|| format!("device {dev}: stash alloc, step {step}"))?;
                    // mirror the device ledger: stash lives alloc → free
                    trace::instant(
                        TraceKind::ActAlloc,
                        Fields {
                            worker: dev as u32,
                            stage: stage as u32,
                            step,
                            bytes: rt.manifest().stages[stage].act_bytes,
                            ..Fields::default()
                        },
                    );
                    if stage < n - 1 {
                        let ver = version_id(&rule, step, mb + 1, stage, n);
                        let t_fwd = trace::start();
                        let y = {
                            let x = inputs.get(&(mb, stage)).ok_or_else(|| {
                                anyhow::anyhow!("fwd(mb {mb}, stage {stage}): input never arrived")
                            })?;
                            let params = store.select(&rule, mb + 1, stage);
                            rt.fwd(&mut exec, stage, ver, params, x)?
                        };
                        trace::span(
                            TraceKind::Fwd,
                            t_fwd,
                            Fields {
                                worker: dev as u32,
                                stage: stage as u32,
                                step,
                                version: ver,
                                ..Fields::default()
                            },
                        );
                        act_comm += y.bytes() as u64; // → next device
                        inputs.insert((mb, stage + 1), y);
                    }
                    // loss stage fwd is fused into its bwd (fwdbwd artifact)
                }
                PipeOp::Bwd { mb, stage } => {
                    let ver = version_id(&rule, step, mb + 1, stage, n);
                    let grange = layout.stage_range(stage);
                    let t_bwd = trace::start();
                    if stage == n - 1 {
                        let x = inputs.get(&(mb, stage)).ok_or_else(|| {
                            anyhow::anyhow!("bwd(mb {mb}, stage {stage}): stashed input missing")
                        })?;
                        let params = store.select(&rule, mb + 1, stage);
                        let targets = targets_of.get(&mb).ok_or_else(|| {
                            anyhow::anyhow!("bwd(mb {mb}): targets missing")
                        })?;
                        let (loss, gx) = rt.last_bwd(
                            &mut exec,
                            ver,
                            params,
                            x,
                            targets,
                            &mut gop[grange.clone()],
                        )?;
                        losses[mb] = loss as f64;
                        if n > 1 {
                            act_comm += gx.bytes() as u64;
                            gxs.insert(mb, gx);
                        }
                        grads.add_flat(stage, mb + 1, &gop[grange]);
                    } else if stage > 0 {
                        let x = inputs.get(&(mb, stage)).ok_or_else(|| {
                            anyhow::anyhow!("bwd(mb {mb}, stage {stage}): stashed input missing")
                        })?;
                        let gy = gxs.remove(&mb).ok_or_else(|| {
                            anyhow::anyhow!("bwd(mb {mb}, stage {stage}): cotangent missing")
                        })?;
                        let params = store.select(&rule, mb + 1, stage);
                        let gx = rt.mid_bwd(
                            &mut exec,
                            stage,
                            ver,
                            params,
                            x,
                            &gy,
                            &mut gop[grange.clone()],
                        )?;
                        act_comm += gx.bytes() as u64;
                        gxs.insert(mb, gx);
                        grads.add_flat(stage, mb + 1, &gop[grange]);
                    } else {
                        let x = inputs.get(&(mb, 0)).ok_or_else(|| {
                            anyhow::anyhow!("bwd(mb {mb}, stage 0): stashed input missing")
                        })?;
                        let gy = gxs.remove(&mb).ok_or_else(|| {
                            anyhow::anyhow!("bwd(mb {mb}, stage 0): cotangent missing")
                        })?;
                        let params = store.select(&rule, mb + 1, 0);
                        rt.first_bwd(&mut exec, ver, params, x, &gy, &mut gop[grange.clone()])?;
                        grads.add_flat(0, mb + 1, &gop[grange]);
                    }
                    trace::span(
                        TraceKind::Bwd,
                        t_bwd,
                        Fields {
                            worker: dev as u32,
                            stage: stage as u32,
                            step,
                            version: ver,
                            ..Fields::default()
                        },
                    );
                    inputs.remove(&(mb, stage));
                    devices[dev]
                        .free("stash")
                        .with_context(|| format!("device {dev}: stash free, step {step}"))?;
                    trace::instant(
                        TraceKind::ActFree,
                        Fields {
                            worker: dev as u32,
                            stage: stage as u32,
                            step,
                            bytes: rt.manifest().stages[stage].act_bytes,
                            ..Fields::default()
                        },
                    );
                }
            }
        }

        // update (per-stage averaged grads, same order as reference)
        grads.average();
        let lr = rt.manifest().lr;
        for j in 0..n {
            let g = grads.stage(j);
            let t_sgd = trace::start();
            let (cur, moms, next) = store.update_parts(j);
            rt.sgd(&mut exec, j, step, cur, moms, g, lr, next)?;
            trace::span(
                TraceKind::Sgd,
                t_sgd,
                Fields { worker: j as u32, stage: j as u32, step, ..Fields::default() },
            );
        }
        grads.reset();
        store.commit_step();

        if opts.checkpoint_at == Some(step) {
            checkpoint = Some(Checkpoint::capture(&store, &rule));
            trace::instant(TraceKind::CkptSave, Fields { step, ..Fields::default() });
        }

        let loss = losses.iter().sum::<f64>() / m as f64;
        metrics.record("loss", step as f64, loss);
        trace::loss(0, step, loss);
        logs.push(StepLog { step, loss });
        trace::span(TraceKind::StepEnd, t_step, Fields { step, ..Fields::default() });
    }

    let peak_stash = devices.iter().map(|d| d.peak()).max().unwrap_or(0);
    Ok(PipelineReport {
        logs,
        bubble_fraction: bubble,
        peak_stash_bytes: peak_stash,
        act_comm_bytes: act_comm,
        param_versions: if rule == Rule::Dp { 1 } else { 2 },
        grad_buckets,
        eager_bucket_fraction,
        metrics,
        checkpoint,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timetable_covers_all_ops_once() {
        for sched in [PipeSchedule::GPipe, PipeSchedule::OneFOneB] {
            let tt = build_timetable(4, 4, sched).unwrap();
            assert_eq!(tt.len(), 2 * 4 * 4);
            let set: std::collections::HashSet<_> =
                tt.iter().map(|(_, _, op)| *op).collect();
            assert_eq!(set.len(), 32);
            // ops run on their own stage's device
            for (_, dev, op) in &tt {
                match op {
                    PipeOp::Fwd { stage, .. } | PipeOp::Bwd { stage, .. } => {
                        assert_eq!(dev, stage)
                    }
                }
            }
        }
    }

    #[test]
    fn timetable_respects_dependencies() {
        for sched in [PipeSchedule::GPipe, PipeSchedule::OneFOneB] {
            let tt = build_timetable(3, 3, sched).unwrap();
            let time_of: std::collections::HashMap<_, _> =
                tt.iter().map(|(t, _, op)| (*op, *t)).collect();
            for mb in 0..3 {
                for s in 1..3 {
                    assert!(
                        time_of[&PipeOp::Fwd { mb, stage: s }]
                            > time_of[&PipeOp::Fwd { mb, stage: s - 1 }]
                    );
                }
                for s in 0..2 {
                    assert!(
                        time_of[&PipeOp::Bwd { mb, stage: s }]
                            > time_of[&PipeOp::Bwd { mb, stage: s + 1 }]
                    );
                }
                assert!(
                    time_of[&PipeOp::Bwd { mb, stage: 2 }]
                        > time_of[&PipeOp::Fwd { mb, stage: 2 }]
                );
            }
        }
    }

    #[test]
    fn gpipe_has_full_fwd_drain() {
        let tt = build_timetable(3, 3, PipeSchedule::GPipe).unwrap();
        let last_fwd = tt
            .iter()
            .filter(|(_, _, op)| matches!(op, PipeOp::Fwd { .. }))
            .map(|(t, _, _)| *t)
            .max()
            .unwrap();
        let first_bwd = tt
            .iter()
            .filter(|(_, _, op)| matches!(op, PipeOp::Bwd { .. }))
            .map(|(t, _, _)| *t)
            .min()
            .unwrap();
        assert!(first_bwd > last_fwd);
    }

    #[test]
    fn onefoneb_interleaves() {
        let tt = build_timetable(4, 4, PipeSchedule::OneFOneB).unwrap();
        let last_fwd = tt
            .iter()
            .filter(|(_, _, op)| matches!(op, PipeOp::Fwd { .. }))
            .map(|(t, _, _)| *t)
            .max()
            .unwrap();
        let first_bwd = tt
            .iter()
            .filter(|(_, _, op)| matches!(op, PipeOp::Bwd { .. }))
            .map(|(t, _, _)| *t)
            .min()
            .unwrap();
        assert!(first_bwd < last_fwd, "1F1B must start bwd before fwd drain");
    }
}
