//! ZeRO-DP trainer (paper §4.4): model states sharded by stage — worker j
//! is the *owner* of stage j's parameters, gradients and optimizer state;
//! no worker holds a full replica.
//!
//! - **Broadcast mode (standard ZeRO-DP)**: before computing a stage, the
//!   owner broadcasts its parameters to all N workers *simultaneously* (a
//!   collective, ≥ O(log N) steps between two time steps).  After the
//!   backward, gradients reduce to the owner, which updates.
//! - **Cyclic mode (ZeRO + CDP)**: micro-batches run staggered, so at any
//!   time step exactly one worker computes stage j — the owner sends the
//!   model states to *one* worker per time step (pure point-to-point), and
//!   the updated parameters hop the same way.  Volume is unchanged (Ψ_P per
//!   step per worker-visit) but the per-time-step message count drops from
//!   N−1 to 1 — the paper's bold entry in Table 1.
//!
//! Gradient reduction to the owners is *eager and bucketed*
//! (`comm::bucketed`): the moment stage j's backward output lands, its
//! buckets fly to owner j while the remaining backward keeps computing —
//! the shard communication is spread across the backward pass instead of
//! bursting at the step boundary.  Owners still reduce in micro-batch
//! order 1..N, so losses stay bit-identical to the reference trainer.
//!
//! Generic over [`Backend`].  On XLA, execution is device-resident by
//! default: the owned shard and every *received* stage's parameters are
//! cached as device buffers per θ-version (a received version uploads at
//! most once per step, and a version still resident from the previous
//! step re-uploads not at all); the owner's fused SGD promotes its
//! result to the next resident version.  Host mirrors remain
//! authoritative — the fabric serves and accounts the same bytes as
//! before, so the paper's comm numbers are unchanged by the execution
//! mode or backend.

use anyhow::Result;

use super::{version_id, ExecMode, SharedBackend, StepLog};
use crate::cluster::run_workers;
use crate::comm::bucketed::{bucket_elems_from_env, BucketedReducer};
use crate::comm::{tags, Endpoint, EventKind, Fabric, Payload};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{Rule, Version};
use crate::runtime::Backend;
use crate::tensor::HostTensor;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateFlow {
    /// Owner broadcasts stage params to all workers each step (ZeRO-DP).
    Broadcast,
    /// Owner hands params to one worker per time step (ZeRO + CDP).
    Cyclic,
}

/// Knobs for [`train_with`]; [`Default`] is the production configuration.
#[derive(Clone, Copy, Debug)]
pub struct ZeroOpts {
    pub mode: ExecMode,
    /// Gradient bucket granularity for the eager shard sends (elements).
    pub bucket_elems: usize,
}

impl Default for ZeroOpts {
    fn default() -> Self {
        Self {
            mode: ExecMode::from_env(ExecMode::DeviceResident),
            bucket_elems: bucket_elems_from_env(),
        }
    }
}

pub struct ZeroReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Max parameter-messages attributable to a single time step.
    pub max_msgs_per_timestep: u64,
    /// Peak per-worker model-state bytes (params it holds at once).
    pub peak_state_bytes: u64,
}

/// Param version a worker must use for (mb i, stage j) under the rule.
fn needed_version(rule: &Rule, i: usize, j: usize, n: usize) -> Version {
    rule.version(i, j + 1, n)
}

/// Flat parameter run for stage `j` as worker `w` (micro-batch `i`) must
/// see it: the locally-owned version for its own stage, the received
/// payload otherwise.
#[allow(clippy::too_many_arguments)]
fn stage_run<'a>(
    j: usize,
    w: usize,
    i: usize,
    n: usize,
    rule: &Rule,
    own_cur: &'a [f32],
    own_prev: &'a [f32],
    recv: &'a [Option<Payload>],
) -> &'a [f32] {
    if j == w {
        match needed_version(rule, i, w, n) {
            Version::Fresh => own_cur,
            Version::Stale => own_prev,
        }
    } else {
        recv[j].as_ref().expect("stage params received")
    }
}

pub fn train<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
) -> Result<ZeroReport> {
    train_with(rt, rule, flow, steps, ZeroOpts::default())
}

pub fn train_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
) -> Result<ZeroReport> {
    let n = rt.manifest().n_stages;
    let n_mb = rt.manifest().n_microbatches;
    assert_eq!(n, n_mb, "ZeRO sharding assumes N stages == N workers");
    let (endpoints, stats) = Fabric::new(n);
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        endpoints.into_iter().map(|e| std::sync::Mutex::new(Some(e))).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().unwrap();
        worker(&rt_arc, &rule_c, flow, &mut ep, w, steps, opts)
            .expect("zero worker failed")
    });

    let (logs, peaks): (Vec<_>, Vec<u64>) = {
        let mut logs = Vec::new();
        let mut peaks = Vec::new();
        for (w, (l, p)) in results.into_iter().enumerate() {
            if w == 0 {
                logs = l;
            }
            peaks.push(p);
        }
        (logs, peaks)
    };

    // Parameter-broadcast concurrency per time step: in Broadcast mode the
    // owner emits N−1 messages within one time step; in Cyclic mode the
    // staggering guarantees one message per time step (see sim::schemes for
    // the step-exact discrete model).
    let max_msgs = match flow {
        StateFlow::Broadcast => (n as u64 - 1).max(1),
        StateFlow::Cyclic => 1,
    };

    Ok(ZeroReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        max_msgs_per_timestep: max_msgs,
        peak_state_bytes: peaks.into_iter().max().unwrap_or(0),
    })
}

#[allow(clippy::too_many_arguments)]
fn worker<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    flow: StateFlow,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: ZeroOpts,
) -> Result<(Vec<StepLog>, u64)> {
    let n = rt.manifest().n_stages;
    let n_mb = ep.n;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let init = rt.init_params_flat()?;
    // Owner state: stage `w` params (current + previous version), momentum
    // and the next-step slot — flat stage runs, allocated once.
    let mut own_cur: Vec<f32> = init[layout.stage_range(w)].to_vec();
    let mut own_prev: Vec<f32> = own_cur.clone();
    let mut own_next: Vec<f32> = vec![0.0; own_cur.len()];
    let mut own_mom: Vec<f32> = vec![0.0; own_cur.len()];
    let own_bytes: u64 = own_cur.len() as u64 * 4;
    // cur + prev + next slot + momentum — all four are persistent
    let mut peak_state: u64 = 4 * own_bytes;
    // Owner-side reduction scratch, reused every step.
    let mut gsum: Vec<f32> = vec![0.0; own_cur.len()];
    // This worker's own micro-batch gradients, model-wide flat scratch.
    let mut gmb: Vec<f32> = layout.zeros();
    let mut exec = rt.executor(opts.mode);
    let reducer = BucketedReducer::new(opts.bucket_elems);

    let data = DataSource::from_manifest(rt.manifest());
    let mut logs = Vec::new();
    let i = w + 1; // this worker's micro-batch index (1-based)

    for t in 0..steps as u64 {
        // ---- parameter distribution -----------------------------------
        // Worker w needs θ̂^j for every stage j.  Owners send; everyone
        // receives what they don't own.
        //
        // Both flows move the same bytes; Cyclic attributes sends to
        // distinct time steps (one peer per step) while Broadcast sends
        // all N−1 at once.  The fabric counts bytes/messages; the
        // step-concurrency difference is scored in `train` above and in
        // sim::schemes.  Each needed version is copied into *one* pooled
        // payload whose handle fans out to every peer wanting it.
        let order: Vec<usize> = match flow {
            // broadcast: all peers at once (rank order)
            StateFlow::Broadcast => (0..n_mb).filter(|p| *p != w).collect(),
            // cyclic: peers in the order their mb reaches stage w —
            // mb i computes stage j at local time; the staggering means
            // peer order is ring order starting after the owner
            StateFlow::Cyclic => (1..n_mb).map(|d| (w + d) % n_mb).collect(),
        };
        let pool = ep.pool().clone();
        let mut fresh_payload: Option<Payload> = None;
        let mut stale_payload: Option<Payload> = None;
        for peer in order {
            let pi = peer + 1;
            let payload = match needed_version(rule, pi, w, n) {
                Version::Fresh => fresh_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_cur))
                    .clone(),
                Version::Stale => stale_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_prev))
                    .clone(),
            };
            ep.send(peer, tags::param(t, w), payload);
        }

        // Receive the other stages' params from their owners; my own stage
        // selects locally from the flat runs.
        let mut recv_params: Vec<Option<Payload>> = vec![None; n];
        let mut recv_bytes: u64 = 0;
        for j in 0..n {
            if j == w {
                continue;
            }
            let payload = ep.recv(j, tags::param(t, j));
            recv_bytes += payload.len() as u64 * 4;
            recv_params[j] = Some(payload);
        }
        // ZeRO memory property: a worker transiently holds its own states
        // + the received stage params (released after use).
        peak_state = peak_state.max(4 * own_bytes + recv_bytes);

        // ---- compute: fwd chain for micro-batch i ----------------------
        let mb = data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match mb {
            MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
            MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
        };
        let mut acts: Vec<B::Act> = Vec::with_capacity(n);
        acts.push(rt.input(&mut exec, x0)?);
        for j in 0..n - 1 {
            let ver = version_id(rule, t, i, j, n);
            let p = stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params);
            let y = rt.fwd(&mut exec, j, ver, p, &acts[j])?;
            acts.push(y);
        }

        // ---- backward chain with eager bucketed shard sends ------------
        // Stage j's gradients fly to owner j bucket by bucket the moment
        // they land; stages below j keep backpropagating meanwhile.  The
        // own-stage slice stays local for the in-order reduction below.
        let last = n - 1;
        let ver = version_id(rule, t, i, last, n);
        let (loss, mut gx) = rt.last_bwd(
            &mut exec,
            ver,
            stage_run(last, w, i, n, rule, &own_cur, &own_prev, &recv_params),
            &acts[last],
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        ep.stats().mark(EventKind::BwdStageDone, w, last, 0);
        if last != w {
            reducer.shard_send(ep, &layout, t, last, i, last, &gmb[layout.stage_range(last)]);
        }
        for j in (1..last).rev() {
            let ver = version_id(rule, t, i, j, n);
            gx = rt.mid_bwd(
                &mut exec,
                j,
                ver,
                stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params),
                &acts[j],
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
            ep.stats().mark(EventKind::BwdStageDone, w, j, 0);
            if j != w {
                reducer.shard_send(ep, &layout, t, j, i, j, &gmb[layout.stage_range(j)]);
            }
        }
        if n > 1 {
            let ver = version_id(rule, t, i, 0, n);
            rt.first_bwd(
                &mut exec,
                ver,
                stage_run(0, w, i, n, rule, &own_cur, &own_prev, &recv_params),
                &acts[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
            ep.stats().mark(EventKind::BwdStageDone, w, 0, 0);
            if w != 0 {
                reducer.shard_send(ep, &layout, t, 0, i, 0, &gmb[layout.stage_range(0)]);
            }
        }
        drop(recv_params); // release received payloads back to the pool

        // ---- owner-side reduction (micro-batch order 1..N) -------------
        reducer.shard_reduce(
            ep,
            &layout,
            t,
            w,
            i,
            n_mb,
            &gmb[layout.stage_range(w)],
            &mut gsum,
        );

        // ---- owner update ----------------------------------------------
        rt.sgd(
            &mut exec,
            w,
            t,
            &own_cur,
            &mut own_mom,
            &gsum,
            rt.manifest().lr,
            &mut own_next,
        )?;
        std::mem::swap(&mut own_prev, &mut own_cur); // prev ← θ_t
        std::mem::swap(&mut own_cur, &mut own_next); // cur ← θ_{t+1}

        // ---- loss reporting (worker 0 logs the canonical mean) ---------
        if w == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok((logs, peak_state))
}
