//! ZeRO-DP trainer (paper §4.4): model states sharded by stage — worker j
//! is the *owner* of stage j's parameters, gradients and optimizer state;
//! no worker holds a full replica.
//!
//! - **Broadcast mode (standard ZeRO-DP)**: before computing a stage, the
//!   owner broadcasts its parameters to all N workers *simultaneously* (a
//!   collective, ≥ O(log N) steps between two time steps).  After the
//!   backward, gradients reduce to the owner, which updates.
//! - **Cyclic mode (ZeRO + CDP)**: micro-batches run staggered, so at any
//!   time step exactly one worker computes stage j — the owner sends the
//!   model states to *one* worker per time step (pure point-to-point), and
//!   the updated parameters hop the same way.  Volume is unchanged (Ψ_P per
//!   step per worker-visit) but the per-time-step message count drops from
//!   N−1 to 1 — the paper's bold entry in Table 1.
//!
//! Measured here: comm bytes, total messages, and `max_msgs_per_timestep`
//! (the schedule-attributed concurrency that distinguishes the two modes).
//! Loss sequences match the reference trainer bit-for-bit.

use anyhow::Result;

use super::{SharedRuntime, StepLog};
use crate::cluster::run_workers;
use crate::comm::{tags, Endpoint, Fabric};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::{Rule, Version};
use crate::tensor::{HostTensor, Tensor};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateFlow {
    /// Owner broadcasts stage params to all workers each step (ZeRO-DP).
    Broadcast,
    /// Owner hands params to one worker per time step (ZeRO + CDP).
    Cyclic,
}

pub struct ZeroReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Max parameter-messages attributable to a single time step.
    pub max_msgs_per_timestep: u64,
    /// Peak per-worker model-state bytes (params it holds at once).
    pub peak_state_bytes: u64,
}

/// Param version a worker must use for (mb i, stage j) under the rule.
fn needed_version(rule: &Rule, i: usize, j: usize, n: usize) -> Version {
    rule.version(i, j + 1, n)
}

pub fn train(
    rt: SharedRuntime,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
) -> Result<ZeroReport> {
    let n = rt.manifest.n_stages;
    let n_mb = rt.manifest.n_microbatches;
    assert_eq!(n, n_mb, "ZeRO sharding assumes N stages == N workers");
    let (endpoints, stats) = Fabric::new(n);
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        endpoints.into_iter().map(|e| std::sync::Mutex::new(Some(e))).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().unwrap();
        worker(&rt_arc, &rule_c, flow, &mut ep, w, steps).expect("zero worker failed")
    });

    let (logs, peaks): (Vec<_>, Vec<u64>) = {
        let mut logs = Vec::new();
        let mut peaks = Vec::new();
        for (w, (l, p)) in results.into_iter().enumerate() {
            if w == 0 {
                logs = l;
            }
            peaks.push(p);
        }
        (logs, peaks)
    };

    // Parameter-broadcast concurrency per time step: in Broadcast mode the
    // owner emits N−1 messages within one time step; in Cyclic mode the
    // staggering guarantees one message per time step (see sim::schemes for
    // the step-exact discrete model).
    let max_msgs = match flow {
        StateFlow::Broadcast => (n as u64 - 1).max(1),
        StateFlow::Cyclic => 1,
    };

    Ok(ZeroReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        max_msgs_per_timestep: max_msgs,
        peak_state_bytes: peaks.into_iter().max().unwrap_or(0),
    })
}

#[allow(clippy::type_complexity)]
fn worker(
    rt: &SharedRuntime,
    rule: &Rule,
    flow: StateFlow,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
) -> Result<(Vec<StepLog>, u64)> {
    let n = rt.manifest.n_stages;
    let n_mb = ep.n;
    let init = rt.init_params()?;
    // Owner state: stage `w` params (current + previous version) + momentum.
    let mut own_cur: Vec<Tensor> = init[w].clone();
    let mut own_prev: Vec<Tensor> = own_cur.clone();
    let mut own_mom: Vec<Tensor> =
        own_cur.iter().map(|t| Tensor::zeros(t.shape.clone())).collect();
    let own_bytes: u64 = own_cur.iter().map(|t| t.bytes() as u64).sum();
    let mut peak_state: u64 = 3 * own_bytes; // cur + prev + momentum

    let data = DataSource::from_manifest(&rt.manifest);
    let mut logs = Vec::new();
    let i = w + 1; // this worker's micro-batch index (1-based)

    for t in 0..steps as u64 {
        // ---- parameter distribution -----------------------------------
        // Worker w needs θ̂^j for every stage j.  Owners send; everyone
        // receives what they don't own.  Tag encodes the version so stale
        // and fresh requests are distinct (fresh = this step's params,
        // stale = previous step's).
        //
        // Both flows move the same bytes; Cyclic attributes sends to
        // distinct time steps (one peer per step) while Broadcast sends
        // all N−1 at once.  The fabric counts bytes/messages; the
        // step-concurrency difference is scored in `train` above and in
        // sim::schemes.
        let mut stage_params: Vec<Option<(Vec<Tensor>, u64)>> = vec![None; n];

        // As owner of stage w: serve both versions to each peer.
        let flat = |ts: &Vec<Tensor>| -> Vec<f32> {
            ts.iter().flat_map(|t| t.data.iter().copied()).collect()
        };
        let order: Vec<usize> = match flow {
            // broadcast: all peers at once (rank order)
            StateFlow::Broadcast => (0..n_mb).filter(|p| *p != w).collect(),
            // cyclic: peers in the order their mb reaches stage w —
            // mb i computes stage j at local time; the staggering means
            // peer order is ring order starting after the owner
            StateFlow::Cyclic => {
                (1..n_mb).map(|d| (w + d) % n_mb).collect()
            }
        };
        for peer in order {
            let pi = peer + 1;
            let v = needed_version(rule, pi, w, n);
            let chosen = match v {
                Version::Fresh => &own_cur,
                Version::Stale => &own_prev,
            };
            ep.send(peer, tags::param(t, w), flat(chosen));
        }
        // My own stage: select locally.
        let v = needed_version(rule, i, w, n);
        stage_params[w] = Some((
            match v {
                Version::Fresh => own_cur.clone(),
                Version::Stale => own_prev.clone(),
            },
            0,
        ));

        // Receive the other stages' params from their owners.
        let mut recv_bytes: u64 = 0;
        for j in 0..n {
            if j == w {
                continue;
            }
            let flat = ep.recv(j, tags::param(t, j));
            recv_bytes += flat.len() as u64 * 4;
            let mut ts = Vec::with_capacity(rt.manifest.stages[j].params.len());
            let mut off = 0;
            for spec in &rt.manifest.stages[j].params {
                let len = spec.elems();
                ts.push(Tensor::new(spec.shape.clone(), flat[off..off + len].to_vec()));
                off += len;
            }
            stage_params[j] = Some((ts, 0));
        }
        // ZeRO memory property: a worker transiently holds its own states
        // + the received stage params (released after use).
        peak_state = peak_state.max(3 * own_bytes + recv_bytes);

        // ---- compute: fwd chain + bwd chain for micro-batch i ----------
        let mb = data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match &mb {
            MicroBatch::Lm { tokens, targets } => {
                (HostTensor::I32(tokens.clone()), targets.clone())
            }
            MicroBatch::Class { x, labels } => {
                (HostTensor::F32(x.clone()), labels.clone())
            }
        };
        let mut inputs: Vec<HostTensor> = vec![x0];
        for j in 0..n - 1 {
            let p = &stage_params[j].as_ref().unwrap().0;
            let y = rt.stage_fwd(j, p, &inputs[j])?;
            inputs.push(HostTensor::F32(y));
        }
        let mut grads: Vec<Vec<Tensor>> = vec![Vec::new(); n];
        let last = n - 1;
        let (loss, mut gx, gp) = rt.last_bwd(
            &stage_params[last].as_ref().unwrap().0,
            inputs[last].as_f32().unwrap(),
            &targets,
        )?;
        grads[last] = gp;
        for j in (1..last).rev() {
            let (gx_new, gp) = rt.mid_bwd(
                j,
                &stage_params[j].as_ref().unwrap().0,
                inputs[j].as_f32().unwrap(),
                &gx,
            )?;
            grads[j] = gp;
            gx = gx_new;
        }
        grads[0] =
            rt.first_bwd(&stage_params[0].as_ref().unwrap().0, &inputs[0], &gx)?;

        // ---- gradient reduction to owners (micro-batch order) ----------
        for j in 0..n {
            if j != w {
                ep.send(
                    j,
                    tags::grad(t, j) ^ ((i as u64) << 40),
                    flat(&grads[j]),
                );
            }
        }
        // Owner: reduce in mb order 1..N (self contribution in its slot).
        let mut sum: Vec<f32> = vec![0.0; own_bytes as usize / 4];
        for mb_i in 1..=n_mb {
            if mb_i == i {
                let own = flat(&grads[w]);
                for (s, v) in sum.iter_mut().zip(&own) {
                    *s += v;
                }
            } else {
                let part =
                    ep.recv(mb_i - 1, tags::grad(t, w) ^ ((mb_i as u64) << 40));
                for (s, v) in sum.iter_mut().zip(&part) {
                    *s += v;
                }
            }
        }
        let inv = 1.0 / n_mb as f32;
        for v in sum.iter_mut() {
            *v *= inv;
        }
        let mut averaged = Vec::with_capacity(own_cur.len());
        let mut off = 0;
        for spec in &rt.manifest.stages[w].params {
            let len = spec.elems();
            averaged.push(Tensor::new(spec.shape.clone(), sum[off..off + len].to_vec()));
            off += len;
        }

        // ---- owner update ----------------------------------------------
        let mut new_p = own_cur.clone();
        rt.sgd_update(w, &mut new_p, &mut own_mom, &averaged, rt.manifest.lr)?;
        own_prev = std::mem::replace(&mut own_cur, new_p);

        // ---- loss reporting (worker 0 logs the canonical mean) ---------
        if w == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok((logs, peak_state))
}
