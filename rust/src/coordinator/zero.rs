//! ZeRO-DP trainer (paper §4.4): model states sharded by stage — worker j
//! is the *owner* of stage j's parameters, gradients and optimizer state;
//! no worker holds a full replica.
//!
//! - **Broadcast mode (standard ZeRO-DP)**: before computing a stage, the
//!   owner broadcasts its parameters to all N workers *simultaneously* (a
//!   collective, ≥ O(log N) steps between two time steps).  After the
//!   backward, gradients reduce to the owner, which updates.
//! - **Cyclic mode (ZeRO + CDP)**: micro-batches run staggered, so at any
//!   time step exactly one worker computes stage j — the owner sends the
//!   model states to *one* worker per time step (pure point-to-point), and
//!   the updated parameters hop the same way.  Volume is unchanged (Ψ_P per
//!   step per worker-visit) but the per-time-step message count drops from
//!   N−1 to 1 — the paper's bold entry in Table 1.
//!
//! Gradient reduction to the owners is *eager and bucketed*
//! (`comm::bucketed`): the moment stage j's backward output lands, its
//! buckets fly to owner j while the remaining backward keeps computing —
//! the shard communication is spread across the backward pass instead of
//! bursting at the step boundary.  Owners still reduce in micro-batch
//! order 1..N, so losses stay bit-identical to the reference trainer.
//!
//! Generic over [`Backend`].  On XLA, execution is device-resident by
//! default: the owned shard and every *received* stage's parameters are
//! cached as device buffers per θ-version (a received version uploads at
//! most once per step, and a version still resident from the previous
//! step re-uploads not at all); the owner's fused SGD promotes its
//! result to the next resident version.  Host mirrors remain
//! authoritative — the fabric serves and accounts the same bytes as
//! before, so the paper's comm numbers are unchanged by the execution
//! mode or backend.
//!
//! ## Robustness (DESIGN-ROBUSTNESS.md)
//!
//! Every receive carries the fabric deadline: a dead owner turns into a
//! typed [`crate::comm::CommError`] naming the peer and the decoded
//! param/shard tag.  Sharding means a lost worker takes its stage's
//! *only* optimizer state with it, so there is no N−1 degraded ring the
//! way the multi trainer re-forms one.  Instead the trainer
//! *re-replicates*: under a scripted kill ([`ZeroOpts::faults`]) the
//! survivors heartbeat at each θ-version boundary, freeze at the
//! junction when the victim goes silent, and hand their shards to a
//! second phase in which the dead worker's stage is rebuilt from the
//! latest persisted checkpoint ([`ZeroOpts::recover_from`], written by
//! worker 0 when [`ZeroOpts::save_checkpoint_to`] is set).
//! [`recover_shard`] returns a typed [`ShardRecoveryError`] when no
//! checkpoint exists, none covers the shard, or the saved boundary does
//! not meet the junction.  With `checkpoint_at = kill_step − 1` the
//! recovered run's losses are bit-identical to a clean run.  Seeded
//! fault injection on the data plane likewise leaves loss sequences
//! bit-identical (retry + seq dedup).

use anyhow::{Context, Result};

use super::{version_id, ExecMode, SharedBackend, StepLog};
use crate::cluster::run_workers;
use crate::comm::bucketed::{bucket_elems_from_env, BucketedReducer};
use crate::comm::fault::FaultPlan;
use crate::comm::{tags, Endpoint, EventKind, Fabric, Payload};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{Checkpoint, Rule, Version};
use crate::runtime::Backend;
use crate::tensor::HostTensor;
use crate::trace::{self, Fields, TraceKind};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A silent peer is declared dead after this long without a heartbeat
/// (generous next to the in-process hop; a live peer answers in µs).
const DETECT_DEADLINE: Duration = Duration::from_secs(2);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateFlow {
    /// Owner broadcasts stage params to all workers each step (ZeRO-DP).
    Broadcast,
    /// Owner hands params to one worker per time step (ZeRO + CDP).
    Cyclic,
}

/// Knobs for [`train_with`]; [`Default`] is the production configuration.
#[derive(Clone, Debug)]
pub struct ZeroOpts {
    pub mode: ExecMode,
    /// Gradient bucket granularity for the eager shard sends (elements).
    pub bucket_elems: usize,
    /// Seeded fault injection on every non-control fabric edge.
    pub faults: Option<FaultPlan>,
    /// Capture a checkpoint at the θ-version boundary after this step
    /// (full state gathered to worker 0 over the control plane).
    pub checkpoint_at: Option<u64>,
    /// Worker 0 also persists the gathered checkpoint here
    /// (`util::binio` format, written atomically via temp + rename).
    pub save_checkpoint_to: Option<PathBuf>,
    /// Shard re-replication source for a scripted kill: the dead
    /// worker's stage is rebuilt from this checkpoint at the junction.
    /// Required whenever [`ZeroOpts::faults`] scripts a kill.
    pub recover_from: Option<PathBuf>,
}

impl Default for ZeroOpts {
    fn default() -> Self {
        Self {
            mode: ExecMode::from_env(ExecMode::DeviceResident),
            bucket_elems: bucket_elems_from_env(),
            faults: None,
            checkpoint_at: None,
            save_checkpoint_to: None,
            recover_from: None,
        }
    }
}

impl ZeroOpts {
    /// Options for executing a planner [`crate::plan::Plan`]: the plan's
    /// bucket size, defaults everywhere else (rule and state flow are
    /// passed to [`train_with`] by [`crate::coordinator::execute_plan`]).
    pub fn from_plan(plan: &crate::plan::Plan) -> Self {
        Self { bucket_elems: plan.bucket_elems as usize, ..Self::default() }
    }
}

pub struct ZeroReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Max parameter-messages attributable to a single time step.
    pub max_msgs_per_timestep: u64,
    /// Peak per-worker model-state bytes (params it holds at once).
    pub peak_state_bytes: u64,
    /// Captured at the [`ZeroOpts::checkpoint_at`] boundary, if any.
    pub checkpoint: Option<Checkpoint>,
}

/// Why a dead worker's shard could not be rebuilt from a checkpoint.
/// Re-replication is only as good as the last persisted boundary; every
/// way it can fall short is a distinct, matchable variant.
#[derive(Debug)]
pub enum ShardRecoveryError {
    /// Nothing at the path — no checkpoint was ever persisted.
    NoCheckpoint { path: PathBuf },
    /// A checkpoint exists but its θ-version boundary is not the
    /// junction the survivors froze at — resuming from it would fork
    /// the dead stage's history.
    StaleCheckpoint { path: PathBuf, found: u64, needed: u64 },
    /// The checkpoint does not contain the dead worker's stage at all.
    ShardUncovered { stage: usize, n_stages: usize },
    /// Unreadable, corrupt, or written under a different rule/layout.
    Invalid { path: PathBuf, source: anyhow::Error },
}

impl fmt::Display for ShardRecoveryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::NoCheckpoint { path } => write!(
                f,
                "no checkpoint covers the lost shard: {path:?} does not exist \
                 (set ZeroOpts::save_checkpoint_to to persist one)"
            ),
            Self::StaleCheckpoint { path, found, needed } => write!(
                f,
                "checkpoint {path:?} is at θ-version boundary {found} but the \
                 survivors froze at {needed} — the lost shard cannot be \
                 rebuilt bit-identically from it"
            ),
            Self::ShardUncovered { stage, n_stages } => write!(
                f,
                "checkpoint holds {n_stages} stage(s); stage {stage} is not \
                 covered"
            ),
            Self::Invalid { path, source } => {
                write!(f, "checkpoint {path:?} unusable for shard recovery: {source:#}")
            }
        }
    }
}

impl std::error::Error for ShardRecoveryError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Invalid { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

/// One stage's model states lifted out of a persisted checkpoint.
pub struct RecoveredShard {
    pub cur: Vec<f32>,
    pub prev: Vec<f32>,
    pub moms: Vec<f32>,
}

/// Rebuild stage `stage`'s shard (θ_t, θ_{t−1}, momentum) from the
/// checkpoint at `path`, for a run whose survivors froze at θ-version
/// boundary `junction`.  The checkpoint must match the run's rule and
/// layout and sit exactly at the junction — anything less is a typed
/// [`ShardRecoveryError`], never a silently-forked history.
pub fn recover_shard(
    path: &Path,
    layout: &ArenaLayout,
    rule: &Rule,
    stage: usize,
    junction: u64,
) -> Result<RecoveredShard, ShardRecoveryError> {
    if !path.exists() {
        return Err(ShardRecoveryError::NoCheckpoint { path: path.to_path_buf() });
    }
    let ck = Checkpoint::load(path)
        .map_err(|source| ShardRecoveryError::Invalid { path: path.to_path_buf(), source })?;
    if stage >= ck.stage_lens.len() {
        return Err(ShardRecoveryError::ShardUncovered {
            stage,
            n_stages: ck.stage_lens.len(),
        });
    }
    let want: Vec<u64> = (0..layout.n_stages())
        .map(|s| layout.stage_len(s) as u64)
        .collect();
    if ck.rule != rule.name() || ck.stage_lens != want {
        return Err(ShardRecoveryError::Invalid {
            path: path.to_path_buf(),
            source: anyhow::anyhow!(
                "written under rule `{}` with layout {:?}; this run is rule `{}` \
                 with layout {:?}",
                ck.rule,
                ck.stage_lens,
                rule.name(),
                want
            ),
        });
    }
    if ck.step != junction {
        return Err(ShardRecoveryError::StaleCheckpoint {
            path: path.to_path_buf(),
            found: ck.step,
            needed: junction,
        });
    }
    let range = layout.stage_range(stage);
    Ok(RecoveredShard {
        cur: ck.cur[range.clone()].to_vec(),
        prev: ck.prev[range.clone()].to_vec(),
        moms: ck.moms[range].to_vec(),
    })
}

/// Param version a worker must use for (mb i, stage j) under the rule.
fn needed_version(rule: &Rule, i: usize, j: usize, n: usize) -> Version {
    rule.version(i, j + 1, n)
}

/// Flat parameter run for stage `j` as worker `w` (micro-batch `i`) must
/// see it: the locally-owned version for its own stage, the received
/// payload otherwise.
#[allow(clippy::too_many_arguments)]
fn stage_run<'a>(
    j: usize,
    w: usize,
    i: usize,
    n: usize,
    rule: &Rule,
    own_cur: &'a [f32],
    own_prev: &'a [f32],
    recv: &'a [Option<Payload>],
) -> Result<&'a [f32]> {
    if j == w {
        Ok(match needed_version(rule, i, w, n) {
            Version::Fresh => own_cur,
            Version::Stale => own_prev,
        })
    } else {
        recv[j]
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("worker {w}: stage {j} params never arrived"))
    }
}

/// How a worker's owned shard comes to exist at phase start.
enum WorkerInit {
    /// Slice the backend's initial parameters (step 0).
    Fresh,
    /// Re-shard a full checkpoint (validated against layout + rule).
    Resume(Checkpoint),
    /// Adopt an already-sharded state at θ-version boundary `t0` — a
    /// survivor's handoff, or the recovered shard of a dead worker.
    Shard { t0: u64, cur: Vec<f32>, prev: Vec<f32>, moms: Vec<f32> },
}

/// A survivor's owned shard, frozen at the junction where the victim's
/// silence was detected.  Phase 2 resumes every worker from here.
struct ShardHandoff {
    at_step: u64,
    cur: Vec<f32>,
    prev: Vec<f32>,
    moms: Vec<f32>,
}

struct WorkerOut {
    logs: Vec<StepLog>,
    peak_state: u64,
    checkpoint: Option<Checkpoint>,
    handoff: Option<ShardHandoff>,
}

pub fn train<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
) -> Result<ZeroReport> {
    train_with(rt, rule, flow, steps, ZeroOpts::default())
}

pub fn train_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
) -> Result<ZeroReport> {
    run(rt, rule, flow, steps, opts, None)
}

/// Continue from a θ-version-boundary checkpoint, re-sharding the saved
/// state: step `ck.step` onward is bit-identical to the run that produced
/// it.  This is ZeRO's full-restart degraded mode; for a single lost
/// worker the cheaper path is shard re-replication (scripted kill +
/// [`ZeroOpts::recover_from`]), which rebuilds only the dead stage.
pub fn resume_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
    ck: Checkpoint,
) -> Result<ZeroReport> {
    run(rt, rule, flow, steps, opts, Some(ck))
}

/// Run one ZeRO worker against an externally-built endpoint — the entry
/// point for multi-process launches, where each OS process owns exactly
/// one endpoint over a wire transport.  Returns (step logs, peak state
/// bytes, checkpoint); logs and checkpoint are only populated on worker
/// 0.  Scripted kills are an in-process orchestration (the two-phase
/// re-replication needs a shared junction) and are rejected here — real
/// processes die for real and resume from a checkpoint.
pub fn run_worker<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
    resume: Option<&Checkpoint>,
    ep: &mut Endpoint,
) -> Result<(Vec<StepLog>, u64, Option<Checkpoint>)> {
    let n = rt.manifest().n_stages;
    anyhow::ensure!(ep.n == n, "fabric size {} != manifest stages {n}", ep.n);
    anyhow::ensure!(
        n == rt.manifest().n_microbatches,
        "ZeRO sharding assumes N stages == N workers"
    );
    if let Some(plan) = opts.faults {
        anyhow::ensure!(
            plan.kill.is_none(),
            "scripted kills are an in-process orchestration; over a wire, \
             kill the process and resume it from a checkpoint"
        );
    }
    let init = match resume {
        Some(ck) => WorkerInit::Resume(ck.clone()),
        None => WorkerInit::Fresh,
    };
    let w = ep.id;
    let out = worker(rt, rule, flow, ep, w, steps, &opts, init)?;
    Ok((out.logs, out.peak_state, out.checkpoint))
}

struct PhaseOut {
    outs: Vec<WorkerOut>,
    bytes: u64,
    messages: u64,
}

/// One fabric lifetime: build endpoints (with the phase's fault plan),
/// seat every worker's initial shard state, run them to completion.
fn run_phase<B: Backend + Send + Sync + 'static>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    flow: StateFlow,
    steps: usize,
    opts: &ZeroOpts,
    inits: Vec<WorkerInit>,
) -> Result<PhaseOut> {
    let n = rt.manifest().n_stages;
    let (endpoints, stats) = match opts.faults {
        Some(plan) => {
            let (eps, stats, _inj) = Fabric::with_faults(n, plan);
            (eps, stats)
        }
        None => Fabric::new(n),
    };
    let eps: Arc<Vec<Mutex<Option<Endpoint>>>> =
        Arc::new(endpoints.into_iter().map(|e| Mutex::new(Some(e))).collect());
    let seats: Arc<Vec<Mutex<Option<WorkerInit>>>> =
        Arc::new(inits.into_iter().map(|i| Mutex::new(Some(i))).collect());

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let opts_c = opts.clone();
    let results = run_workers(n, move |w| -> Result<WorkerOut> {
        let mut ep = eps[w]
            .lock()
            .map_err(|_| anyhow::anyhow!("endpoint mutex poisoned for worker {w}"))?
            .take()
            .ok_or_else(|| anyhow::anyhow!("endpoint for worker {w} taken twice"))?;
        let init = seats[w]
            .lock()
            .map_err(|_| anyhow::anyhow!("init mutex poisoned for worker {w}"))?
            .take()
            .ok_or_else(|| anyhow::anyhow!("init for worker {w} taken twice"))?;
        worker(&rt_arc, &rule_c, flow, &mut ep, w, steps, &opts_c, init)
    });

    let mut outs = Vec::with_capacity(n);
    for (w, r) in results.into_iter().enumerate() {
        outs.push(r.with_context(|| format!("zero worker {w} failed"))?);
    }
    Ok(PhaseOut { outs, bytes: stats.bytes(), messages: stats.messages() })
}

fn run<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
    resume: Option<Checkpoint>,
) -> Result<ZeroReport> {
    let n = rt.manifest().n_stages;
    let n_mb = rt.manifest().n_microbatches;
    anyhow::ensure!(n == n_mb, "ZeRO sharding assumes N stages == N workers");
    let t0 = resume.as_ref().map(|c| c.step).unwrap_or(0);
    let kill = opts.faults.and_then(|p| p.kill);
    if let Some(k) = kill {
        anyhow::ensure!(
            n >= 2,
            "shard re-replication needs at least one survivor (n = {n})"
        );
        anyhow::ensure!(
            k.worker != 0,
            "ZeRO worker 0 is structural (logger + checkpoint assembler) and \
             may not be killed"
        );
        anyhow::ensure!(
            k.worker < n,
            "kill names worker {} but the fabric has {n}",
            k.worker
        );
        anyhow::ensure!(
            opts.recover_from.is_some(),
            "a ZeRO kill needs ZeroOpts::recover_from: the dead worker's \
             optimizer shard has no replica and must re-replicate from a \
             persisted checkpoint (pair checkpoint_at = kill_step − 1 with \
             save_checkpoint_to)"
        );
        anyhow::ensure!(
            k.at_step > t0 && k.at_step < t0 + steps as u64,
            "kill at step {} is outside this run's boundaries {}..{}",
            k.at_step,
            t0 + 1,
            t0 + steps as u64
        );
    }

    let inits: Vec<WorkerInit> = match resume {
        Some(ck) => (0..n).map(|_| WorkerInit::Resume(ck.clone())).collect(),
        None => (0..n).map(|_| WorkerInit::Fresh).collect(),
    };
    let p1 = run_phase(&rt, &rule, flow, steps, &opts, inits)?;
    let mut outs = p1.outs;
    let mut comm_bytes = p1.bytes;
    let mut comm_messages = p1.messages;
    let mut logs = std::mem::take(&mut outs[0].logs);
    let mut checkpoint = outs[0].checkpoint.take();
    let mut peak = outs.iter().map(|o| o.peak_state).max().unwrap_or(0);

    if let Some(k) = kill {
        // ---- phase 2: re-replicate the dead shard, resume the fleet ----
        // Every survivor froze at the junction with its shard in hand; the
        // victim's shard is rebuilt from the persisted checkpoint.  The
        // second fabric re-arms the data-plane faults minus the kill.
        let junction = match outs[0].handoff.as_ref() {
            Some(h) => h.at_step,
            None => anyhow::bail!("scripted kill at step {} never fired", k.at_step),
        };
        let done = (junction - t0) as usize;
        let remaining = steps - done;
        let layout = ArenaLayout::from_manifest(rt.manifest());
        let path = opts
            .recover_from
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("recover_from vanished after validation"))?;
        let shard = recover_shard(path, &layout, &rule, k.worker, junction)?;

        let mut seats: Vec<Option<WorkerInit>> = (0..n).map(|_| None).collect();
        for (w, out) in outs.iter_mut().enumerate() {
            if let Some(h) = out.handoff.take() {
                anyhow::ensure!(
                    h.at_step == junction,
                    "worker {w} froze at step {} but worker 0 froze at \
                     {junction} — survivors disagree on the junction",
                    h.at_step
                );
                seats[w] = Some(WorkerInit::Shard {
                    t0: junction,
                    cur: h.cur,
                    prev: h.prev,
                    moms: h.moms,
                });
            }
        }
        seats[k.worker] = Some(WorkerInit::Shard {
            t0: junction,
            cur: shard.cur,
            prev: shard.prev,
            moms: shard.moms,
        });
        let inits2: Vec<WorkerInit> = seats
            .into_iter()
            .enumerate()
            .map(|(w, s)| {
                s.ok_or_else(|| anyhow::anyhow!("worker {w} returned no shard handoff"))
            })
            .collect::<Result<_>>()?;

        let opts2 = ZeroOpts {
            faults: opts.faults.map(|p| FaultPlan { kill: None, ..p }),
            ..opts.clone()
        };
        let p2 = run_phase(&rt, &rule, flow, remaining, &opts2, inits2)?;
        comm_bytes += p2.bytes;
        comm_messages += p2.messages;
        let mut outs2 = p2.outs;
        logs.extend(std::mem::take(&mut outs2[0].logs));
        checkpoint = checkpoint.or_else(|| outs2[0].checkpoint.take());
        peak = peak.max(outs2.iter().map(|o| o.peak_state).max().unwrap_or(0));
    }

    // Parameter-broadcast concurrency per time step: in Broadcast mode the
    // owner emits N−1 messages within one time step; in Cyclic mode the
    // staggering guarantees one message per time step (see sim::schemes for
    // the step-exact discrete model).
    let max_msgs = match flow {
        StateFlow::Broadcast => (n as u64 - 1).max(1),
        StateFlow::Cyclic => 1,
    };

    Ok(ZeroReport {
        logs,
        comm_bytes,
        comm_messages,
        max_msgs_per_timestep: max_msgs,
        peak_state_bytes: peak,
        checkpoint,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    flow: StateFlow,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: &ZeroOpts,
    init: WorkerInit,
) -> Result<WorkerOut> {
    let n = rt.manifest().n_stages;
    let n_mb = ep.n;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    // Owner state: stage `w` params (current + previous version), momentum
    // and the next-step slot — flat stage runs, allocated once.  On resume
    // each worker re-shards its slices from the checkpoint (validated
    // against this layout + rule via the transient full store); a Shard
    // init adopts an already-sharded state (survivor handoff or recovery).
    let range = layout.stage_range(w);
    let (mut own_cur, mut own_prev, mut own_mom, t0): (Vec<f32>, Vec<f32>, Vec<f32>, u64) =
        match init {
            WorkerInit::Resume(ck) => {
                let full = ck.into_store(layout.clone(), rule)?;
                trace::instant(
                    TraceKind::CkptResume,
                    Fields { worker: w as u32, step: full.step(), ..Fields::default() },
                );
                (
                    full.flat_params()[range.clone()].to_vec(),
                    full.stale_flat()[range.clone()].to_vec(),
                    full.momentum_flat()[range.clone()].to_vec(),
                    full.step(),
                )
            }
            WorkerInit::Fresh => {
                let init = rt.init_params_flat()?;
                let cur = init[range.clone()].to_vec();
                let prev = cur.clone();
                let mom = vec![0.0; cur.len()];
                (cur, prev, mom, 0)
            }
            WorkerInit::Shard { t0, cur, prev, moms } => {
                anyhow::ensure!(
                    cur.len() == range.len()
                        && prev.len() == range.len()
                        && moms.len() == range.len(),
                    "worker {w}: handed a {}-element shard, stage needs {}",
                    cur.len(),
                    range.len()
                );
                (cur, prev, moms, t0)
            }
        };
    let mut own_next: Vec<f32> = vec![0.0; own_cur.len()];
    let own_bytes: u64 = own_cur.len() as u64 * 4;
    // cur + prev + next slot + momentum — all four are persistent
    let mut peak_state: u64 = 4 * own_bytes;
    // Owner-side reduction scratch, reused every step.
    let mut gsum: Vec<f32> = vec![0.0; own_cur.len()];
    // This worker's own micro-batch gradients, model-wide flat scratch.
    // Pool warm-up + composition as in the ring worker: ZeRO workers are
    // threads, kernels parallelize inside whichever worker grabs the pool.
    crate::util::par::warm();
    let mut gmb = layout.zeros_aligned();
    let mut exec = rt.executor(opts.mode);
    let reducer = BucketedReducer::new(opts.bucket_elems);

    let data = DataSource::from_manifest(rt.manifest());
    let mut logs = Vec::new();
    let mut checkpoint = None;
    let i = w + 1; // this worker's micro-batch index (1-based)

    let my_kill = ep.injector().and_then(|inj| inj.kill_step_for(w));
    // heartbeats run only under a kill script; one kill per plan, and the
    // whole fleet freezes at the junction on detection, so there is no
    // post-loss exchange to keep alive
    let hb_active =
        ep.injector().map(|inj| inj.plan().kill.is_some()).unwrap_or(false);
    let peers: Vec<usize> = (0..n_mb).filter(|p| *p != w).collect();

    for t in t0..t0 + steps as u64 {
        if my_kill == Some(t) {
            // scripted crash: vanish at the θ-version boundary without a
            // word — peers must detect the silence, not be told
            trace::instant(
                TraceKind::Kill,
                Fields { worker: w as u32, step: t, ..Fields::default() },
            );
            return Ok(WorkerOut { logs, peak_state, checkpoint, handoff: None });
        }
        let t_step = trace::start();
        trace::instant(
            TraceKind::StepBegin,
            Fields { worker: w as u32, step: t, ..Fields::default() },
        );
        if hb_active {
            trace::instant(
                TraceKind::Heartbeat,
                Fields { worker: w as u32, step: t, ..Fields::default() },
            );
            for &p in &peers {
                // a send error already proves the peer is gone; the recv
                // sweep below records it
                let _ = ep.send(p, tags::hb(t), vec![1.0]);
            }
            let mut lost = false;
            for &p in &peers {
                if ep.recv_deadline(p, tags::hb(t), DETECT_DEADLINE).is_err() {
                    lost = true;
                }
            }
            if lost {
                // ZeRO cannot run degraded — the silent worker's stage has
                // no replica anywhere.  Freeze at this boundary and hand
                // the owned shard to the re-replication phase.
                return Ok(WorkerOut {
                    logs,
                    peak_state,
                    checkpoint,
                    handoff: Some(ShardHandoff {
                        at_step: t,
                        cur: own_cur,
                        prev: own_prev,
                        moms: own_mom,
                    }),
                });
            }
        }

        // ---- parameter distribution -----------------------------------
        // Worker w needs θ̂^j for every stage j.  Owners send; everyone
        // receives what they don't own.
        //
        // Both flows move the same bytes; Cyclic attributes sends to
        // distinct time steps (one peer per step) while Broadcast sends
        // all N−1 at once.  The fabric counts bytes/messages; the
        // step-concurrency difference is scored in `run` above and in
        // sim::schemes.  Each needed version is copied into *one* pooled
        // payload whose handle fans out to every peer wanting it.
        let order: Vec<usize> = match flow {
            // broadcast: all peers at once (rank order)
            StateFlow::Broadcast => (0..n_mb).filter(|p| *p != w).collect(),
            // cyclic: peers in the order their mb reaches stage w —
            // mb i computes stage j at local time; the staggering means
            // peer order is ring order starting after the owner
            StateFlow::Cyclic => (1..n_mb).map(|d| (w + d) % n_mb).collect(),
        };
        let pool = ep.pool().clone();
        let mut fresh_payload: Option<Payload> = None;
        let mut stale_payload: Option<Payload> = None;
        for peer in order {
            let pi = peer + 1;
            let payload = match needed_version(rule, pi, w, n) {
                Version::Fresh => fresh_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_cur))
                    .clone(),
                Version::Stale => stale_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_prev))
                    .clone(),
            };
            trace::instant(
                TraceKind::ParamSend,
                Fields {
                    worker: w as u32,
                    stage: w as u32,
                    step: t,
                    bytes: payload.len() as u64 * 4,
                    ..Fields::default()
                },
            );
            ep.send(peer, tags::param(t, w), payload)
                .with_context(|| format!("owner {w}: param hand-off, step {t}"))?;
        }

        // Receive the other stages' params from their owners; my own stage
        // selects locally from the flat runs.
        let mut recv_params: Vec<Option<Payload>> = vec![None; n];
        let mut recv_bytes: u64 = 0;
        for j in 0..n {
            if j == w {
                continue;
            }
            let payload = ep
                .recv(j, tags::param(t, j))
                .with_context(|| format!("worker {w}: stage params, step {t}"))?;
            recv_bytes += payload.len() as u64 * 4;
            trace::instant(
                TraceKind::ParamRecv,
                Fields {
                    worker: w as u32,
                    stage: j as u32,
                    step: t,
                    bytes: payload.len() as u64 * 4,
                    ..Fields::default()
                },
            );
            recv_params[j] = Some(payload);
        }
        // ZeRO memory property: a worker transiently holds its own states
        // + the received stage params (released after use).
        peak_state = peak_state.max(4 * own_bytes + recv_bytes);

        // ---- compute: fwd chain for micro-batch i ----------------------
        let mb = data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match mb {
            MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
            MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
        };
        let mut acts: Vec<B::Act> = Vec::with_capacity(n);
        acts.push(rt.input(&mut exec, x0)?);
        for j in 0..n - 1 {
            let ver = version_id(rule, t, i, j, n);
            let p = stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params)?;
            let t_fwd = trace::start();
            let y = rt.fwd(&mut exec, j, ver, p, &acts[j])?;
            trace::span(
                TraceKind::Fwd,
                t_fwd,
                Fields {
                    worker: w as u32,
                    stage: j as u32,
                    step: t,
                    version: ver,
                    ..Fields::default()
                },
            );
            // stage j's output is stashed until stage j+1's backward
            trace::instant(
                TraceKind::ActAlloc,
                Fields {
                    worker: w as u32,
                    stage: j as u32,
                    step: t,
                    bytes: rt.manifest().stages[j].act_bytes,
                    ..Fields::default()
                },
            );
            acts.push(y);
        }

        // ---- backward chain with eager bucketed shard sends ------------
        // Stage j's gradients fly to owner j bucket by bucket the moment
        // they land; stages below j keep backpropagating meanwhile.  The
        // own-stage slice stays local for the in-order reduction below.
        let free_act = |j: usize| {
            // stage j's backward consumed stage j−1's stashed output (the
            // raw input at j == 0 was never counted by ActAlloc)
            if j > 0 {
                trace::instant(
                    TraceKind::ActFree,
                    Fields {
                        worker: w as u32,
                        stage: (j - 1) as u32,
                        step: t,
                        bytes: rt.manifest().stages[j - 1].act_bytes,
                        ..Fields::default()
                    },
                );
            }
        };
        let last = n - 1;
        let ver = version_id(rule, t, i, last, n);
        let (loss, mut gx) = rt.last_bwd(
            &mut exec,
            ver,
            stage_run(last, w, i, n, rule, &own_cur, &own_prev, &recv_params)?,
            &acts[last],
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        ep.stats().mark(EventKind::BwdStageDone, w, last, t, 0);
        free_act(last);
        if last != w {
            reducer
                .shard_send(ep, &layout, t, last, i, last, &gmb[layout.stage_range(last)])
                .with_context(|| format!("worker {w}: shard send, step {t} stage {last}"))?;
        }
        for j in (1..last).rev() {
            let ver = version_id(rule, t, i, j, n);
            gx = rt.mid_bwd(
                &mut exec,
                j,
                ver,
                stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params)?,
                &acts[j],
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
            ep.stats().mark(EventKind::BwdStageDone, w, j, t, 0);
            free_act(j);
            if j != w {
                reducer
                    .shard_send(ep, &layout, t, j, i, j, &gmb[layout.stage_range(j)])
                    .with_context(|| format!("worker {w}: shard send, step {t} stage {j}"))?;
            }
        }
        if n > 1 {
            let ver = version_id(rule, t, i, 0, n);
            rt.first_bwd(
                &mut exec,
                ver,
                stage_run(0, w, i, n, rule, &own_cur, &own_prev, &recv_params)?,
                &acts[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
            ep.stats().mark(EventKind::BwdStageDone, w, 0, t, 0);
            if w != 0 {
                reducer
                    .shard_send(ep, &layout, t, 0, i, 0, &gmb[layout.stage_range(0)])
                    .with_context(|| format!("worker {w}: shard send, step {t} stage 0"))?;
            }
        }
        drop(recv_params); // release received payloads back to the pool

        // ---- owner-side reduction (micro-batch order 1..N) -------------
        reducer
            .shard_reduce(
                ep,
                &layout,
                t,
                w,
                i,
                n_mb,
                &gmb[layout.stage_range(w)],
                &mut gsum,
            )
            .with_context(|| format!("owner {w}: shard reduce, step {t}"))?;

        // ---- owner update ----------------------------------------------
        let t_sgd = trace::start();
        rt.sgd(
            &mut exec,
            w,
            t,
            &own_cur,
            &mut own_mom,
            &gsum,
            rt.manifest().lr,
            &mut own_next,
        )?;
        trace::span(
            TraceKind::Sgd,
            t_sgd,
            Fields { worker: w as u32, stage: w as u32, step: t, ..Fields::default() },
        );
        std::mem::swap(&mut own_prev, &mut own_cur); // prev ← θ_t
        std::mem::swap(&mut own_cur, &mut own_next); // cur ← θ_{t+1}

        // ---- checkpoint at the fresh θ-version boundary ----------------
        // The shards converge on worker 0 over the control plane (exempt
        // from fault injection): three messages per non-zero worker, one
        // per arena part.
        if opts.checkpoint_at == Some(t) {
            if w != 0 {
                for (part, run) in
                    [(0usize, &own_cur), (1, &own_prev), (2, &own_mom)]
                {
                    ep.send_copy(0, tags::ckpt(t, w, part), run)
                        .with_context(|| format!("worker {w}: checkpoint shard, step {t}"))?;
                }
            } else {
                let mut cur = layout.zeros();
                let mut prev = layout.zeros();
                let mut moms = layout.zeros();
                cur[range.clone()].copy_from_slice(&own_cur);
                prev[range.clone()].copy_from_slice(&own_prev);
                moms[range.clone()].copy_from_slice(&own_mom);
                for peer in 1..n_mb {
                    let pr = layout.stage_range(peer);
                    for (part, dst) in
                        [(0usize, &mut cur), (1, &mut prev), (2, &mut moms)]
                    {
                        let p = ep.recv(peer, tags::ckpt(t, peer, part)).with_context(
                            || format!("worker 0: checkpoint shard from {peer}, step {t}"),
                        )?;
                        dst[pr.clone()].copy_from_slice(&p);
                    }
                }
                let ck = Checkpoint::from_arenas(&layout, rule, t + 1, cur, prev, moms);
                if let Some(path) = &opts.save_checkpoint_to {
                    ck.save(path)
                        .with_context(|| format!("worker 0: persist checkpoint, step {t}"))?;
                }
                checkpoint = Some(ck);
                trace::instant(
                    TraceKind::CkptSave,
                    Fields { worker: w as u32, step: t, ..Fields::default() },
                );
            }
        }

        // ---- loss reporting (worker 0 logs the canonical mean) ---------
        if w == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                let p = ep
                    .recv(from, tags::loss(t))
                    .with_context(|| format!("worker 0: loss gather, step {t}"))?;
                sum += p[0] as f64;
            }
            let mean = sum / n_mb as f64;
            trace::loss(0, t, mean);
            logs.push(StepLog { step: t, loss: mean });
        } else {
            ep.send(0, tags::loss(t), vec![loss])
                .with_context(|| format!("worker {w}: loss report, step {t}"))?;
        }
        trace::span(
            TraceKind::StepEnd,
            t_step,
            Fields { worker: w as u32, step: t, ..Fields::default() },
        );
    }
    Ok(WorkerOut { logs, peak_state, checkpoint, handoff: None })
}
