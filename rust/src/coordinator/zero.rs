//! ZeRO-DP trainer (paper §4.4): model states sharded by stage — worker j
//! is the *owner* of stage j's parameters, gradients and optimizer state;
//! no worker holds a full replica.
//!
//! - **Broadcast mode (standard ZeRO-DP)**: before computing a stage, the
//!   owner broadcasts its parameters to all N workers *simultaneously* (a
//!   collective, ≥ O(log N) steps between two time steps).  After the
//!   backward, gradients reduce to the owner, which updates.
//! - **Cyclic mode (ZeRO + CDP)**: micro-batches run staggered, so at any
//!   time step exactly one worker computes stage j — the owner sends the
//!   model states to *one* worker per time step (pure point-to-point), and
//!   the updated parameters hop the same way.  Volume is unchanged (Ψ_P per
//!   step per worker-visit) but the per-time-step message count drops from
//!   N−1 to 1 — the paper's bold entry in Table 1.
//!
//! Hot-path layout (DESIGN-PERF.md): the owned shard is a flat stage
//! arena (cur/prev/next/momentum runs); non-owned stage parameters are
//! *received payloads* used directly as flat parameter runs — no
//! per-tensor rebuild.  Serving peers builds at most one pooled payload
//! per version and fans the handle out (zero-copy for the broadcast).
//!
//! Measured here: comm bytes, total messages, and `max_msgs_per_timestep`
//! (the schedule-attributed concurrency that distinguishes the two modes).
//! Loss sequences match the reference trainer bit-for-bit.

use anyhow::Result;

use super::{SharedRuntime, StepLog};
use crate::cluster::run_workers;
use crate::comm::{tags, Endpoint, Fabric, Payload};
use crate::parallel::arena::ArenaLayout;
use crate::data::{DataSource, MicroBatch};
use crate::parallel::{Rule, Version};
use crate::tensor::{ops, HostTensor};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateFlow {
    /// Owner broadcasts stage params to all workers each step (ZeRO-DP).
    Broadcast,
    /// Owner hands params to one worker per time step (ZeRO + CDP).
    Cyclic,
}

pub struct ZeroReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Max parameter-messages attributable to a single time step.
    pub max_msgs_per_timestep: u64,
    /// Peak per-worker model-state bytes (params it holds at once).
    pub peak_state_bytes: u64,
}

/// Param version a worker must use for (mb i, stage j) under the rule.
fn needed_version(rule: &Rule, i: usize, j: usize, n: usize) -> Version {
    rule.version(i, j + 1, n)
}

/// Flat parameter run for stage `j` as worker `w` (micro-batch `i`) must
/// see it: the locally-owned version for its own stage, the received
/// payload otherwise.
#[allow(clippy::too_many_arguments)]
fn stage_run<'a>(
    j: usize,
    w: usize,
    i: usize,
    n: usize,
    rule: &Rule,
    own_cur: &'a [f32],
    own_prev: &'a [f32],
    recv: &'a [Option<Payload>],
) -> &'a [f32] {
    if j == w {
        match needed_version(rule, i, w, n) {
            Version::Fresh => own_cur,
            Version::Stale => own_prev,
        }
    } else {
        recv[j].as_ref().expect("stage params received")
    }
}

pub fn train(
    rt: SharedRuntime,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
) -> Result<ZeroReport> {
    let n = rt.manifest.n_stages;
    let n_mb = rt.manifest.n_microbatches;
    assert_eq!(n, n_mb, "ZeRO sharding assumes N stages == N workers");
    let (endpoints, stats) = Fabric::new(n);
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        endpoints.into_iter().map(|e| std::sync::Mutex::new(Some(e))).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().unwrap();
        worker(&rt_arc, &rule_c, flow, &mut ep, w, steps).expect("zero worker failed")
    });

    let (logs, peaks): (Vec<_>, Vec<u64>) = {
        let mut logs = Vec::new();
        let mut peaks = Vec::new();
        for (w, (l, p)) in results.into_iter().enumerate() {
            if w == 0 {
                logs = l;
            }
            peaks.push(p);
        }
        (logs, peaks)
    };

    // Parameter-broadcast concurrency per time step: in Broadcast mode the
    // owner emits N−1 messages within one time step; in Cyclic mode the
    // staggering guarantees one message per time step (see sim::schemes for
    // the step-exact discrete model).
    let max_msgs = match flow {
        StateFlow::Broadcast => (n as u64 - 1).max(1),
        StateFlow::Cyclic => 1,
    };

    Ok(ZeroReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        max_msgs_per_timestep: max_msgs,
        peak_state_bytes: peaks.into_iter().max().unwrap_or(0),
    })
}

fn worker(
    rt: &SharedRuntime,
    rule: &Rule,
    flow: StateFlow,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
) -> Result<(Vec<StepLog>, u64)> {
    let n = rt.manifest.n_stages;
    let n_mb = ep.n;
    let layout = ArenaLayout::from_manifest(&rt.manifest);
    let init = rt.init_params_flat()?;
    // Owner state: stage `w` params (current + previous version), momentum
    // and the next-step slot — flat stage runs, allocated once.
    let mut own_cur: Vec<f32> = init[layout.stage_range(w)].to_vec();
    let mut own_prev: Vec<f32> = own_cur.clone();
    let mut own_next: Vec<f32> = vec![0.0; own_cur.len()];
    let mut own_mom: Vec<f32> = vec![0.0; own_cur.len()];
    let own_bytes: u64 = own_cur.len() as u64 * 4;
    // cur + prev + next slot + momentum — all four are persistent
    let mut peak_state: u64 = 4 * own_bytes;
    // Owner-side reduction scratch, reused every step.
    let mut gsum: Vec<f32> = vec![0.0; own_cur.len()];
    // This worker's own micro-batch gradients, model-wide flat scratch.
    let mut gmb: Vec<f32> = layout.zeros();

    let data = DataSource::from_manifest(&rt.manifest);
    let mut logs = Vec::new();
    let i = w + 1; // this worker's micro-batch index (1-based)

    for t in 0..steps as u64 {
        // ---- parameter distribution -----------------------------------
        // Worker w needs θ̂^j for every stage j.  Owners send; everyone
        // receives what they don't own.
        //
        // Both flows move the same bytes; Cyclic attributes sends to
        // distinct time steps (one peer per step) while Broadcast sends
        // all N−1 at once.  The fabric counts bytes/messages; the
        // step-concurrency difference is scored in `train` above and in
        // sim::schemes.  Each needed version is copied into *one* pooled
        // payload whose handle fans out to every peer wanting it.
        let order: Vec<usize> = match flow {
            // broadcast: all peers at once (rank order)
            StateFlow::Broadcast => (0..n_mb).filter(|p| *p != w).collect(),
            // cyclic: peers in the order their mb reaches stage w —
            // mb i computes stage j at local time; the staggering means
            // peer order is ring order starting after the owner
            StateFlow::Cyclic => (1..n_mb).map(|d| (w + d) % n_mb).collect(),
        };
        let pool = ep.pool().clone();
        let mut fresh_payload: Option<Payload> = None;
        let mut stale_payload: Option<Payload> = None;
        for peer in order {
            let pi = peer + 1;
            let payload = match needed_version(rule, pi, w, n) {
                Version::Fresh => fresh_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_cur))
                    .clone(),
                Version::Stale => stale_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_prev))
                    .clone(),
            };
            ep.send(peer, tags::param(t, w), payload);
        }

        // Receive the other stages' params from their owners; my own stage
        // selects locally from the flat runs.
        let mut recv_params: Vec<Option<Payload>> = vec![None; n];
        let mut recv_bytes: u64 = 0;
        for j in 0..n {
            if j == w {
                continue;
            }
            let payload = ep.recv(j, tags::param(t, j));
            recv_bytes += payload.len() as u64 * 4;
            recv_params[j] = Some(payload);
        }
        // ZeRO memory property: a worker transiently holds its own states
        // + the received stage params (released after use).
        peak_state = peak_state.max(4 * own_bytes + recv_bytes);

        // ---- compute: fwd chain + bwd chain for micro-batch i ----------
        let mb = data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match &mb {
            MicroBatch::Lm { tokens, targets } => {
                (HostTensor::I32(tokens.clone()), targets.clone())
            }
            MicroBatch::Class { x, labels } => {
                (HostTensor::F32(x.clone()), labels.clone())
            }
        };
        let mut inputs: Vec<HostTensor> = vec![x0];
        for j in 0..n - 1 {
            let p = stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params);
            let y = rt.stage_fwd_flat(j, p, &inputs[j])?;
            inputs.push(HostTensor::F32(y));
        }
        let last = n - 1;
        let (loss, mut gx) = rt.last_bwd_flat(
            stage_run(last, w, i, n, rule, &own_cur, &own_prev, &recv_params),
            inputs[last].as_f32().unwrap(),
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        for j in (1..last).rev() {
            gx = rt.mid_bwd_flat(
                j,
                stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params),
                inputs[j].as_f32().unwrap(),
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
        }
        if n > 1 {
            rt.first_bwd_flat(
                stage_run(0, w, i, n, rule, &own_cur, &own_prev, &recv_params),
                &inputs[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
        }
        drop(recv_params); // release received payloads back to the pool

        // ---- gradient reduction to owners (micro-batch order) ----------
        for j in 0..n {
            if j != w {
                ep.send_copy(j, tags::grad_part(t, j, i), &gmb[layout.stage_range(j)]);
            }
        }
        // Owner: reduce in mb order 1..N (self contribution in its slot).
        gsum.fill(0.0);
        for mb_i in 1..=n_mb {
            if mb_i == i {
                ops::add_into(&mut gsum, &gmb[layout.stage_range(w)]);
            } else {
                let part = ep.recv(mb_i - 1, tags::grad_part(t, w, mb_i));
                ops::add_into(&mut gsum, &part);
            }
        }
        ops::scale(&mut gsum, 1.0 / n_mb as f32);

        // ---- owner update ----------------------------------------------
        rt.sgd_update_flat(w, &own_cur, &mut own_mom, &gsum, rt.manifest.lr, &mut own_next)?;
        std::mem::swap(&mut own_prev, &mut own_cur); // prev ← θ_t
        std::mem::swap(&mut own_cur, &mut own_next); // cur ← θ_{t+1}

        // ---- loss reporting (worker 0 logs the canonical mean) ---------
        if w == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok((logs, peak_state))
}
