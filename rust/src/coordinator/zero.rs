//! ZeRO-DP trainer (paper §4.4): model states sharded by stage — worker j
//! is the *owner* of stage j's parameters, gradients and optimizer state;
//! no worker holds a full replica.
//!
//! - **Broadcast mode (standard ZeRO-DP)**: before computing a stage, the
//!   owner broadcasts its parameters to all N workers *simultaneously* (a
//!   collective, ≥ O(log N) steps between two time steps).  After the
//!   backward, gradients reduce to the owner, which updates.
//! - **Cyclic mode (ZeRO + CDP)**: micro-batches run staggered, so at any
//!   time step exactly one worker computes stage j — the owner sends the
//!   model states to *one* worker per time step (pure point-to-point), and
//!   the updated parameters hop the same way.  Volume is unchanged (Ψ_P per
//!   step per worker-visit) but the per-time-step message count drops from
//!   N−1 to 1 — the paper's bold entry in Table 1.
//!
//! Gradient reduction to the owners is *eager and bucketed*
//! (`comm::bucketed`): the moment stage j's backward output lands, its
//! buckets fly to owner j while the remaining backward keeps computing —
//! the shard communication is spread across the backward pass instead of
//! bursting at the step boundary.  Owners still reduce in micro-batch
//! order 1..N, so losses stay bit-identical to the reference trainer.
//!
//! Generic over [`Backend`].  On XLA, execution is device-resident by
//! default: the owned shard and every *received* stage's parameters are
//! cached as device buffers per θ-version (a received version uploads at
//! most once per step, and a version still resident from the previous
//! step re-uploads not at all); the owner's fused SGD promotes its
//! result to the next resident version.  Host mirrors remain
//! authoritative — the fabric serves and accounts the same bytes as
//! before, so the paper's comm numbers are unchanged by the execution
//! mode or backend.
//!
//! ## Robustness (DESIGN-ROBUSTNESS.md)
//!
//! Every receive carries the fabric deadline: a dead owner turns into a
//! typed [`crate::comm::CommError`] naming the peer and the decoded
//! param/shard tag.  Sharding makes N−1 re-forming structurally
//! impossible — a lost worker takes its stage's only optimizer state
//! with it — so the degraded mode here is *checkpoint and restart*:
//! [`ZeroOpts::checkpoint_at`] gathers the full model state to worker 0
//! at a θ-version boundary over the control plane, and [`resume_with`]
//! re-shards it bit-identically.  Seeded fault injection
//! ([`ZeroOpts::faults`]) leaves loss sequences bit-identical to clean
//! runs (retry + seq dedup); scripted kills are rejected.

use anyhow::{Context, Result};

use super::{version_id, ExecMode, SharedBackend, StepLog};
use crate::cluster::run_workers;
use crate::comm::bucketed::{bucket_elems_from_env, BucketedReducer};
use crate::comm::fault::FaultPlan;
use crate::comm::{tags, Endpoint, EventKind, Fabric, Payload};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{Checkpoint, Rule, Version};
use crate::runtime::Backend;
use crate::tensor::HostTensor;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateFlow {
    /// Owner broadcasts stage params to all workers each step (ZeRO-DP).
    Broadcast,
    /// Owner hands params to one worker per time step (ZeRO + CDP).
    Cyclic,
}

/// Knobs for [`train_with`]; [`Default`] is the production configuration.
#[derive(Clone, Copy, Debug)]
pub struct ZeroOpts {
    pub mode: ExecMode,
    /// Gradient bucket granularity for the eager shard sends (elements).
    pub bucket_elems: usize,
    /// Seeded fault injection on every non-control fabric edge.
    pub faults: Option<FaultPlan>,
    /// Capture a checkpoint at the θ-version boundary after this step
    /// (full state gathered to worker 0 over the control plane).
    pub checkpoint_at: Option<u64>,
}

impl Default for ZeroOpts {
    fn default() -> Self {
        Self {
            mode: ExecMode::from_env(ExecMode::DeviceResident),
            bucket_elems: bucket_elems_from_env(),
            faults: None,
            checkpoint_at: None,
        }
    }
}

pub struct ZeroReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Max parameter-messages attributable to a single time step.
    pub max_msgs_per_timestep: u64,
    /// Peak per-worker model-state bytes (params it holds at once).
    pub peak_state_bytes: u64,
    /// Captured at the [`ZeroOpts::checkpoint_at`] boundary, if any.
    pub checkpoint: Option<Checkpoint>,
}

/// Param version a worker must use for (mb i, stage j) under the rule.
fn needed_version(rule: &Rule, i: usize, j: usize, n: usize) -> Version {
    rule.version(i, j + 1, n)
}

/// Flat parameter run for stage `j` as worker `w` (micro-batch `i`) must
/// see it: the locally-owned version for its own stage, the received
/// payload otherwise.
#[allow(clippy::too_many_arguments)]
fn stage_run<'a>(
    j: usize,
    w: usize,
    i: usize,
    n: usize,
    rule: &Rule,
    own_cur: &'a [f32],
    own_prev: &'a [f32],
    recv: &'a [Option<Payload>],
) -> Result<&'a [f32]> {
    if j == w {
        Ok(match needed_version(rule, i, w, n) {
            Version::Fresh => own_cur,
            Version::Stale => own_prev,
        })
    } else {
        recv[j]
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("worker {w}: stage {j} params never arrived"))
    }
}

pub fn train<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
) -> Result<ZeroReport> {
    train_with(rt, rule, flow, steps, ZeroOpts::default())
}

pub fn train_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
) -> Result<ZeroReport> {
    run(rt, rule, flow, steps, opts, None)
}

/// Continue from a θ-version-boundary checkpoint, re-sharding the saved
/// state: step `ck.step` onward is bit-identical to the run that produced
/// it.  This is ZeRO's whole degraded mode — sharding means a lost worker
/// cannot be absorbed by the survivors (its optimizer shard died with it).
pub fn resume_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
    ck: Checkpoint,
) -> Result<ZeroReport> {
    run(rt, rule, flow, steps, opts, Some(ck))
}

fn run<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    flow: StateFlow,
    steps: usize,
    opts: ZeroOpts,
    resume: Option<Checkpoint>,
) -> Result<ZeroReport> {
    let n = rt.manifest().n_stages;
    let n_mb = rt.manifest().n_microbatches;
    anyhow::ensure!(n == n_mb, "ZeRO sharding assumes N stages == N workers");
    if let Some(plan) = opts.faults {
        anyhow::ensure!(
            plan.kill.is_none(),
            "ZeRO has no degraded ring — a killed worker takes its only \
             optimizer shard with it; recover via checkpoint_at + resume_with"
        );
    }
    let (endpoints, stats) = match opts.faults {
        Some(plan) => {
            let (eps, stats, _inj) = Fabric::with_faults(n, plan);
            (eps, stats)
        }
        None => Fabric::new(n),
    };
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        endpoints.into_iter().map(|e| std::sync::Mutex::new(Some(e))).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let resume = Arc::new(resume);
    let results = run_workers(
        n,
        move |w| -> Result<(Vec<StepLog>, u64, Option<Checkpoint>)> {
            let mut ep = eps[w]
                .lock()
                .map_err(|_| anyhow::anyhow!("endpoint mutex poisoned for worker {w}"))?
                .take()
                .ok_or_else(|| anyhow::anyhow!("endpoint for worker {w} taken twice"))?;
            worker(&rt_arc, &rule_c, flow, &mut ep, w, steps, opts, resume.as_ref().as_ref())
        },
    );

    let mut logs = Vec::new();
    let mut checkpoint = None;
    let mut peaks = Vec::new();
    for (w, r) in results.into_iter().enumerate() {
        let (l, p, ck) = r.with_context(|| format!("zero worker {w} failed"))?;
        if w == 0 {
            logs = l;
            checkpoint = ck;
        }
        peaks.push(p);
    }

    // Parameter-broadcast concurrency per time step: in Broadcast mode the
    // owner emits N−1 messages within one time step; in Cyclic mode the
    // staggering guarantees one message per time step (see sim::schemes for
    // the step-exact discrete model).
    let max_msgs = match flow {
        StateFlow::Broadcast => (n as u64 - 1).max(1),
        StateFlow::Cyclic => 1,
    };

    Ok(ZeroReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        max_msgs_per_timestep: max_msgs,
        peak_state_bytes: peaks.into_iter().max().unwrap_or(0),
        checkpoint,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    flow: StateFlow,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: ZeroOpts,
    resume: Option<&Checkpoint>,
) -> Result<(Vec<StepLog>, u64, Option<Checkpoint>)> {
    let n = rt.manifest().n_stages;
    let n_mb = ep.n;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    // Owner state: stage `w` params (current + previous version), momentum
    // and the next-step slot — flat stage runs, allocated once.  On resume
    // each worker re-shards its slices from the checkpoint (validated
    // against this layout + rule via the transient full store).
    let range = layout.stage_range(w);
    let (mut own_cur, mut own_prev, mut own_mom, t0): (Vec<f32>, Vec<f32>, Vec<f32>, u64) =
        match resume {
            Some(ck) => {
                let full = ck.clone().into_store(layout.clone(), rule)?;
                (
                    full.flat_params()[range.clone()].to_vec(),
                    full.stale_flat()[range.clone()].to_vec(),
                    full.momentum_flat()[range.clone()].to_vec(),
                    full.step(),
                )
            }
            None => {
                let init = rt.init_params_flat()?;
                let cur = init[range.clone()].to_vec();
                let prev = cur.clone();
                let mom = vec![0.0; cur.len()];
                (cur, prev, mom, 0)
            }
        };
    let mut own_next: Vec<f32> = vec![0.0; own_cur.len()];
    let own_bytes: u64 = own_cur.len() as u64 * 4;
    // cur + prev + next slot + momentum — all four are persistent
    let mut peak_state: u64 = 4 * own_bytes;
    // Owner-side reduction scratch, reused every step.
    let mut gsum: Vec<f32> = vec![0.0; own_cur.len()];
    // This worker's own micro-batch gradients, model-wide flat scratch.
    let mut gmb: Vec<f32> = layout.zeros();
    let mut exec = rt.executor(opts.mode);
    let reducer = BucketedReducer::new(opts.bucket_elems);

    let data = DataSource::from_manifest(rt.manifest());
    let mut logs = Vec::new();
    let mut checkpoint = None;
    let i = w + 1; // this worker's micro-batch index (1-based)

    for t in t0..t0 + steps as u64 {
        // ---- parameter distribution -----------------------------------
        // Worker w needs θ̂^j for every stage j.  Owners send; everyone
        // receives what they don't own.
        //
        // Both flows move the same bytes; Cyclic attributes sends to
        // distinct time steps (one peer per step) while Broadcast sends
        // all N−1 at once.  The fabric counts bytes/messages; the
        // step-concurrency difference is scored in `run` above and in
        // sim::schemes.  Each needed version is copied into *one* pooled
        // payload whose handle fans out to every peer wanting it.
        let order: Vec<usize> = match flow {
            // broadcast: all peers at once (rank order)
            StateFlow::Broadcast => (0..n_mb).filter(|p| *p != w).collect(),
            // cyclic: peers in the order their mb reaches stage w —
            // mb i computes stage j at local time; the staggering means
            // peer order is ring order starting after the owner
            StateFlow::Cyclic => (1..n_mb).map(|d| (w + d) % n_mb).collect(),
        };
        let pool = ep.pool().clone();
        let mut fresh_payload: Option<Payload> = None;
        let mut stale_payload: Option<Payload> = None;
        for peer in order {
            let pi = peer + 1;
            let payload = match needed_version(rule, pi, w, n) {
                Version::Fresh => fresh_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_cur))
                    .clone(),
                Version::Stale => stale_payload
                    .get_or_insert_with(|| pool.payload_from_slice(&own_prev))
                    .clone(),
            };
            ep.send(peer, tags::param(t, w), payload)
                .with_context(|| format!("owner {w}: param hand-off, step {t}"))?;
        }

        // Receive the other stages' params from their owners; my own stage
        // selects locally from the flat runs.
        let mut recv_params: Vec<Option<Payload>> = vec![None; n];
        let mut recv_bytes: u64 = 0;
        for j in 0..n {
            if j == w {
                continue;
            }
            let payload = ep
                .recv(j, tags::param(t, j))
                .with_context(|| format!("worker {w}: stage params, step {t}"))?;
            recv_bytes += payload.len() as u64 * 4;
            recv_params[j] = Some(payload);
        }
        // ZeRO memory property: a worker transiently holds its own states
        // + the received stage params (released after use).
        peak_state = peak_state.max(4 * own_bytes + recv_bytes);

        // ---- compute: fwd chain for micro-batch i ----------------------
        let mb = data.microbatch(t, (i - 1) as u64);
        let (x0, targets) = match mb {
            MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
            MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
        };
        let mut acts: Vec<B::Act> = Vec::with_capacity(n);
        acts.push(rt.input(&mut exec, x0)?);
        for j in 0..n - 1 {
            let ver = version_id(rule, t, i, j, n);
            let p = stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params)?;
            let y = rt.fwd(&mut exec, j, ver, p, &acts[j])?;
            acts.push(y);
        }

        // ---- backward chain with eager bucketed shard sends ------------
        // Stage j's gradients fly to owner j bucket by bucket the moment
        // they land; stages below j keep backpropagating meanwhile.  The
        // own-stage slice stays local for the in-order reduction below.
        let last = n - 1;
        let ver = version_id(rule, t, i, last, n);
        let (loss, mut gx) = rt.last_bwd(
            &mut exec,
            ver,
            stage_run(last, w, i, n, rule, &own_cur, &own_prev, &recv_params)?,
            &acts[last],
            &targets,
            &mut gmb[layout.stage_range(last)],
        )?;
        ep.stats().mark(EventKind::BwdStageDone, w, last, 0);
        if last != w {
            reducer
                .shard_send(ep, &layout, t, last, i, last, &gmb[layout.stage_range(last)])
                .with_context(|| format!("worker {w}: shard send, step {t} stage {last}"))?;
        }
        for j in (1..last).rev() {
            let ver = version_id(rule, t, i, j, n);
            gx = rt.mid_bwd(
                &mut exec,
                j,
                ver,
                stage_run(j, w, i, n, rule, &own_cur, &own_prev, &recv_params)?,
                &acts[j],
                &gx,
                &mut gmb[layout.stage_range(j)],
            )?;
            ep.stats().mark(EventKind::BwdStageDone, w, j, 0);
            if j != w {
                reducer
                    .shard_send(ep, &layout, t, j, i, j, &gmb[layout.stage_range(j)])
                    .with_context(|| format!("worker {w}: shard send, step {t} stage {j}"))?;
            }
        }
        if n > 1 {
            let ver = version_id(rule, t, i, 0, n);
            rt.first_bwd(
                &mut exec,
                ver,
                stage_run(0, w, i, n, rule, &own_cur, &own_prev, &recv_params)?,
                &acts[0],
                &gx,
                &mut gmb[layout.stage_range(0)],
            )?;
            ep.stats().mark(EventKind::BwdStageDone, w, 0, 0);
            if w != 0 {
                reducer
                    .shard_send(ep, &layout, t, 0, i, 0, &gmb[layout.stage_range(0)])
                    .with_context(|| format!("worker {w}: shard send, step {t} stage 0"))?;
            }
        }
        drop(recv_params); // release received payloads back to the pool

        // ---- owner-side reduction (micro-batch order 1..N) -------------
        reducer
            .shard_reduce(
                ep,
                &layout,
                t,
                w,
                i,
                n_mb,
                &gmb[layout.stage_range(w)],
                &mut gsum,
            )
            .with_context(|| format!("owner {w}: shard reduce, step {t}"))?;

        // ---- owner update ----------------------------------------------
        rt.sgd(
            &mut exec,
            w,
            t,
            &own_cur,
            &mut own_mom,
            &gsum,
            rt.manifest().lr,
            &mut own_next,
        )?;
        std::mem::swap(&mut own_prev, &mut own_cur); // prev ← θ_t
        std::mem::swap(&mut own_cur, &mut own_next); // cur ← θ_{t+1}

        // ---- checkpoint at the fresh θ-version boundary ----------------
        // The shards converge on worker 0 over the control plane (exempt
        // from fault injection): three messages per non-zero worker, one
        // per arena part.
        if opts.checkpoint_at == Some(t) {
            if w != 0 {
                for (part, run) in
                    [(0usize, &own_cur), (1, &own_prev), (2, &own_mom)]
                {
                    ep.send_copy(0, tags::ckpt(t, w, part), run)
                        .with_context(|| format!("worker {w}: checkpoint shard, step {t}"))?;
                }
            } else {
                let mut cur = layout.zeros();
                let mut prev = layout.zeros();
                let mut moms = layout.zeros();
                cur[range.clone()].copy_from_slice(&own_cur);
                prev[range.clone()].copy_from_slice(&own_prev);
                moms[range.clone()].copy_from_slice(&own_mom);
                for peer in 1..n_mb {
                    let pr = layout.stage_range(peer);
                    for (part, dst) in
                        [(0usize, &mut cur), (1, &mut prev), (2, &mut moms)]
                    {
                        let p = ep.recv(peer, tags::ckpt(t, peer, part)).with_context(
                            || format!("worker 0: checkpoint shard from {peer}, step {t}"),
                        )?;
                        dst[pr.clone()].copy_from_slice(&p);
                    }
                }
                checkpoint = Some(Checkpoint::from_arenas(
                    &layout,
                    rule,
                    t + 1,
                    cur,
                    prev,
                    moms,
                ));
            }
        }

        // ---- loss reporting (worker 0 logs the canonical mean) ---------
        if w == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                let p = ep
                    .recv(from, tags::loss(t))
                    .with_context(|| format!("worker 0: loss gather, step {t}"))?;
                sum += p[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss])
                .with_context(|| format!("worker {w}: loss report, step {t}"))?;
        }
    }
    Ok((logs, peak_state, checkpoint))
}
