//! Multi-worker trainer (paper §4.2): N worker threads, each a full model
//! replica handling one micro-batch per training step.
//!
//! - **DP mode** — the classic barrier pattern: every worker computes all
//!   2N time steps, then a synchronous all-reduce (rank-ordered flat tree;
//!   O(log N)-step collectives are modelled in `sim::analytic`, the flat
//!   tree keeps the sum order bit-identical to the reference trainer).
//!   Every replica applies the same averaged update locally — N copies of
//!   optimizer state.
//! - **CDP mode** — the cyclic pattern: gradients travel the ring as
//!   partial sums in micro-batch order (worker i adds its contribution and
//!   forwards), so the reduction is *balanced across the training step*
//!   with only point-to-point transfers; the last worker (micro-batch N)
//!   holds the only optimizer state, applies the update as each stage's sum
//!   completes, and the fresh stage parameters hop the ring back — the
//!   paper's Fig 1c communication scheme.  Note the asymmetry the paper
//!   highlights: max communications *between two time steps* is O(1) here
//!   vs a collective in DP.
//!
//! Loss sequences are bit-identical to [`super::single::RefTrainer`] under
//! the same rule (tested in rust/tests/trainer_equivalence.rs).

use anyhow::Result;

use super::{SharedRuntime, StepLog};
use crate::cluster::run_workers;
use crate::comm::collectives::{broadcast, reduce_to_root};
use crate::comm::{tags, CommStats, Endpoint, Fabric};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::{ParamStore, Rule};
use crate::tensor::{HostTensor, Tensor};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// Barrier all-reduce at the end of each training step.
    Barrier,
    /// Balanced ring: per-stage partial sums + param hand-off (CDP).
    Ring,
}

pub struct MultiReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Optimizer-state replicas across the cluster (DP: N, CDP ring: 1).
    pub optimizer_replicas: usize,
}

/// Train `steps` steps on `n` worker threads.
pub fn train(
    rt: SharedRuntime,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
) -> Result<MultiReport> {
    let n = rt.manifest.n_microbatches;
    let (endpoints, stats) = Fabric::new(n);
    let mut slots: Vec<Option<Endpoint>> = endpoints.into_iter().map(Some).collect();
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        slots.iter_mut().map(|e| std::sync::Mutex::new(e.take())).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().expect("endpoint taken twice");
        let out = match pattern {
            CommPattern::Barrier => {
                worker_dp(&rt_arc, &rule_c, &mut ep, w, steps)
            }
            CommPattern::Ring => worker_ring(&rt_arc, &rule_c, &mut ep, w, steps),
        };
        out.expect("worker failed")
    });

    // worker 0 reports the canonical loss log
    let logs = results.into_iter().next().unwrap();
    Ok(MultiReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        optimizer_replicas: match pattern {
            CommPattern::Barrier => n,
            CommPattern::Ring => 1,
        },
    })
}

/// Flatten per-stage grads (stage-major, manifest order).
fn flatten(grads: &[Vec<Tensor>]) -> Vec<f32> {
    grads
        .iter()
        .flat_map(|st| st.iter().flat_map(|t| t.data.iter().copied()))
        .collect()
}

fn unflatten_into(flat: &[f32], dst: &mut [Vec<Tensor>]) {
    let mut off = 0;
    for st in dst.iter_mut() {
        for t in st.iter_mut() {
            let len = t.data.len();
            t.data.copy_from_slice(&flat[off..off + len]);
            off += len;
        }
    }
    assert_eq!(off, flat.len());
}

/// One micro-batch fwd+bwd at θ̂ (shared by both worker bodies).
fn compute_grads(
    rt: &SharedRuntime,
    store: &ParamStore,
    data: &DataSource,
    rule: &Rule,
    t: u64,
    i: usize,
) -> Result<(f32, Vec<Vec<Tensor>>)> {
    let n = rt.manifest.n_stages;
    let mb = data.microbatch(t, (i - 1) as u64);
    let (x0, targets) = match &mb {
        MicroBatch::Lm { tokens, targets } => {
            (HostTensor::I32(tokens.clone()), targets.clone())
        }
        MicroBatch::Class { x, labels } => {
            (HostTensor::F32(x.clone()), labels.clone())
        }
    };
    let mut inputs: Vec<HostTensor> = vec![x0];
    for j in 0..n - 1 {
        let y = rt.stage_fwd(j, store.select(rule, i, j), &inputs[j])?;
        inputs.push(HostTensor::F32(y));
    }
    let mut grads: Vec<Vec<Tensor>> = vec![Vec::new(); n];
    let last = n - 1;
    let (loss, mut gx, gp) = rt.last_bwd(
        store.select(rule, i, last),
        inputs[last].as_f32().unwrap(),
        &targets,
    )?;
    grads[last] = gp;
    for j in (1..last).rev() {
        let (gx_new, gp) =
            rt.mid_bwd(j, store.select(rule, i, j), inputs[j].as_f32().unwrap(), &gx)?;
        grads[j] = gp;
        gx = gx_new;
    }
    grads[0] = rt.first_bwd(store.select(rule, i, 0), &inputs[0], &gx)?;
    Ok((loss, grads))
}

/// DP worker: compute → barrier all-reduce → identical local update.
fn worker_dp(
    rt: &SharedRuntime,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
) -> Result<Vec<StepLog>> {
    let n = rt.manifest.n_stages;
    let init = rt.init_params()?;
    let mut store = ParamStore::new(init);
    let data = DataSource::from_manifest(&rt.manifest);
    let mut logs = Vec::new();

    for t in 0..steps as u64 {
        let (loss, grads) = compute_grads(rt, &store, &data, rule, t, w + 1)?;

        // synchronous all-reduce (the paper's waiting barrier)
        let mut flat = flatten(&grads);
        reduce_to_root(ep, 0, t, &mut flat);
        if ep.id == 0 {
            let inv = 1.0 / ep.n as f32;
            for v in flat.iter_mut() {
                *v *= inv;
            }
        }
        broadcast(ep, 0, t, &mut flat);

        let mut averaged: Vec<Vec<Tensor>> = rt.zero_like_params();
        unflatten_into(&flat, &mut averaged);

        // every replica applies the identical update (N optimizer copies)
        let mut new_params = Vec::with_capacity(n);
        let lr = rt.manifest.lr;
        for j in 0..n {
            let mut p = store.fresh(j).clone();
            let (_c, moms) = store.stage_mut(j);
            rt.sgd_update(j, &mut p, moms, &averaged[j], lr)?;
            new_params.push(p);
        }
        store.commit_step(new_params);

        // loss reporting: mean over micro-batches, gathered at worker 0
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..ep.n {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / ep.n as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok(logs)
}

/// CDP worker: ring partial sums per stage, single optimizer owner
/// (micro-batch N = worker n−1), param hand-off around the ring.
fn worker_ring(
    rt: &SharedRuntime,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
) -> Result<Vec<StepLog>> {
    let n = rt.manifest.n_stages;
    let n_mb = ep.n;
    let owner = n_mb - 1; // worker of micro-batch N: the only optimizer state
    let init = rt.init_params()?;
    let mut store = ParamStore::new(init);
    let data = DataSource::from_manifest(&rt.manifest);
    let mut logs = Vec::new();

    for t in 0..steps as u64 {
        let (loss, grads) = compute_grads(rt, &store, &data, rule, t, w + 1)?;

        // --- balanced gradient reduction: partial sums travel the ring in
        // micro-batch order (worker 0 = mb 1 starts; each adds its own and
        // forwards), one stage at a time — the Fig 1c hand-off.  The owner
        // ends up with Σ_i ∇f_i in exactly the reference sum order.
        let mut full_sums: Vec<Vec<f32>> = Vec::new(); // owner only
        for j in 0..n {
            let own: Vec<f32> =
                grads[j].iter().flat_map(|t| t.data.iter().copied()).collect();
            if n_mb == 1 {
                full_sums.push(own);
            } else if w == 0 {
                ep.send(1, tags::grad(t, j), own);
            } else {
                let mut part = ep.recv(w - 1, tags::grad(t, j));
                for (p, v) in part.iter_mut().zip(&own) {
                    *p += v;
                }
                if w < owner {
                    ep.send(w + 1, tags::grad(t, j), part);
                } else {
                    full_sums.push(part);
                }
            }
        }

        // --- owner updates each stage and hands fresh params down the ring
        let lr = rt.manifest.lr;
        let mut new_params: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        if w == owner {
            let inv = 1.0 / n_mb as f32;
            for (j, mut flat) in full_sums.into_iter().enumerate() {
                for v in flat.iter_mut() {
                    *v *= inv;
                }
                let mut averaged = Vec::with_capacity(grads[j].len());
                let mut off = 0;
                for g in &grads[j] {
                    let len = g.data.len();
                    averaged.push(Tensor::new(g.shape.clone(), flat[off..off + len].to_vec()));
                    off += len;
                }
                let mut p = store.fresh(j).clone();
                let (_c, moms) = store.stage_mut(j);
                rt.sgd_update(j, &mut p, moms, &averaged, lr)?;
                if n_mb > 1 {
                    let flat_p: Vec<f32> =
                        p.iter().flat_map(|t| t.data.iter().copied()).collect();
                    ep.send(ep.right(), tags::param(t, j), flat_p);
                }
                new_params.push(p);
            }
        } else {
            // receive fresh stage params from the left, forward along the
            // ring until the hop before the owner
            for j in 0..n {
                let flat = ep.recv(ep.left(), tags::param(t, j));
                if ep.right() != owner {
                    ep.send(ep.right(), tags::param(t, j), flat.clone());
                }
                let mut stage = store.fresh(j).clone();
                let mut off = 0;
                for p in stage.iter_mut() {
                    let len = p.data.len();
                    p.data.copy_from_slice(&flat[off..off + len]);
                    off += len;
                }
                new_params.push(stage);
            }
        }
        store.commit_step(new_params);

        // loss gathering at worker 0 (mb order)
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok(logs)
}

/// Convenience: comm stats snapshot type re-export.
pub type Stats = Arc<CommStats>;
