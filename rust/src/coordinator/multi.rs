//! Multi-worker trainer (paper §4.2): N worker threads, each a full model
//! replica handling one micro-batch per training step.
//!
//! - **DP mode** — the classic barrier pattern: every worker computes all
//!   2N time steps, then a synchronous all-reduce (rank-ordered flat tree;
//!   O(log N)-step collectives are modelled in `sim::analytic`, the flat
//!   tree keeps the sum order bit-identical to the reference trainer).
//!   Every replica applies the same averaged update locally — N copies of
//!   optimizer state.
//! - **CDP mode** — the cyclic pattern, now *eager and bucketed*: the
//!   moment stage j's backward output lands, its gradient run enters the
//!   ring bucket by bucket (`comm::bucketed`) while stage j−1 backprop is
//!   still executing — the balanced communication of Fig 1c, overlapped
//!   with compute instead of paid at the step boundary.  The owner
//!   (micro-batch N) holds the only optimizer state, updates each stage
//!   as its averaged sum completes, and hands the fresh parameters down
//!   the ring — also overlapping the remaining backward.
//!
//! Generic over [`Backend`].  On XLA, execution is device-resident by
//! default (persistent parameter/momentum buffers uploaded once per
//! (stage, θ-version), device-side activation hand-off, fused SGD
//! promoting its result); the native backend runs its single host path.
//! `ExecMode` (or `CDP_EXEC_MODE`) selects the host path on XLA instead —
//! loss sequences are bit-identical either way, and bit-identical to
//! [`super::single::RefTrainer`] under the same rule (rust/tests/).

use anyhow::Result;

use super::{version_id, ExecMode, SharedBackend, StepLog};
use crate::cluster::run_workers;
use crate::comm::bucketed::{bucket_elems_from_env, BucketedReducer};
use crate::comm::collectives::allreduce_mean;
use crate::comm::{tags, CommStats, Endpoint, EventKind, Fabric, TimelineEvent};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{ParamStore, Rule};
use crate::runtime::Backend;
use crate::tensor::{HostTensor, IntTensor};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// Barrier all-reduce at the end of each training step.
    Barrier,
    /// Eager bucketed ring: per-stage partial sums enter the ring as
    /// backward runs, single optimizer owner, param hand-off (CDP).
    Ring,
}

/// Knobs for [`train_with`]; [`Default`] is the production configuration
/// (device-resident where the backend has a device, default bucket size,
/// no timeline recording).
#[derive(Clone, Copy, Debug)]
pub struct MultiOpts {
    pub mode: ExecMode,
    /// Gradient bucket granularity for the eager ring (elements).
    pub bucket_elems: usize,
    /// Record the comm/compute timeline (benches assert overlap on it).
    pub record_timeline: bool,
}

impl Default for MultiOpts {
    fn default() -> Self {
        Self {
            mode: ExecMode::from_env(ExecMode::DeviceResident),
            bucket_elems: bucket_elems_from_env(),
            record_timeline: false,
        }
    }
}

pub struct MultiReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Optimizer-state replicas across the cluster (DP: N, CDP ring: 1).
    pub optimizer_replicas: usize,
    /// Recorded events when `record_timeline` was set (else empty).
    pub timeline: Vec<TimelineEvent>,
}

/// Train `steps` steps on `n` worker threads with default options.
pub fn train<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
) -> Result<MultiReport> {
    train_with(rt, rule, pattern, steps, MultiOpts::default())
}

pub fn train_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
    opts: MultiOpts,
) -> Result<MultiReport> {
    let n = rt.manifest().n_microbatches;
    let (endpoints, stats) = Fabric::new(n);
    if opts.record_timeline {
        stats.enable_timeline();
    }
    let mut slots: Vec<Option<Endpoint>> = endpoints.into_iter().map(Some).collect();
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        slots.iter_mut().map(|e| std::sync::Mutex::new(e.take())).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().expect("endpoint taken twice");
        let out = match pattern {
            CommPattern::Barrier => worker_dp(&rt_arc, &rule_c, &mut ep, w, steps, opts),
            CommPattern::Ring => worker_ring(&rt_arc, &rule_c, &mut ep, w, steps, opts),
        };
        out.expect("worker failed")
    });

    // worker 0 reports the canonical loss log
    let logs = results.into_iter().next().unwrap();
    Ok(MultiReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        optimizer_replicas: match pattern {
            CommPattern::Barrier => n,
            CommPattern::Ring => 1,
        },
        timeline: stats.timeline(),
    })
}

/// Forward chain for micro-batch `i` at the rule's θ̂ versions: stashes
/// every stage input (the remat unit) plus the targets.
fn forward_mb<B: Backend>(
    rt: &SharedBackend<B>,
    exec: &mut B::Exec,
    store: &ParamStore,
    data: &DataSource,
    rule: &Rule,
    t: u64,
    i: usize,
) -> Result<(Vec<B::Act>, IntTensor)> {
    let n = rt.manifest().n_stages;
    let mb = data.microbatch(t, (i - 1) as u64);
    let (x0, targets) = match mb {
        MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
        MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
    };
    let mut acts: Vec<B::Act> = Vec::with_capacity(n);
    acts.push(rt.input(exec, x0)?);
    for j in 0..n - 1 {
        let ver = version_id(rule, store.step(), i, j, n);
        let y = rt.fwd(exec, j, ver, store.select(rule, i, j), &acts[j])?;
        acts.push(y);
    }
    Ok((acts, targets))
}

/// One micro-batch fwd+bwd at θ̂, gradients written into the model-wide
/// flat scratch `gmb` (the DP worker's whole-chain form — the ring worker
/// interleaves its backward with the eager reduction instead).
#[allow(clippy::too_many_arguments)]
fn compute_grads<B: Backend>(
    rt: &SharedBackend<B>,
    exec: &mut B::Exec,
    store: &ParamStore,
    data: &DataSource,
    rule: &Rule,
    t: u64,
    i: usize,
    gmb: &mut [f32],
) -> Result<f32> {
    let n = rt.manifest().n_stages;
    let layout = store.layout().clone();
    let (acts, targets) = forward_mb(rt, exec, store, data, rule, t, i)?;
    let last = n - 1;
    let ver = version_id(rule, store.step(), i, last, n);
    let (loss, mut gx) = rt.last_bwd(
        exec,
        ver,
        store.select(rule, i, last),
        &acts[last],
        &targets,
        &mut gmb[layout.stage_range(last)],
    )?;
    for j in (1..last).rev() {
        let ver = version_id(rule, store.step(), i, j, n);
        gx = rt.mid_bwd(
            exec,
            j,
            ver,
            store.select(rule, i, j),
            &acts[j],
            &gx,
            &mut gmb[layout.stage_range(j)],
        )?;
    }
    if n > 1 {
        let ver = version_id(rule, store.step(), i, 0, n);
        rt.first_bwd(
            exec,
            ver,
            store.select(rule, i, 0),
            &acts[0],
            &gx,
            &mut gmb[layout.stage_range(0)],
        )?;
    }
    Ok(loss)
}

/// DP worker: compute → barrier all-reduce → identical local update.
fn worker_dp<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: MultiOpts,
) -> Result<Vec<StepLog>> {
    let n = rt.manifest().n_stages;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let mut store = ParamStore::from_flat(layout.clone(), rt.init_params_flat()?);
    let mut exec = rt.executor(opts.mode);
    let data = DataSource::from_manifest(rt.manifest());
    let mut gmb = layout.zeros();
    let mut logs = Vec::new();

    for t in 0..steps as u64 {
        let loss =
            compute_grads(rt, &mut exec, &store, &data, rule, t, w + 1, &mut gmb)?;

        // synchronous all-reduce over the model-wide gradient run (the
        // paper's waiting barrier); rank-ordered sum + 1/N at the root
        allreduce_mean(ep, t, &mut gmb);

        // every replica applies the identical update (N optimizer copies)
        let lr = rt.manifest().lr;
        for j in 0..n {
            let (cur, moms, next) = store.update_parts(j);
            rt.sgd(&mut exec, j, t, cur, moms, &gmb[layout.stage_range(j)], lr, next)?;
        }
        store.commit_step();

        // loss reporting: mean over micro-batches, gathered at worker 0
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..ep.n {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / ep.n as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok(logs)
}

/// CDP worker: eager bucketed ring — as each backward stage completes,
/// its gradient buckets travel the ring in micro-batch order while the
/// remaining backward keeps computing; the owner (micro-batch N, the
/// only optimizer state) updates each stage the moment its averaged sum
/// assembles and hands the fresh parameters down the ring.
fn worker_ring<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: MultiOpts,
) -> Result<Vec<StepLog>> {
    let n = rt.manifest().n_stages;
    let n_mb = ep.n;
    let owner = n_mb - 1; // worker of micro-batch N: the only optimizer state
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let mut store = ParamStore::from_flat(layout.clone(), rt.init_params_flat()?);
    let mut exec = rt.executor(opts.mode);
    let data = DataSource::from_manifest(rt.manifest());
    let reducer = BucketedReducer::new(opts.bucket_elems);
    let mut gmb = layout.zeros();
    // owner-side scratch the averaged sums assemble into, bucket by bucket
    let mut avg = layout.zeros();
    let mut logs = Vec::new();
    let lr = rt.manifest().lr;
    let i = w + 1; // this worker's micro-batch index (1-based)

    for t in 0..steps as u64 {
        let (acts, targets) = forward_mb(rt, &mut exec, &store, &data, rule, t, i)?;

        // ---- backward chain interleaved with the eager ring ----------
        // Stages run N−1 .. 0.  The moment stage j's grads land in the
        // arena scratch, its buckets enter the ring (worker 0 launches,
        // middles add+forward in micro-batch order, the owner folds the
        // final add and the 1/N average — exactly the reference sum
        // order, so losses stay bit-identical).  The owner then updates
        // stage j and sends θ_{t+1}^j down the ring — all while stages
        // j−1..0 are still backpropagating everywhere: the balanced
        // communication of Fig 1c, overlapped with compute.
        let mut loss = 0f32;
        let mut gx: Option<B::Act> = None;
        for j in (0..n).rev() {
            let ver = version_id(rule, store.step(), i, j, n);
            let grange = layout.stage_range(j);
            if j == n - 1 {
                let (l, g) = rt.last_bwd(
                    &mut exec,
                    ver,
                    store.select(rule, i, j),
                    &acts[j],
                    &targets,
                    &mut gmb[grange.clone()],
                )?;
                loss = l;
                if n > 1 {
                    gx = Some(g);
                }
            } else if j > 0 {
                let g = rt.mid_bwd(
                    &mut exec,
                    j,
                    ver,
                    store.select(rule, i, j),
                    &acts[j],
                    gx.as_ref().expect("cotangent from stage above"),
                    &mut gmb[grange.clone()],
                )?;
                gx = Some(g);
            } else {
                rt.first_bwd(
                    &mut exec,
                    ver,
                    store.select(rule, i, j),
                    &acts[j],
                    gx.as_ref().expect("cotangent from stage above"),
                    &mut gmb[grange.clone()],
                )?;
            }
            ep.stats().mark(EventKind::BwdStageDone, w, j, 0);

            // eager hop: stage j's buckets enter the ring now
            let avg_out = if w == owner {
                Some(&mut avg[grange.clone()])
            } else {
                None
            };
            reducer.ring_stage(ep, &layout, t, j, &gmb[grange.clone()], avg_out);

            if w == owner {
                // update stage j immediately; θ_{t+1}^j hops the ring
                // while backward continues below stage j
                let g = &avg[grange];
                let (cur, moms, next) = store.update_parts(j);
                rt.sgd(&mut exec, j, t, cur, moms, g, lr, next)?;
                if n_mb > 1 {
                    let fresh = store.next_stage(j);
                    ep.stats().mark(
                        EventKind::ParamSend,
                        w,
                        j,
                        fresh.len() as u64 * 4,
                    );
                    ep.send_copy(ep.right(), tags::param(t, j), fresh);
                }
            }
        }

        // ---- non-owners: fresh stage params hop the ring from the owner;
        // forward the payload by handle, then write it into the next slot
        if w != owner && n_mb > 1 {
            for j in 0..n {
                let flat = ep.recv(ep.left(), tags::param(t, j));
                if ep.right() != owner {
                    ep.send(ep.right(), tags::param(t, j), flat.clone());
                }
                store.write_next(j, &flat);
            }
        }
        store.commit_step();

        // loss gathering at worker 0 (mb order)
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok(logs)
}

/// Convenience: comm stats snapshot type re-export.
pub type Stats = Arc<CommStats>;
