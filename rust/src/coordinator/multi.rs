//! Multi-worker trainer (paper §4.2): N worker threads, each a full model
//! replica handling one micro-batch per training step.
//!
//! - **DP mode** — the classic barrier pattern: every worker computes all
//!   2N time steps, then a synchronous all-reduce (rank-ordered flat tree;
//!   O(log N)-step collectives are modelled in `sim::analytic`, the flat
//!   tree keeps the sum order bit-identical to the reference trainer).
//!   Every replica applies the same averaged update locally — N copies of
//!   optimizer state.
//! - **CDP mode** — the cyclic pattern, now *eager and bucketed*: the
//!   moment stage j's backward output lands, its gradient run enters the
//!   ring bucket by bucket (`comm::bucketed`) while stage j−1 backprop is
//!   still executing — the balanced communication of Fig 1c, overlapped
//!   with compute instead of paid at the step boundary.  The owner
//!   (micro-batch N) holds the only optimizer state, updates each stage
//!   as its averaged sum completes, and hands the fresh parameters down
//!   the ring — also overlapping the remaining backward.
//!
//! Generic over [`Backend`].  On XLA, execution is device-resident by
//! default (persistent parameter/momentum buffers uploaded once per
//! (stage, θ-version), device-side activation hand-off, fused SGD
//! promoting its result); the native backend runs its single host path.
//! `ExecMode` (or `CDP_EXEC_MODE`) selects the host path on XLA instead —
//! loss sequences are bit-identical either way, and bit-identical to
//! [`super::single::RefTrainer`] under the same rule (rust/tests/).
//!
//! ## Robustness (DESIGN-ROBUSTNESS.md)
//!
//! Every receive runs against the fabric deadline, so a lost peer turns
//! into a typed [`crate::comm::CommError`] naming the peer and decoded
//! tag instead of a silent hang.  [`MultiOpts::faults`] wires a seeded
//! [`FaultPlan`] into the fabric; loss sequences under drop/dup/reorder
//! injection stay bit-identical to the clean run (retry + seq dedup).
//! [`MultiOpts::checkpoint_at`] captures a [`Checkpoint`] at a θ-version
//! boundary and [`resume_with`] continues from one bit-identically.  A
//! scripted worker kill in ring mode degrades gracefully: the survivors
//! detect the silent peer by heartbeat at the next step boundary and
//! re-form the cyclic ring with N−1 members — post-junction losses match
//! a fresh N−1-micro-batch run resumed from the junction state.

use anyhow::{Context, Result};

use super::{version_id, ExecMode, SharedBackend, StepLog};
use crate::cluster::run_workers;
use crate::comm::bucketed::{bucket_elems_from_env, BucketedReducer};
use crate::comm::collectives::allreduce_mean;
use crate::comm::fault::FaultPlan;
use crate::comm::{
    tags, CommStats, Endpoint, EventKind, Fabric, RingView, TimelineEvent,
};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{Checkpoint, ParamStore, Rule};
use crate::runtime::Backend;
use crate::tensor::{HostTensor, IntTensor};
use crate::trace::{self, Fields, TraceKind};
use std::sync::Arc;
use std::time::Duration;

/// How long a heartbeat may stay silent before the peer is declared dead.
/// Generous against scheduler noise (heartbeats are sent before anyone
/// blocks, so live peers answer in microseconds).
const DETECT_DEADLINE: Duration = Duration::from_secs(2);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// Barrier all-reduce at the end of each training step.
    Barrier,
    /// Eager bucketed ring: per-stage partial sums enter the ring as
    /// backward runs, single optimizer owner, param hand-off (CDP).
    Ring,
}

/// Knobs for [`train_with`]; [`Default`] is the production configuration
/// (device-resident where the backend has a device, default bucket size,
/// no timeline recording, no faults, no checkpoint).
#[derive(Clone, Copy, Debug)]
pub struct MultiOpts {
    pub mode: ExecMode,
    /// Gradient bucket granularity for the eager ring (elements).
    pub bucket_elems: usize,
    /// Record the comm/compute timeline (benches assert overlap on it).
    pub record_timeline: bool,
    /// Seeded fault injection on every non-control fabric edge.
    pub faults: Option<FaultPlan>,
    /// Capture a checkpoint at the θ-version boundary after this step.
    pub checkpoint_at: Option<u64>,
}

impl Default for MultiOpts {
    fn default() -> Self {
        Self {
            mode: ExecMode::from_env(ExecMode::DeviceResident),
            bucket_elems: bucket_elems_from_env(),
            record_timeline: false,
            faults: None,
            checkpoint_at: None,
        }
    }
}

impl MultiOpts {
    /// Options for executing a planner [`crate::plan::Plan`]: the plan's
    /// bucket size, defaults everywhere else (the rule and comm pattern
    /// are passed to [`train_with`] directly by
    /// [`crate::coordinator::execute_plan`]).
    pub fn from_plan(plan: &crate::plan::Plan) -> Self {
        Self { bucket_elems: plan.bucket_elems as usize, ..Self::default() }
    }
}

pub struct MultiReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Optimizer-state replicas across the cluster (DP: N, CDP ring: 1).
    pub optimizer_replicas: usize,
    /// Recorded events when `record_timeline` was set (else empty).
    pub timeline: Vec<TimelineEvent>,
    /// Captured at the [`MultiOpts::checkpoint_at`] boundary, if any.
    pub checkpoint: Option<Checkpoint>,
}

/// Train `steps` steps on `n` worker threads with default options.
pub fn train<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
) -> Result<MultiReport> {
    train_with(rt, rule, pattern, steps, MultiOpts::default())
}

pub fn train_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
    opts: MultiOpts,
) -> Result<MultiReport> {
    run(rt, rule, pattern, steps, opts, None)
}

/// Continue a run from a θ-version-boundary checkpoint: step `ck.step`
/// onward is bit-identical to the uninterrupted run that produced it.
pub fn resume_with<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
    opts: MultiOpts,
    ck: Checkpoint,
) -> Result<MultiReport> {
    run(rt, rule, pattern, steps, opts, Some(ck))
}

/// One worker's share of [`train_with`] over an externally built
/// endpoint — the multi-process path: `cdp launch` spawns one OS process
/// per worker, each of which binds a wire endpoint (`Fabric::wire`'s
/// per-process analogue) and calls this.  `ep.id` is the worker's rank;
/// `ep.n` must match the manifest's micro-batch count.  Returns the
/// worker's loss log (canonical on rank 0, empty elsewhere) and the
/// checkpoint if this rank captured one.
pub fn run_worker<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    pattern: CommPattern,
    steps: usize,
    opts: MultiOpts,
    resume: Option<&Checkpoint>,
    ep: &mut Endpoint,
) -> Result<(Vec<StepLog>, Option<Checkpoint>)> {
    anyhow::ensure!(
        ep.n == rt.manifest().n_microbatches,
        "fabric size {} != manifest micro-batches {}",
        ep.n,
        rt.manifest().n_microbatches
    );
    let w = ep.id;
    match pattern {
        CommPattern::Barrier => worker_dp(rt, rule, ep, w, steps, opts, resume),
        CommPattern::Ring => worker_ring(rt, rule, ep, w, steps, opts, resume),
    }
}

fn run<B: Backend + Send + Sync + 'static>(
    rt: SharedBackend<B>,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
    opts: MultiOpts,
    resume: Option<Checkpoint>,
) -> Result<MultiReport> {
    let n = rt.manifest().n_microbatches;
    if let Some(plan) = opts.faults {
        if let Some(k) = plan.kill {
            anyhow::ensure!(
                pattern == CommPattern::Ring,
                "scripted worker kills require the ring pattern (the barrier \
                 has no degraded mode — a killed peer is a typed timeout)"
            );
            anyhow::ensure!(
                n >= 3 && k.worker >= 1 && k.worker <= n - 2,
                "killable workers are 1..={} (worker 0 is the loss logger, \
                 worker {} the optimizer owner); got {}",
                n.saturating_sub(2),
                n - 1,
                k.worker
            );
        }
    }
    let (endpoints, stats) = match opts.faults {
        Some(plan) => {
            let (eps, stats, _inj) = Fabric::with_faults(n, plan);
            (eps, stats)
        }
        None => Fabric::new(n),
    };
    if opts.record_timeline {
        stats.enable_timeline();
    }
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        endpoints.into_iter().map(|e| std::sync::Mutex::new(Some(e))).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let resume = Arc::new(resume);
    let results = run_workers(n, move |w| -> Result<(Vec<StepLog>, Option<Checkpoint>)> {
        let mut ep = eps[w]
            .lock()
            .map_err(|_| anyhow::anyhow!("endpoint mutex poisoned for worker {w}"))?
            .take()
            .ok_or_else(|| anyhow::anyhow!("endpoint for worker {w} taken twice"))?;
        run_worker(&rt_arc, &rule_c, pattern, steps, opts, resume.as_ref().as_ref(), &mut ep)
    });

    // worker 0 reports the canonical loss log + checkpoint
    let mut logs = Vec::new();
    let mut checkpoint = None;
    for (w, r) in results.into_iter().enumerate() {
        let (l, ck) = r.with_context(|| format!("multi worker {w} failed"))?;
        if w == 0 {
            logs = l;
            checkpoint = ck;
        }
    }
    Ok(MultiReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        optimizer_replicas: match pattern {
            CommPattern::Barrier => n,
            CommPattern::Ring => 1,
        },
        timeline: stats.timeline(),
        checkpoint,
    })
}

/// Fresh-or-restored replica state shared by both worker kinds.
fn init_store<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    layout: &Arc<ArenaLayout>,
    resume: Option<&Checkpoint>,
) -> Result<(ParamStore, u64)> {
    match resume {
        Some(ck) => {
            let store = ck.clone().into_store(layout.clone(), rule)?;
            let t0 = store.step();
            trace::instant(TraceKind::CkptResume, Fields { step: t0, ..Fields::default() });
            Ok((store, t0))
        }
        None => Ok((ParamStore::from_flat(layout.clone(), rt.init_params_flat()?), 0)),
    }
}

/// Forward chain for micro-batch `i` at the rule's θ̂ versions: stashes
/// every stage input (the remat unit) plus the targets.
fn forward_mb<B: Backend>(
    rt: &SharedBackend<B>,
    exec: &mut B::Exec,
    store: &ParamStore,
    data: &DataSource,
    rule: &Rule,
    t: u64,
    i: usize,
) -> Result<(Vec<B::Act>, IntTensor)> {
    let n = rt.manifest().n_stages;
    let mb = data.microbatch(t, (i - 1) as u64);
    let (x0, targets) = match mb {
        MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
        MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
    };
    let mut acts: Vec<B::Act> = Vec::with_capacity(n);
    acts.push(rt.input(exec, x0)?);
    for j in 0..n - 1 {
        let ver = version_id(rule, store.step(), i, j, n);
        let t_fwd = trace::start();
        let y = rt.fwd(exec, j, ver, store.select(rule, i, j), &acts[j])?;
        trace::span(
            TraceKind::Fwd,
            t_fwd,
            Fields {
                worker: (i - 1) as u32,
                stage: j as u32,
                step: t,
                version: ver,
                ..Fields::default()
            },
        );
        // stage j's output is stashed until stage j+1's backward frees it
        trace::instant(
            TraceKind::ActAlloc,
            Fields {
                worker: (i - 1) as u32,
                stage: j as u32,
                step: t,
                bytes: rt.manifest().stages[j].act_bytes,
                ..Fields::default()
            },
        );
        acts.push(y);
    }
    Ok((acts, targets))
}

/// One micro-batch fwd+bwd at θ̂, gradients written into the model-wide
/// flat scratch `gmb` (the DP worker's whole-chain form — the ring worker
/// interleaves its backward with the eager reduction instead).
#[allow(clippy::too_many_arguments)]
fn compute_grads<B: Backend>(
    rt: &SharedBackend<B>,
    exec: &mut B::Exec,
    store: &ParamStore,
    data: &DataSource,
    rule: &Rule,
    t: u64,
    i: usize,
    gmb: &mut [f32],
) -> Result<f32> {
    let n = rt.manifest().n_stages;
    let layout = store.layout().clone();
    let (acts, targets) = forward_mb(rt, exec, store, data, rule, t, i)?;
    let last = n - 1;
    let w = (i - 1) as u32;
    let free_act = |j: usize| {
        // stage j's backward consumed the stash forward_mb allocated for
        // the stage below it (raw input at j == 0 was never counted)
        if j > 0 {
            trace::instant(
                TraceKind::ActFree,
                Fields {
                    worker: w,
                    stage: (j - 1) as u32,
                    step: t,
                    bytes: rt.manifest().stages[j - 1].act_bytes,
                    ..Fields::default()
                },
            );
        }
    };
    let ver = version_id(rule, store.step(), i, last, n);
    let t_bwd = trace::start();
    let (loss, mut gx) = rt.last_bwd(
        exec,
        ver,
        store.select(rule, i, last),
        &acts[last],
        &targets,
        &mut gmb[layout.stage_range(last)],
    )?;
    trace::span(
        TraceKind::Bwd,
        t_bwd,
        Fields { worker: w, stage: last as u32, step: t, version: ver, ..Fields::default() },
    );
    free_act(last);
    for j in (1..last).rev() {
        let ver = version_id(rule, store.step(), i, j, n);
        let t_bwd = trace::start();
        gx = rt.mid_bwd(
            exec,
            j,
            ver,
            store.select(rule, i, j),
            &acts[j],
            &gx,
            &mut gmb[layout.stage_range(j)],
        )?;
        trace::span(
            TraceKind::Bwd,
            t_bwd,
            Fields { worker: w, stage: j as u32, step: t, version: ver, ..Fields::default() },
        );
        free_act(j);
    }
    if n > 1 {
        let ver = version_id(rule, store.step(), i, 0, n);
        let t_bwd = trace::start();
        rt.first_bwd(
            exec,
            ver,
            store.select(rule, i, 0),
            &acts[0],
            &gx,
            &mut gmb[layout.stage_range(0)],
        )?;
        trace::span(
            TraceKind::Bwd,
            t_bwd,
            Fields { worker: w, stage: 0, step: t, version: ver, ..Fields::default() },
        );
    }
    Ok(loss)
}

/// DP worker: compute → barrier all-reduce → identical local update.
#[allow(clippy::too_many_arguments)]
fn worker_dp<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: MultiOpts,
    resume: Option<&Checkpoint>,
) -> Result<(Vec<StepLog>, Option<Checkpoint>)> {
    let n = rt.manifest().n_stages;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let (mut store, t0) = init_store(rt, rule, &layout, resume)?;
    let mut exec = rt.executor(opts.mode);
    let data = DataSource::from_manifest(rt.manifest());
    // Kernel-pool warm-up + parallelism composition: each ring worker is
    // already a thread, so the first worker to hit a parallel kernel gets
    // the pool and the rest run the bit-identical serial fallback
    // (DESIGN-PERF.md §Kernel architecture).
    crate::util::par::warm();
    let mut gmb = layout.zeros_aligned();
    let mut logs = Vec::new();
    let mut checkpoint = None;

    for t in t0..t0 + steps as u64 {
        let t_step = trace::start();
        trace::instant(
            TraceKind::StepBegin,
            Fields { worker: w as u32, step: t, ..Fields::default() },
        );
        let loss =
            compute_grads(rt, &mut exec, &store, &data, rule, t, w + 1, &mut gmb)?;

        // the barrier pattern ships the whole model-wide gradient run in
        // one burst at the step boundary — the comm spike `cdp trace
        // verify --expect spike` asserts against the eager ring
        trace::instant(
            TraceKind::GradSend,
            Fields {
                worker: w as u32,
                step: t,
                bytes: gmb.len() as u64 * 4,
                ..Fields::default()
            },
        );
        // synchronous all-reduce over the model-wide gradient run (the
        // paper's waiting barrier); rank-ordered sum + 1/N at the root
        allreduce_mean(ep, t, &mut gmb)
            .with_context(|| format!("worker {w}: barrier all-reduce, step {t}"))?;

        // every replica applies the identical update (N optimizer copies)
        let lr = rt.manifest().lr;
        for j in 0..n {
            let t_sgd = trace::start();
            let (cur, moms, next) = store.update_parts(j);
            rt.sgd(&mut exec, j, t, cur, moms, &gmb[layout.stage_range(j)], lr, next)?;
            trace::span(
                TraceKind::Sgd,
                t_sgd,
                Fields { worker: w as u32, stage: j as u32, step: t, ..Fields::default() },
            );
        }
        store.commit_step();

        // momentum is replicated bit-identically, so worker 0's replica
        // is the complete cluster state — direct capture
        if w == 0 && opts.checkpoint_at == Some(t) {
            checkpoint = Some(Checkpoint::capture(&store, rule));
            trace::instant(
                TraceKind::CkptSave,
                Fields { worker: w as u32, step: t, ..Fields::default() },
            );
        }

        // loss reporting: mean over micro-batches, gathered at worker 0
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..ep.n {
                let p = ep
                    .recv(from, tags::loss(t))
                    .with_context(|| format!("worker 0: loss gather, step {t}"))?;
                sum += p[0] as f64;
            }
            let mean = sum / ep.n as f64;
            trace::loss(0, t, mean);
            logs.push(StepLog { step: t, loss: mean });
        } else {
            ep.send(0, tags::loss(t), vec![loss])
                .with_context(|| format!("worker {w}: loss report, step {t}"))?;
        }
        trace::span(
            TraceKind::StepEnd,
            t_step,
            Fields { worker: w as u32, step: t, ..Fields::default() },
        );
    }
    Ok((logs, checkpoint))
}

/// CDP worker: eager bucketed ring — as each backward stage completes,
/// its gradient buckets travel the ring in micro-batch order while the
/// remaining backward keeps computing; the owner (micro-batch N, the
/// only optimizer state) updates each stage the moment its averaged sum
/// assembles and hands the fresh parameters down the ring.
///
/// With a scripted kill in the fault plan the survivors heartbeat at
/// each step boundary; when the victim goes silent they drop it from
/// the live set and the next ring forms over N−1 members (the victim's
/// micro-batch slot disappears; positions and the 1/m average follow
/// the smaller ring).  Worker 0 (logger) and the owner are structural
/// and may not be killed — `run` validates this.
#[allow(clippy::too_many_arguments)]
fn worker_ring<B: Backend>(
    rt: &SharedBackend<B>,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
    opts: MultiOpts,
    resume: Option<&Checkpoint>,
) -> Result<(Vec<StepLog>, Option<Checkpoint>)> {
    let n = rt.manifest().n_stages;
    let n_mb = ep.n;
    let layout = ArenaLayout::from_manifest(rt.manifest());
    let (mut store, t0) = init_store(rt, rule, &layout, resume)?;
    let mut exec = rt.executor(opts.mode);
    let data = DataSource::from_manifest(rt.manifest());
    let reducer = BucketedReducer::new(opts.bucket_elems);
    crate::util::par::warm(); // see the all-reduce worker's note
    let mut gmb = layout.zeros_aligned();
    // owner-side scratch the averaged sums assemble into, bucket by bucket
    let mut avg = layout.zeros_aligned();
    let mut logs = Vec::new();
    let mut checkpoint = None;
    let lr = rt.manifest().lr;

    let my_kill = ep.injector().and_then(|inj| inj.kill_step_for(w));
    // heartbeats run only under a kill script; one kill per plan, so the
    // exchange stops once the loss has been observed
    let mut hb_active =
        ep.injector().map(|inj| inj.plan().kill.is_some()).unwrap_or(false);
    let mut live: Vec<usize> = (0..n_mb).collect();

    for t in t0..t0 + steps as u64 {
        if my_kill == Some(t) {
            // scripted crash: vanish at the θ-version boundary without a
            // word — peers must detect the silence, not be told
            trace::instant(
                TraceKind::Kill,
                Fields { worker: w as u32, step: t, ..Fields::default() },
            );
            return Ok((logs, checkpoint));
        }
        let t_step = trace::start();
        trace::instant(
            TraceKind::StepBegin,
            Fields { worker: w as u32, step: t, ..Fields::default() },
        );
        if hb_active {
            trace::instant(
                TraceKind::Heartbeat,
                Fields { worker: w as u32, step: t, ..Fields::default() },
            );
            for &p in &live {
                if p != w {
                    // a send error already proves the peer is gone; the
                    // recv sweep below records it
                    let _ = ep.send(p, tags::hb(t), vec![1.0]);
                }
            }
            let mut dead = Vec::new();
            for &p in &live {
                if p != w && ep.recv_deadline(p, tags::hb(t), DETECT_DEADLINE).is_err() {
                    dead.push(p);
                }
            }
            if !dead.is_empty() {
                live.retain(|p| !dead.contains(p));
                anyhow::ensure!(
                    live.len() >= 2,
                    "worker {w}: ring cannot re-form with {} member(s)",
                    live.len()
                );
                hb_active = false;
            }
        }

        // ring geometry for this step: full fabric until a loss, then the
        // sorted survivors.  Micro-batch index = ring position + 1, so a
        // degraded step is exactly an m-micro-batch training step.
        let ring = RingView::from_live(w, &live);
        let m = ring.m;
        let owner = live[m - 1];
        let i = ring.pos + 1;

        let (acts, targets) = forward_mb(rt, &mut exec, &store, &data, rule, t, i)?;

        // ---- backward chain interleaved with the eager ring ----------
        // Stages run N−1 .. 0.  The moment stage j's grads land in the
        // arena scratch, its buckets enter the ring (position 0 launches,
        // middles add+forward in micro-batch order, the owner folds the
        // final add and the 1/m average — exactly the reference sum
        // order, so losses stay bit-identical).  The owner then updates
        // stage j and sends θ_{t+1}^j down the ring — all while stages
        // j−1..0 are still backpropagating everywhere: the balanced
        // communication of Fig 1c, overlapped with compute.
        let mut loss = 0f32;
        let mut gx: Option<B::Act> = None;
        for j in (0..n).rev() {
            let ver = version_id(rule, store.step(), i, j, n);
            let grange = layout.stage_range(j);
            if j == n - 1 {
                let (l, g) = rt.last_bwd(
                    &mut exec,
                    ver,
                    store.select(rule, i, j),
                    &acts[j],
                    &targets,
                    &mut gmb[grange.clone()],
                )?;
                loss = l;
                if n > 1 {
                    gx = Some(g);
                }
            } else if j > 0 {
                let g = rt.mid_bwd(
                    &mut exec,
                    j,
                    ver,
                    store.select(rule, i, j),
                    &acts[j],
                    gx.as_ref()
                        .ok_or_else(|| anyhow::anyhow!("missing cotangent above stage {j}"))?,
                    &mut gmb[grange.clone()],
                )?;
                gx = Some(g);
            } else {
                rt.first_bwd(
                    &mut exec,
                    ver,
                    store.select(rule, i, j),
                    &acts[j],
                    gx.as_ref()
                        .ok_or_else(|| anyhow::anyhow!("missing cotangent above stage {j}"))?,
                    &mut gmb[grange.clone()],
                )?;
            }
            ep.stats().mark(EventKind::BwdStageDone, w, j, t, 0);
            if j > 0 {
                // stage j's backward consumed stage j−1's stashed output
                trace::instant(
                    TraceKind::ActFree,
                    Fields {
                        worker: (i - 1) as u32,
                        stage: (j - 1) as u32,
                        step: t,
                        bytes: rt.manifest().stages[j - 1].act_bytes,
                        ..Fields::default()
                    },
                );
            }

            // eager hop: stage j's buckets enter the ring now
            let avg_out = if w == owner {
                Some(&mut avg[grange.clone()])
            } else {
                None
            };
            reducer
                .ring_stage(ep, &ring, &layout, t, j, &gmb[grange.clone()], avg_out)
                .with_context(|| format!("worker {w}: grad ring, step {t} stage {j}"))?;

            if w == owner {
                // update stage j immediately; θ_{t+1}^j hops the ring
                // while backward continues below stage j
                let g = &avg[grange];
                let t_sgd = trace::start();
                let (cur, moms, next) = store.update_parts(j);
                rt.sgd(&mut exec, j, t, cur, moms, g, lr, next)?;
                trace::span(
                    TraceKind::Sgd,
                    t_sgd,
                    Fields { worker: w as u32, stage: j as u32, step: t, ..Fields::default() },
                );
                if m > 1 {
                    let fresh = store.next_stage(j);
                    ep.stats().mark(
                        EventKind::ParamSend,
                        w,
                        j,
                        t,
                        fresh.len() as u64 * 4,
                    );
                    ep.send_copy(ring.right, tags::param(t, j), fresh)
                        .with_context(|| {
                            format!("worker {w}: param hand-off, step {t} stage {j}")
                        })?;
                }
            }
        }

        // ---- non-owners: fresh stage params hop the ring from the owner;
        // forward the payload by handle, then write it into the next slot
        if w != owner && m > 1 {
            for j in 0..n {
                let flat = ep
                    .recv(ring.left, tags::param(t, j))
                    .with_context(|| format!("worker {w}: param recv, step {t} stage {j}"))?;
                trace::instant(
                    TraceKind::ParamRecv,
                    Fields {
                        worker: w as u32,
                        stage: j as u32,
                        step: t,
                        bytes: flat.len() as u64 * 4,
                        ..Fields::default()
                    },
                );
                if ring.right != owner {
                    ep.send(ring.right, tags::param(t, j), flat.clone())
                        .with_context(|| {
                            format!("worker {w}: param forward, step {t} stage {j}")
                        })?;
                }
                store.write_next(j, &flat);
            }
        }
        store.commit_step();

        // ---- checkpoint at the fresh θ-version boundary ----------------
        // Every replica's cur/prev are bit-identical here; only the owner
        // has live momentum, so it ships that one arena to the logger
        // over the control plane (exempt from fault injection).
        if opts.checkpoint_at == Some(t) {
            if w == owner && w != 0 {
                ep.send_copy(0, tags::ckpt(t, 0, 2), store.momentum_flat())
                    .with_context(|| format!("owner {w}: checkpoint momentum, step {t}"))?;
            }
            if w == 0 {
                let moms = if owner == 0 {
                    store.momentum_flat().to_vec()
                } else {
                    ep.recv(owner, tags::ckpt(t, 0, 2))
                        .with_context(|| format!("worker 0: checkpoint momentum, step {t}"))?
                        .to_vec()
                };
                checkpoint = Some(Checkpoint::from_arenas(
                    &layout,
                    rule,
                    store.step(),
                    store.flat_params().to_vec(),
                    store.stale_flat().to_vec(),
                    moms,
                ));
                trace::instant(
                    TraceKind::CkptSave,
                    Fields { worker: w as u32, step: t, ..Fields::default() },
                );
            }
        }

        // loss gathering at worker 0 (mb order)
        if w == 0 {
            let mut sum = loss as f64;
            for &from in &live {
                if from == 0 {
                    continue;
                }
                let p = ep
                    .recv(from, tags::loss(t))
                    .with_context(|| format!("worker 0: loss gather, step {t}"))?;
                sum += p[0] as f64;
            }
            let mean = sum / m as f64;
            trace::loss(0, t, mean);
            logs.push(StepLog { step: t, loss: mean });
        } else {
            ep.send(0, tags::loss(t), vec![loss])
                .with_context(|| format!("worker {w}: loss report, step {t}"))?;
        }
        trace::span(
            TraceKind::StepEnd,
            t_step,
            Fields { worker: w as u32, step: t, ..Fields::default() },
        );
    }
    Ok((logs, checkpoint))
}

/// Convenience: comm stats snapshot type re-export.
pub type Stats = Arc<CommStats>;
