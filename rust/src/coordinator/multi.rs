//! Multi-worker trainer (paper §4.2): N worker threads, each a full model
//! replica handling one micro-batch per training step.
//!
//! - **DP mode** — the classic barrier pattern: every worker computes all
//!   2N time steps, then a synchronous all-reduce (rank-ordered flat tree;
//!   O(log N)-step collectives are modelled in `sim::analytic`, the flat
//!   tree keeps the sum order bit-identical to the reference trainer).
//!   Every replica applies the same averaged update locally — N copies of
//!   optimizer state.
//! - **CDP mode** — the cyclic pattern: gradients travel the ring as
//!   partial sums in micro-batch order (worker i adds its contribution and
//!   forwards), so the reduction is *balanced across the training step*
//!   with only point-to-point transfers; the last worker (micro-batch N)
//!   holds the only optimizer state, applies the update as each stage's sum
//!   completes, and the fresh stage parameters hop the ring back — the
//!   paper's Fig 1c communication scheme.  Note the asymmetry the paper
//!   highlights: max communications *between two time steps* is O(1) here
//!   vs a collective in DP.
//!
//! Hot-path layout (DESIGN-PERF.md): every worker's parameters, momentum
//! and gradients are flat arenas; the ring forwards received payloads by
//! handle (zero-copy) and mutates partial sums in place, and the DP
//! all-reduce runs over the model-wide gradient run with pooled buffers.
//! Steady-state steps perform no host-side allocation for model state.
//!
//! Loss sequences are bit-identical to [`super::single::RefTrainer`] under
//! the same rule (tested in rust/tests/trainer_equivalence.rs).

use anyhow::Result;

use super::{SharedRuntime, StepLog};
use crate::cluster::run_workers;
use crate::comm::collectives::allreduce_mean;
use crate::comm::{tags, CommStats, Endpoint, Fabric};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::{ParamStore, Rule};
use crate::tensor::{ops, HostTensor};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// Barrier all-reduce at the end of each training step.
    Barrier,
    /// Balanced ring: per-stage partial sums + param hand-off (CDP).
    Ring,
}

pub struct MultiReport {
    pub logs: Vec<StepLog>,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Optimizer-state replicas across the cluster (DP: N, CDP ring: 1).
    pub optimizer_replicas: usize,
}

/// Train `steps` steps on `n` worker threads.
pub fn train(
    rt: SharedRuntime,
    rule: Rule,
    pattern: CommPattern,
    steps: usize,
) -> Result<MultiReport> {
    let n = rt.manifest.n_microbatches;
    let (endpoints, stats) = Fabric::new(n);
    let mut slots: Vec<Option<Endpoint>> = endpoints.into_iter().map(Some).collect();
    let eps: Arc<Vec<std::sync::Mutex<Option<Endpoint>>>> = Arc::new(
        slots.iter_mut().map(|e| std::sync::Mutex::new(e.take())).collect(),
    );

    let rt_arc = rt.clone();
    let rule_c = rule.clone();
    let results = run_workers(n, move |w| {
        let mut ep = eps[w].lock().unwrap().take().expect("endpoint taken twice");
        let out = match pattern {
            CommPattern::Barrier => {
                worker_dp(&rt_arc, &rule_c, &mut ep, w, steps)
            }
            CommPattern::Ring => worker_ring(&rt_arc, &rule_c, &mut ep, w, steps),
        };
        out.expect("worker failed")
    });

    // worker 0 reports the canonical loss log
    let logs = results.into_iter().next().unwrap();
    Ok(MultiReport {
        logs,
        comm_bytes: stats.bytes(),
        comm_messages: stats.messages(),
        optimizer_replicas: match pattern {
            CommPattern::Barrier => n,
            CommPattern::Ring => 1,
        },
    })
}

/// One micro-batch fwd+bwd at θ̂, gradients written into the model-wide
/// flat scratch `gmb` (shared by both worker bodies).
fn compute_grads(
    rt: &SharedRuntime,
    store: &ParamStore,
    data: &DataSource,
    rule: &Rule,
    t: u64,
    i: usize,
    gmb: &mut [f32],
) -> Result<f32> {
    let n = rt.manifest.n_stages;
    let layout = store.layout();
    let mb = data.microbatch(t, (i - 1) as u64);
    let (x0, targets) = match &mb {
        MicroBatch::Lm { tokens, targets } => {
            (HostTensor::I32(tokens.clone()), targets.clone())
        }
        MicroBatch::Class { x, labels } => {
            (HostTensor::F32(x.clone()), labels.clone())
        }
    };
    let mut inputs: Vec<HostTensor> = vec![x0];
    for j in 0..n - 1 {
        let y = rt.stage_fwd_flat(j, store.select(rule, i, j), &inputs[j])?;
        inputs.push(HostTensor::F32(y));
    }
    let last = n - 1;
    let (loss, mut gx) = rt.last_bwd_flat(
        store.select(rule, i, last),
        inputs[last].as_f32().unwrap(),
        &targets,
        &mut gmb[layout.stage_range(last)],
    )?;
    for j in (1..last).rev() {
        gx = rt.mid_bwd_flat(
            j,
            store.select(rule, i, j),
            inputs[j].as_f32().unwrap(),
            &gx,
            &mut gmb[layout.stage_range(j)],
        )?;
    }
    if n > 1 {
        rt.first_bwd_flat(
            store.select(rule, i, 0),
            &inputs[0],
            &gx,
            &mut gmb[layout.stage_range(0)],
        )?;
    }
    Ok(loss)
}

/// DP worker: compute → barrier all-reduce → identical local update.
fn worker_dp(
    rt: &SharedRuntime,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
) -> Result<Vec<StepLog>> {
    let n = rt.manifest.n_stages;
    let layout = ArenaLayout::from_manifest(&rt.manifest);
    let mut store = ParamStore::from_flat(layout.clone(), rt.init_params_flat()?);
    let data = DataSource::from_manifest(&rt.manifest);
    let mut gmb = layout.zeros();
    let mut logs = Vec::new();

    for t in 0..steps as u64 {
        let loss = compute_grads(rt, &store, &data, rule, t, w + 1, &mut gmb)?;

        // synchronous all-reduce over the model-wide gradient run (the
        // paper's waiting barrier); rank-ordered sum + 1/N at the root
        allreduce_mean(ep, t, &mut gmb);

        // every replica applies the identical update (N optimizer copies)
        let lr = rt.manifest.lr;
        for j in 0..n {
            let (cur, moms, next) = store.update_parts(j);
            rt.sgd_update_flat(j, cur, moms, &gmb[layout.stage_range(j)], lr, next)?;
        }
        store.commit_step();

        // loss reporting: mean over micro-batches, gathered at worker 0
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..ep.n {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / ep.n as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok(logs)
}

/// CDP worker: ring partial sums per stage, single optimizer owner
/// (micro-batch N = worker n−1), param hand-off around the ring.
fn worker_ring(
    rt: &SharedRuntime,
    rule: &Rule,
    ep: &mut Endpoint,
    w: usize,
    steps: usize,
) -> Result<Vec<StepLog>> {
    let n = rt.manifest.n_stages;
    let n_mb = ep.n;
    let owner = n_mb - 1; // worker of micro-batch N: the only optimizer state
    let layout = ArenaLayout::from_manifest(&rt.manifest);
    let mut store = ParamStore::from_flat(layout.clone(), rt.init_params_flat()?);
    let data = DataSource::from_manifest(&rt.manifest);
    let mut gmb = layout.zeros();
    let mut logs = Vec::new();
    let lr = rt.manifest.lr;
    let inv = 1.0 / n_mb as f32;

    for t in 0..steps as u64 {
        let loss = compute_grads(rt, &store, &data, rule, t, w + 1, &mut gmb)?;

        // --- balanced gradient reduction: partial sums travel the ring in
        // micro-batch order (worker 0 = mb 1 starts; each adds its own and
        // forwards), one stage at a time — the Fig 1c hand-off.  Received
        // payloads are mutated in place (unique handles) and re-sent, so a
        // hop neither copies nor allocates.  The owner ends up with
        // Σ_i ∇f_i in exactly the reference sum order, averages while
        // adding its own contribution (fused), updates the stage and hands
        // the fresh parameters down the ring.
        for j in 0..n {
            let range = layout.stage_range(j);
            if n_mb == 1 {
                // single worker: own grads are the full sum
                let g = &mut gmb[range];
                ops::scale(g, inv);
                let (cur, moms, next) = store.update_parts(j);
                rt.sgd_update_flat(j, cur, moms, g, lr, next)?;
            } else if w == 0 {
                ep.send_copy(1, tags::grad(t, j), &gmb[range]);
            } else {
                let mut part = ep.recv(w - 1, tags::grad(t, j));
                if w < owner {
                    ops::add_into(part.make_mut(), &gmb[range]);
                    ep.send(w + 1, tags::grad(t, j), part);
                } else {
                    // owner: add own contribution and average in one pass
                    ops::add_scale(part.make_mut(), &gmb[range], inv);
                    let (cur, moms, next) = store.update_parts(j);
                    rt.sgd_update_flat(j, cur, moms, &part, lr, next)?;
                    ep.send_copy(ep.right(), tags::param(t, j), store.next_stage(j));
                }
            }
        }

        // --- non-owners: fresh stage params hop the ring from the owner;
        // forward the payload by handle, then write it into the next slot
        if w != owner && n_mb > 1 {
            for j in 0..n {
                let flat = ep.recv(ep.left(), tags::param(t, j));
                if ep.right() != owner {
                    ep.send(ep.right(), tags::param(t, j), flat.clone());
                }
                store.write_next(j, &flat);
            }
        }
        store.commit_step();

        // loss gathering at worker 0 (mb order)
        if ep.id == 0 {
            let mut sum = loss as f64;
            for from in 1..n_mb {
                sum += ep.recv(from, tags::loss(t))[0] as f64;
            }
            logs.push(StepLog { step: t, loss: sum / n_mb as f64 });
        } else {
            ep.send(0, tags::loss(t), vec![loss]);
        }
    }
    Ok(logs)
}

/// Convenience: comm stats snapshot type re-export.
pub type Stats = Arc<CommStats>;
