//! Summary statistics + timing helpers for benches and metrics.

use std::time::{Duration, Instant};

/// Online mean/min/max/percentile summary over f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// p in [0, 100]; nearest-rank on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[rank.min(s.len() - 1)]
    }
}

/// Measure a closure `iters` times after `warmup` runs; returns per-iter
/// durations.  The criterion-less bench substrate.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<Duration> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect()
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }

    #[test]
    fn bench_returns_iters() {
        let d = bench(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(d.len(), 5);
    }
}
