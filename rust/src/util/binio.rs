//! Binary IO for parameter blobs: `params.bin` is little-endian f32,
//! stage-major, manifest order (written by `python/compile/aot.py`) —
//! plus the little-endian cursor primitives ([`ByteWriter`] /
//! [`ByteReader`]) and the FNV-1a checksum the checkpoint format
//! (`parallel::checkpoint`, DESIGN-ROBUSTNESS.md) is built from.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// FNV-1a, 64-bit — checkpoint integrity checksum.  Not cryptographic;
/// it catches truncation and bit rot, which is the failure model for
/// local checkpoint files.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Append-only little-endian byte buffer for fixed-layout records.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed (u32) UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Raw f32 slice, little-endian, no length prefix (the record's
    /// layout carries the lengths).
    pub fn f32_slice(&mut self, data: &[f32]) {
        self.buf.reserve(data.len() * 4);
        for v in data {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Current contents (e.g. to checksum before appending the digest).
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian cursor over a byte slice.  Every read
/// returns `Err` on truncation instead of panicking — a half-written
/// checkpoint must surface as a diagnosable error.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Bytes consumed so far (everything before the cursor).
    pub fn consumed(&self) -> &'a [u8] {
        &self.buf[..self.pos]
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.remaining() >= n,
            "truncated record: wanted {n} bytes at offset {}, {} left",
            self.pos,
            self.remaining()
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Length-prefixed (u32) UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let b = self.bytes(len)?;
        Ok(std::str::from_utf8(b)
            .context("record string is not UTF-8")?
            .to_string())
    }

    /// `n` little-endian f32 values.
    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Read a whole file of little-endian f32 values.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let mut f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{path:?}: length {} not a multiple of 4",
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write little-endian f32 values (checkpointing).
pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    for v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cdp_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let data = vec![0.0f32, -1.5, 3.25, f32::MIN_POSITIVE, 1e30];
        write_f32_file(&p, &data).unwrap();
        let back = read_f32_file(&p).unwrap();
        assert_eq!(back, data);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn byte_cursor_round_trips() {
        let mut w = ByteWriter::new();
        w.u32(7);
        w.u64(u64::MAX - 3);
        w.str("cdp-v2");
        w.f32_slice(&[0.0, -1.5, f32::MIN_POSITIVE, 1e30]);
        let body_sum = fnv1a64(w.as_slice());
        w.u64(body_sum);
        let bytes = w.finish();

        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.str().unwrap(), "cdp-v2");
        assert_eq!(r.f32_vec(4).unwrap(), vec![0.0, -1.5, f32::MIN_POSITIVE, 1e30]);
        assert_eq!(fnv1a64(r.consumed()), body_sum);
        assert_eq!(r.u64().unwrap(), body_sum);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn byte_reader_rejects_truncation() {
        let mut w = ByteWriter::new();
        w.u64(42);
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes[..5]);
        assert!(r.u64().is_err());
        let mut r2 = ByteReader::new(&bytes);
        assert!(r2.str().is_err(), "u64 misread as huge string length errors");
    }

    #[test]
    fn fnv1a64_known_answers() {
        // Pinned vectors from the FNV reference implementation.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn rejects_misaligned() {
        let dir = std::env::temp_dir().join("cdp_binio_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [1u8, 2, 3]).unwrap();
        assert!(read_f32_file(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
