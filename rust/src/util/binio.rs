//! Binary IO for parameter blobs: `params.bin` is little-endian f32,
//! stage-major, manifest order (written by `python/compile/aot.py`).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// Read a whole file of little-endian f32 values.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let mut f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{path:?}: length {} not a multiple of 4",
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write little-endian f32 values (checkpointing).
pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    for v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cdp_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let data = vec![0.0f32, -1.5, 3.25, f32::MIN_POSITIVE, 1e30];
        write_f32_file(&p, &data).unwrap();
        let back = read_f32_file(&p).unwrap();
        assert_eq!(back, data);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_misaligned() {
        let dir = std::env::temp_dir().join("cdp_binio_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [1u8, 2, 3]).unwrap();
        assert!(read_f32_file(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
