//! Substrate utilities built from scratch (no crates.io access — DESIGN.md
//! substitution #4): JSON, deterministic RNG, binary IO, summary stats.

pub mod binio;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
