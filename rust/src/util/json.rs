//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Covers the full JSON grammar needed by bundle manifests, golden traces
//! and metric dumps: objects, arrays, strings (with escapes), numbers,
//! bools, null.  Numbers are held as f64 (all our payloads — shapes,
//! losses, byte counts — fit exactly or tolerate f64).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors (ergonomics for manifest reading) -------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            // Non-finite numbers ride as string sentinels (see `write`);
            // map them back so emit → parse → as_f64 round-trips.
            Json::Str(s) => match s.as_str() {
                "NaN" => Some(f64::NAN),
                "Infinity" => Some(f64::INFINITY),
                "-Infinity" => Some(f64::NEG_INFINITY),
                _ => None,
            },
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// `obj["a"]["b"][2]`-style access: `j.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid usize field `{key}`"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid f64 field `{key}`"))
    }

    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid str field `{key}`"))
    }

    pub fn arr_field(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    // ---- emission --------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // Bare `NaN`/`inf` is invalid JSON; emit the string
                    // sentinels `as_f64` maps back to non-finite f64s.
                    out.push('"');
                    out.push_str(if n.is_nan() {
                        "NaN"
                    } else if *n > 0.0 {
                        "Infinity"
                    } else {
                        "-Infinity"
                    });
                    out.push('"');
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for emission sites.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // re-sync to char boundary for multi-byte utf-8
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.str_field("c").unwrap(), "x");
        assert!(j.at(&["a"]).unwrap().as_arr().unwrap()[2]
            .get("b")
            .unwrap()
            .is_null());
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",false,null],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""héllo ∂""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ∂");
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn non_finite_numbers_emit_valid_json_and_round_trip() {
        for (v, sentinel) in [
            (f64::NAN, "\"NaN\""),
            (f64::INFINITY, "\"Infinity\""),
            (f64::NEG_INFINITY, "\"-Infinity\""),
        ] {
            let text = Json::Num(v).to_string();
            assert_eq!(text, sentinel);
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{sentinel}");
        }
        // Embedded in a structure, the document stays parseable.
        let j = obj(vec![("bad", Json::Num(f64::NAN)), ("ok", Json::Num(1.0))]);
        let re = Json::parse(&j.to_string()).unwrap();
        assert!(re.get("bad").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(re.get("ok").unwrap().as_f64(), Some(1.0));
    }
}
