//! Minimal fixed-size worker pool for data-parallel kernel execution.
//!
//! rayon is unavailable offline (the DESIGN.md no-crates substitution
//! applies to parallelism too), so this module provides the small subset
//! the dense kernels need: fan a closure over `0..total` block indices
//! across a lazily spawned, process-wide worker pool, block the submitter
//! until every index has run, and do all of that **without allocating** in
//! steady state — the job slot is inline in the pool, not boxed per call,
//! so parallel kernels stay compatible with the hot-path bench's
//! zero-allocation windows (warm the pool first, see [`warm`]).
//!
//! Determinism contract: the pool only ever *partitions* work; it never
//! reorders arithmetic.  Callers must hand it element- or row-independent
//! block bodies (each output element fully computed by exactly one index),
//! which is what keeps kernel results bit-identical at every thread count
//! — see DESIGN-PERF.md §Kernel architecture and the
//! `kernel_equivalence` suite.
//!
//! Thread count: `RAYON_NUM_THREADS` (the conventional knob) if set and
//! ≥ 1, else `std::thread::available_parallelism()`.  A value of 1
//! disables the pool entirely — every [`run`] call executes inline on the
//! caller's thread.  [`with_threads`] overrides the *partitioning target*
//! on the current thread (used by the thread-count-invariance tests).
//!
//! Concurrency notes: one job runs at a time (`submit` mutex).  A caller
//! that finds the pool busy — e.g. two coordinator worker threads hitting
//! a parallel kernel at once — falls back to inline serial execution,
//! which by the determinism contract yields the same bits.  Stale workers
//! are fenced by an epoch tag in the claim ticket: an index can only be
//! claimed by CAS on a ticket whose epoch matches the job the worker
//! snapshotted, so a descheduled worker can never run a stale closure
//! against a newer job's indices.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock, TryLockError};

/// Low 32 bits of the claim ticket: next unclaimed index.  High 32 bits:
/// the job epoch (wraps at 2³² runs; a worker would have to stay
/// descheduled across 2³² submissions to be fooled, which we accept).
const INDEX_MASK: u64 = (1 << 32) - 1;

/// Raw pointer to the submitter's closure.  Only dereferenced for indices
/// claimed through the epoch-checked ticket CAS, and the submitter does
/// not return until `done == total`, so every dereference happens while
/// the closure is alive on the submitter's stack.
#[derive(Clone, Copy)]
struct FnPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: see FnPtr docs — lifetime is enforced by the done-counter wait,
// and the pointee is `Sync` so shared cross-thread calls are fine.
unsafe impl Send for FnPtr {}
unsafe impl Sync for FnPtr {}

/// The published job: guarded by the slot mutex, snapshotted by workers.
struct Slot {
    epoch: u64,
    func: Option<FnPtr>,
    total: usize,
}

struct Inner {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// `(epoch & 0xffff_ffff) << 32 | next_index` — claims CAS this.
    ticket: AtomicU64,
    done: AtomicUsize,
    panicked: AtomicBool,
}

struct Pool {
    inner: Inner,
    /// Serializes submitters; busy callers fall back to inline serial.
    submit: Mutex<()>,
}

/// The process-wide pool, spawned on first parallel submission and
/// intentionally leaked (workers live for the process lifetime, parked on
/// `work_cv` when idle).  `None` when the configured thread count is 1.
fn pool() -> Option<&'static Pool> {
    static POOL: OnceLock<Option<&'static Pool>> = OnceLock::new();
    *POOL.get_or_init(|| {
        let n = configured_threads();
        if n <= 1 {
            return None;
        }
        let p: &'static Pool = Box::leak(Box::new(Pool {
            inner: Inner {
                slot: Mutex::new(Slot { epoch: 0, func: None, total: 0 }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                ticket: AtomicU64::new(0),
                done: AtomicUsize::new(0),
                panicked: AtomicBool::new(false),
            },
            submit: Mutex::new(()),
        }));
        // n − 1 workers: the submitting thread is the n-th participant.
        for w in 0..n - 1 {
            std::thread::Builder::new()
                .name(format!("cdp-kern-{w}"))
                .spawn(move || worker(&p.inner))
                .expect("spawn kernel pool worker");
        }
        Some(p)
    })
}

fn worker(inner: &'static Inner) {
    let mut seen = 0u64;
    loop {
        let (func, total, epoch) = {
            let mut s = inner.slot.lock().unwrap();
            loop {
                if s.epoch != seen {
                    seen = s.epoch;
                    if let Some(f) = s.func {
                        break (f, s.total, s.epoch);
                    }
                    // epoch advanced but the job already retired — keep
                    // waiting for the next one.
                }
                s = inner.work_cv.wait(s).unwrap();
            }
        };
        execute(inner, func, total, epoch);
    }
}

/// Claim-and-run loop shared by workers and the submitter.  Claims are
/// epoch-fenced CASes, so once a job's `done` count reaches `total` no
/// further claim on it can succeed — the invariant that makes the raw
/// closure pointer sound.
fn execute(inner: &Inner, func: FnPtr, total: usize, epoch: u64) {
    let tag = (epoch & INDEX_MASK) << 32;
    loop {
        let cur = inner.ticket.load(Ordering::Acquire);
        if cur & !INDEX_MASK != tag {
            return; // a newer job owns the ticket
        }
        let idx = (cur & INDEX_MASK) as usize;
        if idx >= total {
            return; // every index claimed
        }
        if inner
            .ticket
            .compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            continue;
        }
        // SAFETY: the claim succeeded under the live epoch, so the
        // submitter is still blocked in `run` and the closure is alive.
        let f = unsafe { &*func.0 };
        if catch_unwind(AssertUnwindSafe(|| f(idx))).is_err() {
            inner.panicked.store(true, Ordering::Relaxed);
        }
        if inner.done.fetch_add(1, Ordering::AcqRel) + 1 == total {
            // Lock-then-notify so the submitter can't miss the wakeup
            // between its predicate check and its wait.
            let _g = inner.slot.lock().unwrap();
            inner.done_cv.notify_all();
        }
    }
}

/// The configured pool width: `RAYON_NUM_THREADS` if set and ≥ 1, else
/// the machine's available parallelism.  Read once per process.
pub fn configured_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("RAYON_NUM_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

thread_local! {
    static OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The thread count [`run`] partitions for on the current thread: the
/// [`with_threads`] override if one is active, else [`configured_threads`].
pub fn effective_threads() -> usize {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(configured_threads)
}

/// Run `f` with the partitioning target overridden to `n` on this thread
/// (restored on exit, panic-safe).  `n = 1` forces fully inline serial
/// execution — the reference arm of the thread-count-invariance tests.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let _restore = Restore(OVERRIDE.with(|o| o.replace(Some(n.max(1)))));
    f()
}

/// Spawn the pool's workers and run one trivial job through them, so the
/// one-time setup (thread spawn, stacks, lazy statics) happens *before*
/// any allocation-counting window opens.  Cheap and idempotent.
pub fn warm() {
    let n = configured_threads();
    if n > 1 {
        run(n * 2, |_| {});
    }
}

/// Call `f(i)` for every `i in 0..total`, fanned across the pool; returns
/// when all indices have run.  Falls back to inline serial execution when
/// the pool is width-1, busy, or `total == 1` — identical results either
/// way, because callers only submit index-independent bodies (the module
/// determinism contract).  Steady-state allocation-free.  Re-raises as a
/// panic on the submitting thread if any index's body panicked.
pub fn run<F: Fn(usize) + Sync>(total: usize, f: F) {
    let serial = |f: &F| {
        for i in 0..total {
            f(i);
        }
    };
    if total == 0 {
        return;
    }
    if total == 1 || effective_threads() <= 1 {
        serial(&f);
        return;
    }
    let Some(p) = pool() else {
        serial(&f);
        return;
    };
    let guard = match p.submit.try_lock() {
        Ok(g) => g,
        // Busy (another submitter, possibly this thread re-entering from
        // inside a parallel body): run inline.
        Err(TryLockError::WouldBlock) => {
            serial(&f);
            return;
        }
        // A previous submitter re-raised a body panic while holding the
        // lock; the pool state was already retired cleanly — recover.
        Err(TryLockError::Poisoned(pe)) => pe.into_inner(),
    };
    assert!(total < INDEX_MASK as usize, "par::run: total out of ticket range");
    let fobj: &(dyn Fn(usize) + Sync) = &f;
    let fp = FnPtr(fobj as *const _);
    let epoch;
    {
        let mut s = p.inner.slot.lock().unwrap();
        s.epoch += 1;
        epoch = s.epoch;
        s.func = Some(fp);
        s.total = total;
        p.inner.done.store(0, Ordering::Relaxed);
        p.inner.panicked.store(false, Ordering::Relaxed);
        p.inner.ticket.store((epoch & INDEX_MASK) << 32, Ordering::Release);
        p.inner.work_cv.notify_all();
    }
    // The submitter is a full participant.
    execute(&p.inner, fp, total, epoch);
    {
        let mut s = p.inner.slot.lock().unwrap();
        while p.inner.done.load(Ordering::Acquire) < total {
            s = p.inner.done_cv.wait(s).unwrap();
        }
        s.func = None;
    }
    drop(guard);
    if p.inner.panicked.load(Ordering::Relaxed) {
        panic!("par::run: a parallel kernel task panicked");
    }
}

/// Fan disjoint `chunk`-sized pieces of `data` across the pool:
/// `f(block_index, piece)` where piece `b` is `data[b·chunk ..]` clipped
/// to `chunk` elements.  The mutable splits are disjoint by construction,
/// which is what makes handing them to concurrent workers sound.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "par_chunks_mut: zero chunk");
    let len = data.len();
    if len == 0 {
        return;
    }
    let nblocks = len.div_ceil(chunk);
    let ptr = SendPtr(data.as_mut_ptr());
    run(nblocks, move |b| {
        let start = b * chunk;
        let n = chunk.min(len - start);
        // SAFETY: blocks index disjoint ranges of one live &mut slice.
        let piece = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), n) };
        f(b, piece);
    });
}

/// Number of blocks to split `total` work items into: enough for load
/// balance (4 blocks per effective thread) but never finer than
/// `min_per_block` items.  Partitioning never affects result bits (the
/// module determinism contract), so this may vary with thread count.
pub fn partition(total: usize, min_per_block: usize) -> usize {
    if total == 0 {
        return 1;
    }
    let max_blocks = total.div_ceil(min_per_block.max(1));
    (effective_threads() * 4).clamp(1, max_blocks)
}

/// Wrapper making a raw pointer shippable to pool workers.  The caller
/// asserts that concurrent uses touch disjoint memory — used by kernels
/// that update several parallel arrays (e.g. params + momentum) in one
/// partitioned pass.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

// SAFETY: caller-asserted disjointness (see type docs).
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let counts: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        run(counts.len(), |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn par_chunks_mut_writes_disjoint_pieces() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 64, |b, piece| {
            for (j, x) in piece.iter_mut().enumerate() {
                *x = (b * 64 + j) as u32;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
    }

    #[test]
    fn serial_override_matches_parallel() {
        let work = |blocks: usize| {
            let out: Vec<AtomicU64> = (0..blocks).map(|_| AtomicU64::new(0)).collect();
            run(blocks, |i| {
                out[i].store((i as u64).wrapping_mul(0x9E37_79B9), Ordering::Relaxed);
            });
            out.iter().map(|x| x.load(Ordering::Relaxed)).collect::<Vec<_>>()
        };
        let par = work(100);
        let ser = with_threads(1, || work(100));
        assert_eq!(par, ser);
    }

    #[test]
    fn nested_run_falls_back_to_serial() {
        let hits = AtomicUsize::new(0);
        run(4, |_| {
            run(4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panics_propagate_to_submitter() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            run(8, |i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // the pool must still be usable afterwards
        let n = AtomicUsize::new(0);
        run(8, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn partition_respects_min_block() {
        with_threads(8, || {
            assert_eq!(partition(0, 16), 1);
            assert_eq!(partition(10, 16), 1);
            assert_eq!(partition(1000, 16), 32); // 8 threads × 4
            assert_eq!(partition(64, 16), 4); // capped by min_per_block
        });
    }
}
