//! Deterministic RNG, **bit-compatible with `python/compile/datagen.py`**.
//!
//! The cross-language golden tests rest on this contract: both sides
//! implement xorshift64*, the 24-bit-mantissa uniform, the sequential
//! 12-uniform Irwin–Hall normal (f32 accumulation order matters!), and the
//! splitmix64-based per-(step, micro-batch) seed derivation.  Known-answer
//! values are pinned in both test suites.

pub const PHI64: u64 = 0x9E37_79B9_7F4A_7C15;

/// xorshift64* — 2^64−1 period, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct XorShift64Star {
    s: u64,
}

impl XorShift64Star {
    pub fn new(seed: u64) -> Self {
        Self { s: if seed == 0 { PHI64 } else { seed } }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut s = self.s;
        s ^= s >> 12;
        s ^= s << 25;
        s ^= s >> 27;
        self.s = s;
        s.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform integer in [0, n).  Matches python's `% n` (modulo bias is
    /// irrelevant here and identical on both sides).
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// f32 in [0, 1) with exactly 24 bits of mantissa (always exact).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Irwin–Hall(12) − 6 ≈ N(0,1); summed sequentially in f32 to match
    /// python bit-for-bit.
    pub fn normal(&mut self) -> f32 {
        let mut acc = 0f32;
        for _ in 0..12 {
            acc += self.uniform();
        }
        acc - 6.0
    }
}

/// splitmix64 finalizer; used to derive independent stream seeds.
pub fn splitmix64(x: u64) -> u64 {
    let x = x.wrapping_add(PHI64);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seed for micro-batch `mb` of training step `step`.
pub fn microbatch_seed(base: u64, step: u64, mb: u64) -> u64 {
    splitmix64(base ^ step.wrapping_mul(1_000_003).wrapping_add(mb + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let mut a = XorShift64Star::new(42);
        let mut b = XorShift64Star::new(42);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(va, vb);
        let set: std::collections::HashSet<_> = va.iter().collect();
        assert_eq!(set.len(), 8);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64Star::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn python_contract_xorshift() {
        // Pinned against python: XorShift64Star(42).next_u64() etc.
        // (python computes: s=42 -> first output 7766321926531936011)
        let mut r = XorShift64Star::new(42);
        let first = r.next_u64();
        // recompute by hand to lock the algorithm (not just determinism)
        let mut s: u64 = 42;
        s ^= s >> 12;
        s ^= s << 25;
        s ^= s >> 27;
        assert_eq!(first, s.wrapping_mul(0x2545_F491_4F6C_DD1D));
    }

    #[test]
    fn uniform_in_range_and_exact() {
        let mut r = XorShift64Star::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            let scaled = u * (1u32 << 24) as f32;
            assert_eq!(scaled, scaled.trunc());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShift64Star::new(11);
        let xs: Vec<f32> = (0..4000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.1, "std={}", var.sqrt());
    }

    #[test]
    fn microbatch_seeds_unique() {
        let mut seen = std::collections::HashSet::new();
        for t in 0..50u64 {
            for i in 0..8u64 {
                assert!(seen.insert(microbatch_seed(42, t, i)));
            }
        }
    }
}
