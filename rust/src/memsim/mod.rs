//! Activation-memory tracking and the paper's Fig 4 extrapolation.
//!
//! Method (paper §5, "Activation memory tracking"): record the activation
//! memory curve A(τ) of one forward-backward pass (parameter memory
//! subtracted), then extrapolate to N workers:
//!
//! - DP: all workers execute in phase, so per-worker memory is A(τ)
//!   itself — it peaks at the fwd/bwd turning point.
//! - CDP: worker i is phase-shifted by 2T·(i−1)/N, so per-worker memory is
//!   the *cyclic mean* (1/N)·Σ_i A((τ + offset_i) mod 2T), which flattens
//!   toward mean(A) as N grows.
//!
//! The ratio 1 − mean(A)/max(A) is the CDP saving: ≈ 50% for homogeneous
//! layer profiles (ViT — every layer same memory and time), less for
//! heterogeneous ones (ResNet — early layers hold much larger activations
//! for the same compute time).

pub mod profiles;

pub use profiles::{resnet50_profile, vit_b16_profile, LayerProfile};

/// Activation memory curve over one fwd+bwd pass, sampled at layer
/// boundaries with per-layer durations ∝ FLOPs.
#[derive(Clone, Debug)]
pub struct MemoryCurve {
    /// (time, live activation bytes) — time normalized to [0, 1].
    pub points: Vec<(f64, f64)>,
}

impl MemoryCurve {
    /// Build from per-layer (act_bytes, flops): forward accumulates stashes
    /// in layer order, backward releases in reverse; each layer occupies
    /// wall-time ∝ its flops (fwd) and 2× that (bwd, standard cost model).
    pub fn from_layers(layers: &[LayerProfile]) -> Self {
        let total_fwd: f64 = layers.iter().map(|l| l.flops as f64).sum();
        let total = 3.0 * total_fwd; // fwd + 2×bwd
        let mut points = Vec::with_capacity(2 * layers.len() + 2);
        let mut t = 0.0;
        let mut live = 0.0;
        points.push((0.0, 0.0));
        for l in layers {
            t += l.flops as f64 / total;
            live += l.act_bytes as f64;
            points.push((t, live));
        }
        for l in layers.iter().rev() {
            t += 2.0 * l.flops as f64 / total;
            live -= l.act_bytes as f64;
            points.push((t, live.max(0.0)));
        }
        Self { points }
    }

    /// Piecewise-linear sample at time τ ∈ [0, 1].
    pub fn at(&self, tau: f64) -> f64 {
        let tau = tau.rem_euclid(1.0);
        let pts = &self.points;
        for w in pts.windows(2) {
            let (t0, v0) = w[0];
            let (t1, v1) = w[1];
            if tau >= t0 && tau <= t1 {
                if t1 - t0 < 1e-12 {
                    return v1;
                }
                let f = (tau - t0) / (t1 - t0);
                return v0 + f * (v1 - v0);
            }
        }
        pts.last().map(|(_, v)| *v).unwrap_or(0.0)
    }

    pub fn peak(&self) -> f64 {
        self.points.iter().map(|(_, v)| *v).fold(0.0, f64::max)
    }

    /// Time-weighted mean of the curve.
    pub fn mean(&self) -> f64 {
        let mut acc = 0.0;
        for w in self.points.windows(2) {
            let (t0, v0) = w[0];
            let (t1, v1) = w[1];
            acc += (t1 - t0) * (v0 + v1) / 2.0;
        }
        acc
    }
}

/// Per-worker memory over time for DP and CDP with N workers (Fig 4).
#[derive(Clone, Debug)]
pub struct Extrapolation {
    pub n: usize,
    /// samples of (τ, dp_bytes, cdp_bytes)
    pub samples: Vec<(f64, f64, f64)>,
    pub dp_peak: f64,
    pub cdp_peak: f64,
    /// 1 − cdp_peak/dp_peak: the paper's reported reduction.
    pub reduction: f64,
}

/// Extrapolate a single-pass curve to N workers (paper's Fig 4 method).
pub fn extrapolate(curve: &MemoryCurve, n: usize, samples: usize) -> Extrapolation {
    let mut out = Vec::with_capacity(samples);
    let mut dp_peak = 0.0f64;
    let mut cdp_peak = 0.0f64;
    for s in 0..samples {
        let tau = s as f64 / samples as f64;
        // DP: every worker is at phase τ simultaneously.
        let dp = curve.at(tau);
        // CDP: workers at staggered phases; per-worker = mean over phases.
        let cdp = (0..n)
            .map(|i| curve.at(tau + i as f64 / n as f64))
            .sum::<f64>()
            / n as f64;
        dp_peak = dp_peak.max(dp);
        cdp_peak = cdp_peak.max(cdp);
        out.push((tau, dp, cdp));
    }
    Extrapolation {
        n,
        samples: out,
        dp_peak,
        cdp_peak,
        reduction: 1.0 - cdp_peak / dp_peak.max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn homogeneous(nl: usize) -> Vec<LayerProfile> {
        (0..nl)
            .map(|i| LayerProfile {
                name: format!("l{i}"),
                act_bytes: 1_000_000,
                flops: 1_000_000_000,
            })
            .collect()
    }

    #[test]
    fn curve_shape_triangle_for_homogeneous() {
        let c = MemoryCurve::from_layers(&homogeneous(10));
        assert_eq!(c.peak(), 10.0e6);
        // mean of a triangle ≈ half the peak
        assert!((c.mean() / c.peak() - 0.5).abs() < 0.05);
        // starts and ends at zero
        assert_eq!(c.points.first().unwrap().1, 0.0);
        assert!(c.points.last().unwrap().1.abs() < 1.0);
    }

    #[test]
    fn extrapolation_flattens_with_n() {
        let c = MemoryCurve::from_layers(&homogeneous(24));
        let e4 = extrapolate(&c, 4, 512);
        let e32 = extrapolate(&c, 32, 512);
        assert!(e32.cdp_peak < e4.cdp_peak);
        assert_eq!(e4.dp_peak, e32.dp_peak);
        // homogeneous profile → approaches the ideal halving
        assert!(e32.reduction > 0.40, "reduction {}", e32.reduction);
        assert!(e32.reduction < 0.55);
    }

    #[test]
    fn cdp_never_exceeds_dp_peak() {
        let c = MemoryCurve::from_layers(&resnet50_profile(32));
        for n in [2usize, 4, 8, 32] {
            let e = extrapolate(&c, n, 256);
            assert!(e.cdp_peak <= e.dp_peak * 1.0001, "n={n}");
            for (_, _, cdp) in &e.samples {
                assert!(*cdp <= e.dp_peak * 1.0001);
            }
        }
    }

    #[test]
    fn heterogeneous_saves_less_than_homogeneous() {
        // the paper's ResNet-vs-ViT observation (≈30% vs ≈42%)
        let r = extrapolate(&MemoryCurve::from_layers(&resnet50_profile(32)), 32, 512);
        let v = extrapolate(&MemoryCurve::from_layers(&vit_b16_profile(32)), 32, 512);
        assert!(
            v.reduction > r.reduction,
            "vit {:.3} should beat resnet {:.3}",
            v.reduction,
            r.reduction
        );
    }
}
