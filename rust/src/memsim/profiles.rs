//! Analytic activation/FLOP profiles for the two Fig-4 architectures
//! (DESIGN.md substitution #3: we cannot train ResNet-50 / ViT-B/16 on
//! ImageNet here, but Fig 4 only needs their per-layer activation-memory
//! and compute-time profiles, which follow from the architectures).
//!
//! Conventions: ImageNet input 224×224×3, f32 activations, batch = B
//! (per micro-batch).  `act_bytes` is the stash a layer holds awaiting its
//! backward (≈ its output plus internal intermediates), `flops` its
//! forward compute — what sets its share of wall-time in the memory curve.

#[derive(Clone, Debug)]
pub struct LayerProfile {
    pub name: String,
    pub act_bytes: u64,
    pub flops: u64,
}

/// ResNet-50: stem + 16 bottleneck blocks (3/4/6/3) + head.
/// Heterogeneous: early blocks hold ~4× the activations of late blocks at
/// similar FLOPs — the reason the paper measures only ~30% saving.
pub fn resnet50_profile(batch: u64) -> Vec<LayerProfile> {
    let mut out = Vec::new();
    let f32b = 4u64;
    // stem: conv7x7/2 → 112²×64 (+ pooled 56²×64)
    let stem_out = 112 * 112 * 64 + 56 * 56 * 64;
    out.push(LayerProfile {
        name: "stem".into(),
        act_bytes: batch * stem_out * f32b,
        flops: batch * 2 * 7 * 7 * 3 * 64 * 112 * 112,
    });
    // (stage, blocks, hw, c_out) with bottleneck width c_out/4
    let stages: [(usize, u64, u64); 4] =
        [(3, 56, 256), (4, 28, 512), (6, 14, 1024), (3, 7, 2048)];
    for (si, (blocks, hw, c)) in stages.iter().enumerate() {
        let width = c / 4;
        for b in 0..*blocks {
            // intermediates: two width-sized maps + one c-sized output
            let act = batch * (2 * hw * hw * width + hw * hw * c) * f32b;
            // three convs: 1x1 c→w, 3x3 w→w, 1x1 w→c (input ch ≈ c)
            let fl = batch
                * 2
                * hw
                * hw
                * (c * width + 9 * width * width + width * c);
            out.push(LayerProfile {
                name: format!("s{}b{}", si + 1, b),
                act_bytes: act,
                flops: fl,
            });
        }
    }
    // head: pool + fc
    out.push(LayerProfile {
        name: "head".into(),
        act_bytes: batch * 2048 * f32b,
        flops: batch * 2 * 2048 * 1000,
    });
    out
}

/// ViT-B/16: patch embed + 12 identical transformer layers + head.
/// Homogeneous: every layer stashes the same bytes and costs the same
/// FLOPs — CDP approaches the ideal halving (paper: 42%).
pub fn vit_b16_profile(batch: u64) -> Vec<LayerProfile> {
    let f32b = 4u64;
    let s = 197u64; // 14×14 patches + CLS
    let d = 768u64;
    let ff = 3072u64;
    let heads = 12u64;
    let mut out = Vec::new();
    out.push(LayerProfile {
        name: "patch_embed".into(),
        act_bytes: batch * s * d * f32b,
        flops: batch * 2 * s * (16 * 16 * 3) * d,
    });
    for l in 0..12 {
        // stash: ln, qkv, attn probs (h·s²), attn out, mlp hidden, out
        let act = batch * (4 * s * d + heads * s * s + s * ff) * f32b;
        let fl = batch * 2 * s * (4 * d * d + 2 * d * ff) + batch * 4 * heads * s * s * (d / heads);
        out.push(LayerProfile {
            name: format!("layer{l}"),
            act_bytes: act,
            flops: fl,
        });
    }
    out.push(LayerProfile {
        name: "head".into(),
        act_bytes: batch * d * f32b,
        flops: batch * 2 * d * 1000,
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_is_heterogeneous() {
        let p = resnet50_profile(1);
        // first stage blocks hold much more activation than last stage
        let early = p[1].act_bytes as f64;
        let late = p[p.len() - 2].act_bytes as f64;
        assert!(early / late > 2.0, "early {early} late {late}");
    }

    #[test]
    fn vit_is_homogeneous() {
        let p = vit_b16_profile(1);
        let layers = &p[1..13];
        let first = layers[0].act_bytes;
        for l in layers {
            assert_eq!(l.act_bytes, first);
            assert_eq!(l.flops, layers[0].flops);
        }
    }

    #[test]
    fn profiles_are_nonempty_with_exact_layer_counts() {
        // stem + 16 bottleneck blocks (3/4/6/3) + head
        assert_eq!(resnet50_profile(1).len(), 18);
        // patch embed + 12 transformer layers + head
        assert_eq!(vit_b16_profile(1).len(), 14);
        for l in resnet50_profile(1).iter().chain(vit_b16_profile(1).iter()) {
            assert!(l.act_bytes > 0, "{} stashes nothing", l.name);
            assert!(l.flops > 0, "{} costs nothing", l.name);
        }
    }

    #[test]
    fn byte_totals_match_closed_forms() {
        let b = 8u64;
        let f32b = 4u64;

        // ViT-B/16: B·4·[s·d + 12·(4·s·d + h·s² + s·ff) + d]
        let (s, d, ff, heads) = (197u64, 768u64, 3072u64, 12u64);
        let vit_expect =
            b * f32b * (s * d + 12 * (4 * s * d + heads * s * s + s * ff) + d);
        let vit_total: u64 = vit_b16_profile(b).iter().map(|l| l.act_bytes).sum();
        assert_eq!(vit_total, vit_expect);

        // ResNet-50: B·4·[stem + Σ blocks·(2·hw²·(c/4) + hw²·c) + 2048]
        let stem = 112 * 112 * 64 + 56 * 56 * 64;
        let stages: [(u64, u64, u64); 4] =
            [(3, 56, 256), (4, 28, 512), (6, 14, 1024), (3, 7, 2048)];
        let blocks: u64 = stages
            .iter()
            .map(|(n, hw, c)| n * (2 * hw * hw * (c / 4) + hw * hw * c))
            .sum();
        let resnet_expect = b * f32b * (stem + blocks + 2048);
        let resnet_total: u64 = resnet50_profile(b).iter().map(|l| l.act_bytes).sum();
        assert_eq!(resnet_total, resnet_expect);

        // Both scale linearly in batch.
        let vit1: u64 = vit_b16_profile(1).iter().map(|l| l.act_bytes).sum();
        assert_eq!(vit_total, b * vit1);
    }

    #[test]
    fn per_layer_act_bytes_feed_the_planner_budget_check() {
        // The planner's memory feasibility consumes these profiles through
        // plan::peak_act_from_layers; the predicate must flip exactly at
        // the measured peak.
        for layers in [vit_b16_profile(4), resnet50_profile(4)] {
            let peak = crate::plan::peak_act_from_layers(&layers);
            assert!(peak > 0);
            // Peak is at most the full stash sum, at least the largest layer.
            let total: u64 = layers.iter().map(|l| l.act_bytes).sum();
            let largest = layers.iter().map(|l| l.act_bytes).max().unwrap();
            assert!(peak <= total);
            assert!(peak >= largest);
            assert!(crate::plan::fits_budget(peak, peak));
            assert!(!crate::plan::fits_budget(peak, peak - 1));
            assert!(crate::plan::fits_budget(peak, peak + 1));
        }
    }

    #[test]
    fn magnitudes_are_plausible() {
        // ViT-B/16 batch 64 activation total: paper tracks ~3.9 GB
        let p = vit_b16_profile(64);
        let total: u64 = p.iter().map(|l| l.act_bytes).sum();
        let gb = total as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!(gb > 1.0 && gb < 12.0, "{gb} GB");
        // ResNet-50 batch 64: a few GB too
        let r = resnet50_profile(64);
        let total_r: u64 = r.iter().map(|l| l.act_bytes).sum();
        let gbr = total_r as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!(gbr > 0.5 && gbr < 12.0, "{gbr} GB");
    }
}
