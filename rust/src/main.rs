//! `cdp` — the Cyclic Data Parallelism coordinator CLI.
//!
//! Subcommands:
//!   train     --bundle tiny --rule cdp_v2 --steps 20 [--trainer single|multi|zero|pipeline]
//!             [--pattern barrier|ring] [--flow broadcast|cyclic] [--sched gpipe|1f1b]
//!             [--backend native|xla]   (also CDP_BACKEND; native needs no artifacts
//!                                       for the mlp family — try --bundle native_mlp)
//!             [--precision f32|bf16]   (also CDP_PRECISION; native backend only —
//!                                       f32 is the bit-identical default)
//!             [--plan auto|FILE]       (auto: profile + search + run the winner
//!                                       under --mem-budget; FILE: run a saved plan)
//!   plan      --model native_mlp|deep_narrow|shallow_wide --mem-budget 2GiB
//!             [--calib-steps 3] [--save plan.bin]
//!             (profile + search standalone; ranked table on stderr, JSON on stdout)
//!   launch    --workers N --transport uds|tcp --trainer multi|zero
//!             [--rule ...] [--steps ...] [--wire-faults disc:F:T:K,...]
//!             (spawns one OS process per worker; see `worker` below)
//!   worker    --worker-id W --workers N --transport uds|tcp --rendezvous DIR
//!             (one rank of a multi-process fleet; normally spawned by launch)
//!   trace     summarize|chrome|verify FILE  (CDPTRACE1 JSONL analyzer;
//!             verify: [--expect balanced|spike] [--balance-ratio 2.5]
//!             [--mem-factor 1.5]; chrome: [--out FILE]; summarize:
//!             [--buckets 20].  Produce traces with `train --trace FILE
//!             [--trace-kernels] [--trace-cap N]`, `worker --trace FILE |
//!             --trace-dir DIR`, or `launch --trace FILE` which merges
//!             the per-process files from the rendezvous dir.)
//!   timeline  --n 3 --horizon 18            (Fig 1)
//!   schemes   --n 3                         (Fig 2)
//!   table1    --n 4                         (Tab 1)
//!   memsim    --arch vit|resnet --n 4,8,32  (Fig 4)
//!   golden    --bundle tiny                 (cross-language check)

use anyhow::Result;
use cyclic_dp::cli::Args;
use cyclic_dp::coordinator::{multi, pipeline, single, zero, SharedBackend};
use cyclic_dp::memsim::{extrapolate, resnet50_profile, vit_b16_profile, MemoryCurve};
use cyclic_dp::parallel::{rule_by_name, Schedule};
use cyclic_dp::runtime::{backend_choice, Backend, BackendChoice, NativeBackend, Precision};
use cyclic_dp::sim::{analytic, schemes, Scheme, SymbolicCosts};
use cyclic_dp::util::stats::fmt_bytes;
use std::sync::Arc;

fn main() {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "plan" => cmd_plan(&args),
        "launch" => cmd_launch(&args),
        "worker" => cmd_worker(&args),
        "trace" => cmd_trace(&args),
        "timeline" => cmd_timeline(&args),
        "schemes" => cmd_schemes(&args),
        "table1" => cmd_table1(&args),
        "memsim" => cmd_memsim(&args),
        "golden" => cmd_golden(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "cdp — Cyclic Data Parallelism coordinator\n\
         subcommands: train | plan | launch | worker | trace | timeline | schemes | table1 | memsim | golden\n\
         backend: --backend native|xla (or CDP_BACKEND); this build has \
         xla {}\n\
         see rust/src/main.rs header for flags",
        if cfg!(feature = "xla") { "enabled" } else { "disabled" }
    );
}

/// Load the XLA bundle named by `--bundle` (feature `xla` builds only).
#[cfg(feature = "xla")]
fn load_xla_bundle(args: &Args) -> Result<cyclic_dp::runtime::BundleRuntime> {
    use anyhow::Context;
    let bundle = args.str_or("bundle", "tiny");
    let dir = cyclic_dp::model::artifacts_root().join(bundle);
    cyclic_dp::runtime::BundleRuntime::load(&dir)
        .with_context(|| format!("load bundle {dir:?} (run `make artifacts`?)"))
}

/// Load the native bundle: an on-disk mlp bundle dir, or the synthetic
/// in-memory `mlp`/`native_mlp` when no artifacts exist.  `--precision`
/// (then `CDP_PRECISION`, default f32) selects the storage precision.
fn load_native_bundle(args: &Args) -> Result<NativeBackend> {
    let bundle = args.str_or("bundle", "native_mlp");
    let precision = match args.get("precision") {
        Some(v) => Precision::parse(v)?,
        None => Precision::from_env(Precision::default()),
    };
    if precision != Precision::default() {
        println!("precision={}", precision.name());
    }
    Ok(NativeBackend::load_or_synthetic(bundle)?.with_precision(precision))
}

fn cmd_train(args: &Args) -> Result<()> {
    if args.get("plan").is_some() {
        anyhow::ensure!(
            matches!(backend_choice(args.get("backend"))?, BackendChoice::Native),
            "--plan drives the native backend only (repartitioning rebuilds \
             the synthetic stage graph)"
        );
        return cmd_train_plan(args);
    }
    match backend_choice(args.get("backend"))? {
        BackendChoice::Native => run_train(load_native_bundle(args)?, args),
        BackendChoice::Xla => train_xla(args),
    }
}

/// `cdp train --plan auto|FILE`: resolve the plan (auto = profile +
/// search under `--mem-budget`, logging the ranked table to stderr; FILE
/// = a saved `Plan`), rebuild the backend to the plan's partition and
/// precision, and run the winning coordinator.
fn cmd_train_plan(args: &Args) -> Result<()> {
    use cyclic_dp::coordinator::execute_plan;
    use cyclic_dp::plan::{parse_mem_budget, search, Plan, SearchSpace};
    use cyclic_dp::profile::ProfileOpts;

    let steps = args.usize_or("steps", 10);
    let bundle = args.str_or("bundle", "native_mlp");
    let plan = match args.str_or("plan", "auto") {
        "auto" => {
            let budget = parse_mem_budget(args.str_or("mem-budget", "4GiB"))?;
            let opts = ProfileOpts {
                calib_steps: args.usize_or("calib-steps", 3),
                ..ProfileOpts::default()
            };
            let profile = profile_for_model(bundle, opts)?;
            eprint!("{}", profile.render());
            let ranked = search(&profile, budget, &SearchSpace::for_profile(&profile))
                .map_err(anyhow::Error::new)?;
            eprint!("{}", ranked.render());
            ranked.winner().plan.clone()
        }
        path => Plan::load(std::path::Path::new(path))?,
    };
    println!("plan: {} (predicted {:.1} us/mb)", plan.label(), plan.predicted_step_ns / 1e3);
    if let Some(p) = args.get("save-plan") {
        plan.save(std::path::Path::new(p))?;
        eprintln!("saved plan to {p}");
    }

    // Realize the plan's partition + precision on a fresh backend.
    let rt = NativeBackend::load_or_synthetic(bundle)?;
    let rt = if rt.manifest().n_stages == plan.n_stages as usize {
        rt
    } else {
        rt.repartitioned(plan.n_stages as usize)?
    };
    let rt = rt.with_precision(plan.precision);
    let logs = execute_plan(SharedBackend(Arc::new(rt)), &plan, steps)?;
    for log in &logs {
        println!("step {:>4}  loss {:.5}", log.step, log.loss);
    }
    Ok(())
}

/// Profile `model`: native-preset granularity (per-layer refinement +
/// trainer calibration) when the bundle is synthetic, stage granularity
/// for on-disk bundles.
fn profile_for_model(
    model: &str,
    opts: cyclic_dp::profile::ProfileOpts,
) -> Result<cyclic_dp::profile::ModelProfile> {
    use cyclic_dp::profile::StageProfiler;
    let profiler = StageProfiler::new(opts);
    let rt = NativeBackend::load_or_synthetic(model)?;
    match rt.synthetic_config() {
        Some(cfg) => profiler.profile_native(&cfg),
        None => profiler.profile(&rt),
    }
}

/// `cdp plan`: the standalone profile + search.  Ranked table to stderr,
/// machine-readable JSON to stdout, optional `--save` of the winner.
fn cmd_plan(args: &Args) -> Result<()> {
    use cyclic_dp::plan::{parse_mem_budget, search, SearchSpace};
    use cyclic_dp::profile::ProfileOpts;

    let model = args.str_or("model", "native_mlp");
    let budget = parse_mem_budget(args.str_or("mem-budget", "4GiB"))?;
    let opts = ProfileOpts {
        calib_steps: args.usize_or("calib-steps", 3),
        ..ProfileOpts::default()
    };
    let profile = profile_for_model(model, opts)?;
    eprint!("{}", profile.render());
    let ranked = search(&profile, budget, &SearchSpace::for_profile(&profile))
        .map_err(anyhow::Error::new)?;
    eprint!("{}", ranked.render());
    println!("{}", ranked.to_json());
    if let Some(p) = args.get("save") {
        ranked.winner().plan.save(std::path::Path::new(p))?;
        eprintln!("saved winning plan to {p}");
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn train_xla(args: &Args) -> Result<()> {
    run_train(load_xla_bundle(args)?, args)
}

#[cfg(not(feature = "xla"))]
fn train_xla(_args: &Args) -> Result<()> {
    unreachable!("backend_choice rejects xla without the feature")
}

/// Default trace-ring capacity (events).  ~26 MB resident when enabled;
/// big enough that a smoke run never wraps, bounded when one does.
const TRACE_CAP_DEFAULT: usize = 262_144;

/// Turn the recorder on when `--trace`/`--trace-dir` asks for a file;
/// returns the output path to flush to after the run.
fn trace_setup(args: &Args, out: Option<std::path::PathBuf>) -> Option<std::path::PathBuf> {
    if out.is_some() {
        cyclic_dp::trace::enable(args.usize_or("trace-cap", TRACE_CAP_DEFAULT));
        cyclic_dp::trace::set_kernels(args.bool_or("trace-kernels", false));
    }
    out
}

/// Drain the recorder and write the CDPTRACE1 JSONL file.
fn trace_flush(path: &std::path::Path) -> Result<()> {
    let (events, dropped) = cyclic_dp::trace::drain();
    cyclic_dp::trace::write_jsonl(path, &events, dropped)?;
    eprintln!(
        "trace: {} events ({dropped} dropped) -> {}",
        events.len(),
        path.display()
    );
    Ok(())
}

fn run_train<B: Backend + Send + Sync + 'static>(rt: B, args: &Args) -> Result<()> {
    let rule = rule_by_name(args.str_or("rule", "cdp_v2"))?;
    let steps = args.usize_or("steps", 10);
    let trainer = args.str_or("trainer", "single");
    let trace_to = trace_setup(args, args.get("trace").map(std::path::PathBuf::from));
    println!(
        "bundle={} family={} stages={} params={} rule={} trainer={trainer} backend={}",
        rt.manifest().name,
        rt.manifest().family,
        rt.manifest().n_stages,
        rt.manifest().total_param_elems,
        rule.name(),
        rt.name()
    );
    match trainer {
        "single" => {
            let mut t = single::RefTrainer::new(&rt, rule)?;
            for log in t.train(steps)? {
                println!("step {:>4}  loss {:.5}", log.step, log.loss);
            }
            if args.bool_or("eval", false) {
                if rt.manifest().family == "transformer" {
                    println!("eval loss: {:.5}", t.eval_loss(8)?);
                } else {
                    println!("eval accuracy: {:.4}", t.accuracy(8)?);
                }
            }
        }
        "multi" => {
            let pattern = match args.str_or("pattern", "ring") {
                "barrier" => multi::CommPattern::Barrier,
                _ => multi::CommPattern::Ring,
            };
            let rep = multi::train(SharedBackend(Arc::new(rt)), rule, pattern, steps)?;
            for log in &rep.logs {
                println!("step {:>4}  loss {:.5}", log.step, log.loss);
            }
            println!(
                "comm: {} in {} msgs; optimizer replicas: {}",
                fmt_bytes(rep.comm_bytes),
                rep.comm_messages,
                rep.optimizer_replicas
            );
        }
        "zero" => {
            let flow = match args.str_or("flow", "cyclic") {
                "broadcast" => zero::StateFlow::Broadcast,
                _ => zero::StateFlow::Cyclic,
            };
            let rep = zero::train(SharedBackend(Arc::new(rt)), rule, flow, steps)?;
            for log in &rep.logs {
                println!("step {:>4}  loss {:.5}", log.step, log.loss);
            }
            println!(
                "comm: {} in {} msgs; max msgs/timestep: {}; peak state/worker: {}",
                fmt_bytes(rep.comm_bytes),
                rep.comm_messages,
                rep.max_msgs_per_timestep,
                fmt_bytes(rep.peak_state_bytes)
            );
        }
        "pipeline" => {
            let sched = match args.str_or("sched", "1f1b") {
                "gpipe" => pipeline::PipeSchedule::GPipe,
                _ => pipeline::PipeSchedule::OneFOneB,
            };
            let rep = pipeline::train(&rt, rule, sched, steps)?;
            for log in &rep.logs {
                println!("step {:>4}  loss {:.5}", log.step, log.loss);
            }
            println!(
                "bubble: {:.1}%; peak stash/dev: {}; act traffic: {}; param versions: {}",
                rep.bubble_fraction * 100.0,
                fmt_bytes(rep.peak_stash_bytes),
                fmt_bytes(rep.act_comm_bytes),
                rep.param_versions
            );
        }
        other => anyhow::bail!("unknown trainer `{other}`"),
    }
    if let Some(path) = trace_to {
        trace_flush(&path)?;
    }
    Ok(())
}

/// `cdp trace summarize|chrome|verify FILE`: analyze a CDPTRACE1 JSONL
/// trace — per-stage/per-kind breakdown, Chrome trace-event export, or
/// the paper-claim verifier (constant activation memory + balanced
/// gradient communication for the cyclic rules; `--expect spike` asserts
/// the barrier baseline *fails* balance).
fn cmd_trace(args: &Args) -> Result<()> {
    use anyhow::Context;
    use cyclic_dp::trace as tr;

    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("summarize");
    let file = args
        .positional
        .get(2)
        .context("usage: cdp trace summarize|chrome|verify FILE")?;
    let parsed = tr::parse_jsonl_file(std::path::Path::new(file))
        .with_context(|| format!("parsing trace {file}"))?;
    if parsed.skipped > 0 {
        eprintln!("note: skipped {} corrupt/unknown lines", parsed.skipped);
    }
    match sub {
        "summarize" => {
            let s = tr::summarize(&parsed.events, args.usize_or("buckets", 20));
            print!("{}", tr::render_summary(&s));
        }
        "chrome" => {
            let json = tr::to_chrome(&parsed.events);
            match args.get("out") {
                Some(p) => {
                    std::fs::write(p, &json)
                        .with_context(|| format!("writing chrome trace {p}"))?;
                    eprintln!("wrote chrome trace to {p} (open in chrome://tracing or Perfetto)");
                }
                None => println!("{json}"),
            }
        }
        "verify" => {
            let expect = match args.str_or("expect", "balanced") {
                "spike" => tr::Expect::Spike,
                _ => tr::Expect::Balanced,
            };
            let opts = tr::VerifyOpts {
                balance_ratio: args.f64_or("balance-ratio", 2.5),
                mem_factor: args.f64_or("mem-factor", 1.5),
                expect,
            };
            let report = tr::verify(&parsed.events, &opts);
            print!("{}", tr::render_verify(&report));
            anyhow::ensure!(report.ok, "trace verification failed");
        }
        other => {
            anyhow::bail!("unknown trace subcommand `{other}` (summarize|chrome|verify)")
        }
    }
    Ok(())
}

/// Spawn one OS process per worker (`cdp worker ...`), rendezvousing
/// over a real wire transport, and re-print worker 0's output.  The
/// launcher only needs the manifest (for the fleet size); the children
/// load the bundle themselves.
fn cmd_launch(args: &Args) -> Result<()> {
    use cyclic_dp::cluster::launch::{default_rendezvous_dir, launch, LaunchSpec};
    use cyclic_dp::comm::WireKind;

    let rt = load_native_bundle(args)?;
    let workers = args.usize_or("workers", rt.manifest().n_microbatches);
    anyhow::ensure!(
        workers == rt.manifest().n_microbatches,
        "--workers {workers} must match the bundle's micro-batch count {} \
         (the fabric is one endpoint per micro-batch)",
        rt.manifest().n_microbatches
    );
    let transport = WireKind::parse(args.str_or("transport", "uds"))?;
    let (rendezvous, created) = match args.get("rendezvous") {
        Some(d) => (std::path::PathBuf::from(d), false),
        None => (default_rendezvous_dir(), true),
    };
    // Trainer-facing flags travel to every child verbatim; the launcher
    // stays agnostic of what they mean.
    let mut forward = Vec::new();
    for key in [
        "trainer",
        "rule",
        "steps",
        "bundle",
        "flow",
        "pattern",
        "wire-faults",
        "precision",
    ] {
        if let Some(v) = args.get(key) {
            forward.push(format!("--{key}"));
            forward.push(v.to_string());
        }
    }
    // --trace FILE: children write per-rank trace-w{id}.jsonl files into
    // the rendezvous dir; the launcher merges them after the fleet exits.
    let trace_out = args.get("trace").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        forward.push("--trace-dir".to_string());
        forward.push(rendezvous.display().to_string());
        if let Some(cap) = args.get("trace-cap") {
            forward.push("--trace-cap".to_string());
            forward.push(cap.to_string());
        }
    }
    let spec = LaunchSpec {
        workers,
        transport,
        rendezvous: rendezvous.clone(),
        exe: None,
        forward,
    };
    println!(
        "launching {workers} worker processes over {} (rendezvous {})",
        transport.name(),
        rendezvous.display()
    );
    let result = launch(&spec);
    // Merge whatever per-rank traces exist before the rendezvous dir is
    // cleaned up — even a failed fleet leaves evidence worth keeping.
    let merged = trace_out.as_ref().map(|out| {
        cyclic_dp::cluster::launch::merge_traces(&rendezvous, workers)
            .and_then(|m| {
                cyclic_dp::trace::write_jsonl(out, &m.events, m.dropped)?;
                eprintln!(
                    "trace: merged {} events ({} dropped, {} skipped) -> {}",
                    m.events.len(),
                    m.dropped,
                    m.skipped,
                    out.display()
                );
                Ok(())
            })
    });
    if created {
        let _ = std::fs::remove_dir_all(&rendezvous);
    }
    let outs = result?;
    if let Some(m) = merged {
        m?;
    }
    print!("{}", String::from_utf8_lossy(&outs[0].stdout));
    Ok(())
}

/// One rank of a multi-process fleet: bind the wire endpoint, run the
/// worker loop of the selected trainer, and (on worker 0) print per-step
/// losses both human-readable and as `CDP_LOSS <step> <f64-bits-hex>`
/// lines for bit-exact comparison by the launcher's caller.
fn cmd_worker(args: &Args) -> Result<()> {
    use anyhow::Context;
    use cyclic_dp::comm::{
        BufferPool, CommStats, Endpoint, WireConfig, WireFaultPlan, WireKind, WireTransport,
    };

    let id: usize = args
        .get("worker-id")
        .context("worker needs --worker-id")?
        .parse()
        .context("--worker-id")?;
    let n: usize = args
        .get("workers")
        .context("worker needs --workers")?
        .parse()
        .context("--workers")?;
    let dir = args.get("rendezvous").context("worker needs --rendezvous")?;
    let kind = WireKind::parse(args.str_or("transport", "uds"))?;
    let mut cfg = WireConfig::new(kind, dir, n);
    if let Some(spec) = args.get("wire-faults") {
        cfg.faults = WireFaultPlan::parse(spec)?;
    }

    let rt = load_native_bundle(args)?;
    let rule = rule_by_name(args.str_or("rule", "cdp_v2"))?;
    let steps = args.usize_or("steps", 10);
    // --trace FILE names the worker's own file; --trace-dir DIR (what the
    // launcher forwards) derives the per-rank name the merger expects.
    let trace_to = trace_setup(
        args,
        args.get("trace").map(std::path::PathBuf::from).or_else(|| {
            args.get("trace-dir").map(|d| {
                cyclic_dp::cluster::launch::worker_trace_path(std::path::Path::new(d), id)
            })
        }),
    );

    let pool = BufferPool::new();
    let stats = Arc::new(CommStats::default());
    let transport = WireTransport::bind(id, &cfg, pool.clone())
        .with_context(|| format!("worker {id}: bind {} endpoint", kind.name()))?;
    let mut ep = Endpoint::over(id, n, Box::new(transport), stats, pool);

    let shared = SharedBackend(Arc::new(rt));
    let logs = match args.str_or("trainer", "multi") {
        "multi" => {
            let pattern = match args.str_or("pattern", "ring") {
                "barrier" => multi::CommPattern::Barrier,
                _ => multi::CommPattern::Ring,
            };
            let (logs, _ck) = multi::run_worker(
                &shared,
                &rule,
                pattern,
                steps,
                multi::MultiOpts::default(),
                None,
                &mut ep,
            )?;
            logs
        }
        "zero" => {
            let flow = match args.str_or("flow", "cyclic") {
                "broadcast" => zero::StateFlow::Broadcast,
                _ => zero::StateFlow::Cyclic,
            };
            let (logs, _peak, _ck) = zero::run_worker(
                &shared,
                &rule,
                flow,
                steps,
                zero::ZeroOpts::default(),
                None,
                &mut ep,
            )?;
            logs
        }
        other => anyhow::bail!("worker supports --trainer multi|zero, got `{other}`"),
    };
    if let Some(path) = trace_to {
        trace_flush(&path)?;
    }
    if id == 0 {
        for log in &logs {
            println!("step {:>4}  loss {:.5}", log.step, log.loss);
            // The bit-exact loss line is *derived from* the structured
            // Loss trace event — one format, two renderings (the trainers
            // record the same event into the trace stream).
            let ev = cyclic_dp::trace::TraceEvent::loss(id, log.step, log.loss);
            let line = cyclic_dp::trace::render_loss_line(&ev)
                .expect("a Loss event always renders a CDP_LOSS line");
            println!("{line}");
        }
    }
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 3);
    let horizon = args.usize_or("horizon", 6 * n);
    println!("=== DP (Fig 1a): lockstep, barrier every 2N steps ===");
    println!("{}", Schedule::dp(n, horizon).render(horizon));
    let s = Schedule::cyclic(n, horizon);
    println!("=== CDP (Fig 1b/c): delay 2(i-1), no barrier ===");
    println!("{}", s.render(horizon));
    let (dp_peak, _) = Schedule::dp(n, horizon).stash_stats();
    let (peak, steady) = s.stash_stats();
    println!("activation stashes: DP peak {dp_peak}, CDP peak {peak} (steady ≈ {steady:.1})");
    Ok(())
}

fn cmd_schemes(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 3);
    let c = SymbolicCosts {
        psi_p: args.u64_or("psi-p", 4_000_000),
        b_psi_a: args.u64_or("b-psi-a", 8_000_000),
        b_psi_a_int: args.u64_or("b-psi-a-int", 400_000),
    };
    println!("Fig 2 schematic costs (N = {n}):");
    for s in Scheme::all() {
        println!("{}", schemes::render_scheme(s, n, c));
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 4);
    print!("{}", analytic::render_table1(n));
    Ok(())
}

fn cmd_memsim(args: &Args) -> Result<()> {
    let arch = args.str_or("arch", "vit");
    let batch = args.u64_or("batch", 64);
    let ns: Vec<usize> = args
        .str_or("n", "4,8,32")
        .split(',')
        .map(|s| s.parse().expect("bad --n"))
        .collect();
    let layers = match arch {
        "resnet" => resnet50_profile(batch),
        _ => vit_b16_profile(batch),
    };
    let curve = MemoryCurve::from_layers(&layers);
    println!(
        "{arch}: peak activation {} | mean {}",
        fmt_bytes(curve.peak() as u64),
        fmt_bytes(curve.mean() as u64)
    );
    for n in ns {
        let e = extrapolate(&curve, n, 512);
        println!(
            "N={n:<3} DP peak/worker {} | CDP peak/worker {} | reduction {:.1}%",
            fmt_bytes(e.dp_peak as u64),
            fmt_bytes(e.cdp_peak as u64),
            e.reduction * 100.0
        );
    }
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    match backend_choice(args.get("backend"))? {
        BackendChoice::Native => run_golden(load_native_bundle(args)?),
        BackendChoice::Xla => golden_xla(args),
    }
}

#[cfg(feature = "xla")]
fn golden_xla(args: &Args) -> Result<()> {
    run_golden(load_xla_bundle(args)?)
}

#[cfg(not(feature = "xla"))]
fn golden_xla(_args: &Args) -> Result<()> {
    unreachable!("backend_choice rejects xla without the feature")
}

fn run_golden<B: Backend>(rt: B) -> Result<()> {
    let Some(golden) = rt.manifest().load_golden()? else {
        anyhow::bail!(
            "bundle has no golden.json (synthetic native bundles carry none — \
             point --bundle at a `make artifacts` directory)"
        );
    };
    let steps = rt.manifest().golden_steps;
    let mut worst: f64 = 0.0;
    for (rule_name, expect) in &golden {
        let rule = rule_by_name(rule_name)?;
        let mut t = single::RefTrainer::new(&rt, rule)?;
        let logs = t.train(steps)?;
        for (log, want) in logs.iter().zip(expect) {
            let rel = (log.loss - want).abs() / want.abs().max(1e-9);
            worst = worst.max(rel);
            println!(
                "{rule_name:>7} step {:>2}: rust {:.6} python {:.6} rel {:.2e}",
                log.step, log.loss, want, rel
            );
        }
    }
    println!("worst relative deviation: {worst:.3e}");
    anyhow::ensure!(worst < 5e-3, "golden mismatch");
    println!("golden check PASSED");
    Ok(())
}
