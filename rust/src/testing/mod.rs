//! Property-test mini-framework (proptest is unavailable offline).
//!
//! `check(name, cases, |g| { ... })` runs a closure over `cases` random
//! inputs drawn through the [`Gen`] handle.  On failure it re-raises with
//! the offending case index and seed so the case can be replayed with
//! `Gen::replay`.  No shrinking — cases are kept small instead.
//!
//! [`instrument`] holds the shared measurement plumbing (counting
//! allocator, comm-overlap digests) used by the benches and the profiler.

pub mod instrument;

use crate::util::rng::XorShift64Star;

pub struct Gen {
    rng: XorShift64Star,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: XorShift64Star::new(seed), seed }
    }

    /// Replay a failing case printed by `check`.
    pub fn replay(seed: u64) -> Self {
        Self::new(seed)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Random permutation of 0..n (Fisher-Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.usize_in(0, i);
            v.swap(i, j);
        }
        v
    }
}

/// Run `f` over `cases` generated inputs.  Panics with seed info on the
/// first failing case (assert inside the closure).
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut f: F) {
    // Base seed is fixed for reproducibility; per-case seeds derive from it.
    let base = crate::util::rng::splitmix64(0xC0FFEE ^ name.len() as u64);
    for case in 0..cases {
        let seed = crate::util::rng::splitmix64(base ^ case as u64);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay with Gen::replay({seed:#x})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        check("gen-ranges", 50, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f32_in(-2.0, 2.0);
            assert!((-2.0..=2.0).contains(&x));
            let v = g.vec_f32(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
            let p = g.permutation(n);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn failures_report_seed() {
        check("always-fails", 3, |g| {
            let x = g.usize_in(0, 10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Vec::new();
        check("det", 5, |g| a.push(g.u64()));
        let mut b = Vec::new();
        check("det", 5, |g| b.push(g.u64()));
        assert_eq!(a, b);
    }
}
