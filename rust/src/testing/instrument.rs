//! Shared measurement instrumentation, promoted out of `benches/hotpath.rs`
//! so the profiler ([`crate::profile`]), the benches and the tests use one
//! implementation (ISSUE 9 satellite):
//!
//! - [`CountingAlloc`] — a counting [`GlobalAlloc`] wrapper around
//!   [`System`].  A `#[global_allocator]` can only be *declared* in the
//!   final binary, so each bench keeps its one-line declaration
//!   (`#[global_allocator] static GLOBAL: CountingAlloc = CountingAlloc;`)
//!   and everything else — the counter, [`alloc_count`], the
//!   [`alloc_delta`] window helper — lives here.  In a binary that does
//!   not install the allocator the counter simply stays at zero, so
//!   library code (the profiler) can record deltas unconditionally.
//! - [`OverlapDigest`] — the comm/backward overlap digest the hotpath and
//!   wire benches both derive from a [`CommStats`] timeline: the first
//!   eager gradient send must precede the last backward-stage completion.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::comm::{CommStats, EventKind, TimelineEvent};

/// Global allocation counter behind [`CountingAlloc`].  One per process;
/// shared by every window so deltas compose.
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Counting allocator: forwards to [`System`], bumping [`ALLOCS`] on every
/// `alloc` / `realloc` / `alloc_zeroed` (frees are not counted — the
/// benches prove *allocation-free* steady states, not leak-free ones).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Total allocations observed so far (0 unless the binary installed
/// [`CountingAlloc`] as its `#[global_allocator]`).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Run `f` and return `(result, allocations performed inside it)`.
pub fn alloc_delta<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let before = alloc_count();
    let r = f();
    (r, alloc_count() - before)
}

/// The eager-overlap digest: when did gradient reduction start relative
/// to the end of the backward pass?  `first_grad_send_ns <
/// last_bwd_done_ns` is the paper's comm/backprop overlap property.
#[derive(Clone, Copy, Debug)]
pub struct OverlapDigest {
    /// Timestamp (ns, timeline clock) of the first `GradSend` event.
    pub first_grad_send_ns: u64,
    /// Timestamp of the last `BwdStageDone` event.
    pub last_bwd_done_ns: u64,
}

impl OverlapDigest {
    /// True iff reduction started before the last backward completed.
    pub fn overlapped(&self) -> bool {
        self.first_grad_send_ns < self.last_bwd_done_ns
    }
}

/// Digest from a [`CommStats`] with its timeline enabled; `None` when
/// either event kind was never recorded.
pub fn overlap_from_stats(stats: &CommStats) -> Option<OverlapDigest> {
    Some(OverlapDigest {
        first_grad_send_ns: stats.first_ns(EventKind::GradSend)?,
        last_bwd_done_ns: stats.last_ns(EventKind::BwdStageDone)?,
    })
}

/// Digest from a structured trace (`src/trace`): the first `GradSend`
/// departure against the *end* of the last `Bwd` span (bwd events are
/// spans there, so the completion time is `end_ns`, not `ns`).
pub fn overlap_from_trace(events: &[crate::trace::TraceEvent]) -> Option<OverlapDigest> {
    use crate::trace::TraceKind;
    let first = events
        .iter()
        .filter(|e| e.kind == TraceKind::GradSend)
        .map(|e| e.ns)
        .min()?;
    let last = events
        .iter()
        .filter(|e| e.kind == TraceKind::Bwd)
        .map(|e| e.end_ns())
        .max()?;
    Some(OverlapDigest { first_grad_send_ns: first, last_bwd_done_ns: last })
}

/// Digest from a raw event slice (e.g. a report's captured timeline).
pub fn overlap_from_events(events: &[TimelineEvent]) -> Option<OverlapDigest> {
    let first = events
        .iter()
        .filter(|e| e.kind == EventKind::GradSend)
        .map(|e| e.ns)
        .min()?;
    let last = events
        .iter()
        .filter(|e| e.kind == EventKind::BwdStageDone)
        .map(|e| e.ns)
        .max()?;
    Some(OverlapDigest { first_grad_send_ns: first, last_bwd_done_ns: last })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_delta_composes_and_is_monotone() {
        // The library test binary does not install CountingAlloc, so the
        // counter is constant — but the window algebra must still hold.
        let (v, d1) = alloc_delta(|| vec![1u8; 64]);
        assert_eq!(v.len(), 64);
        let (_, d2) = alloc_delta(|| ());
        assert!(d2 <= d1 + alloc_count());
    }

    #[test]
    fn overlap_digest_from_events() {
        let ev = |kind, ns| TimelineEvent { ns, kind, worker: 0, stage: 0, bytes: 0 };
        let events = vec![
            ev(EventKind::BwdStageDone, 10),
            ev(EventKind::GradSend, 12),
            ev(EventKind::BwdStageDone, 20),
        ];
        let d = overlap_from_events(&events).unwrap();
        assert_eq!(d.first_grad_send_ns, 12);
        assert_eq!(d.last_bwd_done_ns, 20);
        assert!(d.overlapped());
        assert!(overlap_from_events(&[]).is_none());
    }

    #[test]
    fn overlap_digest_from_structured_trace_uses_span_ends() {
        use crate::trace::{Fields, TraceEvent, TraceKind};
        let events = vec![
            // bwd span [5, 25): completion is end_ns=25, not start ns=5
            TraceEvent::new(TraceKind::Bwd, 5, 20, Fields::default()),
            TraceEvent::new(TraceKind::GradSend, 12, 0, Fields::default()),
        ];
        let d = overlap_from_trace(&events).unwrap();
        assert_eq!(d.first_grad_send_ns, 12);
        assert_eq!(d.last_bwd_done_ns, 25);
        assert!(d.overlapped());
        assert!(overlap_from_trace(&[]).is_none());
        // a send after every backward completed is not an overlap
        let late = vec![
            TraceEvent::new(TraceKind::Bwd, 5, 2, Fields::default()),
            TraceEvent::new(TraceKind::GradSend, 12, 0, Fields::default()),
        ];
        assert!(!overlap_from_trace(&late).unwrap().overlapped());
    }
}
