//! The paper's contribution (Sec. 3): Cyclic Data Parallelism.
//!
//! - [`update_rule`] — the u_{i,j} parameter-version rules defining DP,
//!   CDP-v1, CDP-v2 (+ the randomized future-work extension).
//! - [`arena`] — flat parameter/gradient arenas: contiguous per-stage
//!   state with precomputed views (DESIGN-PERF.md).
//! - [`param_store`] — versioned parameter state (θ_t, θ_{t-1}) with the
//!   θ_{-1} := θ_0 bootstrap, arena-backed.
//! - [`grad_buffer`] — deterministic-order gradient accumulation over a
//!   model-wide flat arena.
//! - [`schedule`] — the time-step timelines of Fig 1 (DP lockstep vs the
//!   cyclic pattern with per-worker delay 2(i−1)).
//! - [`checkpoint`] — θ-version-boundary snapshots for kill/resume
//!   (DESIGN-ROBUSTNESS.md): bit-exact serialization of the param store.

pub mod arena;
pub mod checkpoint;
pub mod grad_buffer;
pub mod param_store;
pub mod schedule;
pub mod update_rule;

pub use arena::{AlignedBuf, ArenaLayout};
pub use checkpoint::Checkpoint;
pub use grad_buffer::GradBuffer;
pub use param_store::ParamStore;
pub use schedule::{Op, Schedule};
pub use update_rule::{rule_by_name, Rule, Version};
