//! Gradient accumulation with a *fixed, deterministic* reduction order
//! (micro-batch order 1..N).  This is the order the cyclic ring reduction
//! produces naturally (micro-batch i finishes stage-j backward before
//! micro-batch i+1), so the single-process reference, the threaded CDP
//! ring and the python mirror all sum in the same order — bit-for-bit.

use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct GradBuffer {
    sums: Vec<Vec<Tensor>>,
    /// Which micro-batch index is expected next per stage (1-based).
    next_mb: Vec<usize>,
    n_microbatches: usize,
}

impl GradBuffer {
    pub fn new(shapes: &[Vec<Vec<usize>>], n_microbatches: usize) -> Self {
        let sums = shapes
            .iter()
            .map(|st| st.iter().map(|s| Tensor::zeros(s.clone())).collect())
            .collect();
        Self { sums, next_mb: vec![1; shapes.len()], n_microbatches }
    }

    pub fn from_params(params: &[Vec<Tensor>], n_microbatches: usize) -> Self {
        let shapes: Vec<Vec<Vec<usize>>> = params
            .iter()
            .map(|st| st.iter().map(|t| t.shape.clone()).collect())
            .collect();
        Self::new(&shapes, n_microbatches)
    }

    /// Accumulate micro-batch `mb`'s (1-based) gradients for `stage`.
    /// Panics if called out of micro-batch order — the order *is* the
    /// determinism contract.
    pub fn add(&mut self, stage: usize, mb: usize, grads: &[Tensor]) {
        assert_eq!(
            mb, self.next_mb[stage],
            "stage {stage}: gradient for mb {mb} arrived out of order (expected {})",
            self.next_mb[stage]
        );
        assert_eq!(grads.len(), self.sums[stage].len());
        for (s, g) in self.sums[stage].iter_mut().zip(grads) {
            s.add_assign(g);
        }
        self.next_mb[stage] += 1;
    }

    pub fn stage_complete(&self, stage: usize) -> bool {
        self.next_mb[stage] == self.n_microbatches + 1
    }

    pub fn all_complete(&self) -> bool {
        (0..self.sums.len()).all(|s| self.stage_complete(s))
    }

    /// Average (divide by N) and take the per-stage sums; resets the buffer.
    pub fn take_averaged(&mut self) -> Vec<Vec<Tensor>> {
        assert!(self.all_complete(), "take_averaged before all micro-batches");
        let inv = 1.0 / self.n_microbatches as f32;
        let mut out: Vec<Vec<Tensor>> = self
            .sums
            .iter_mut()
            .map(|st| {
                st.iter_mut()
                    .map(|t| {
                        let mut g = std::mem::replace(t, Tensor::zeros(t.shape.clone()));
                        g.scale(inv);
                        g
                    })
                    .collect()
            })
            .collect();
        self.next_mb.iter_mut().for_each(|x| *x = 1);
        // keep shapes for reuse
        out.iter_mut().for_each(|_| {});
        out
    }

    /// Take the average for a single stage (used by trainers that update
    /// stages independently, e.g. CDP-v2's per-stage hand-off).
    pub fn take_stage_averaged(&mut self, stage: usize) -> Vec<Tensor> {
        assert!(self.stage_complete(stage));
        let inv = 1.0 / self.n_microbatches as f32;
        self.next_mb[stage] = 1;
        self.sums[stage]
            .iter_mut()
            .map(|t| {
                let mut g = std::mem::replace(t, Tensor::zeros(t.shape.clone()));
                g.scale(inv);
                g
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf() -> GradBuffer {
        GradBuffer::new(&[vec![vec![2]], vec![vec![1]]], 2)
    }

    #[test]
    fn accumulates_in_order_and_averages() {
        let mut b = buf();
        b.add(0, 1, &[Tensor::new(vec![2], vec![1.0, 2.0])]);
        b.add(0, 2, &[Tensor::new(vec![2], vec![3.0, 4.0])]);
        b.add(1, 1, &[Tensor::new(vec![1], vec![10.0])]);
        assert!(!b.all_complete());
        b.add(1, 2, &[Tensor::new(vec![1], vec![30.0])]);
        assert!(b.all_complete());
        let avg = b.take_averaged();
        assert_eq!(avg[0][0].data, vec![2.0, 3.0]);
        assert_eq!(avg[1][0].data, vec![20.0]);
        // reset: accepts mb 1 again
        b.add(0, 1, &[Tensor::new(vec![2], vec![1.0, 1.0])]);
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn rejects_out_of_order() {
        let mut b = buf();
        b.add(0, 2, &[Tensor::new(vec![2], vec![1.0, 1.0])]);
    }

    #[test]
    fn per_stage_take() {
        let mut b = buf();
        b.add(0, 1, &[Tensor::new(vec![2], vec![2.0, 2.0])]);
        b.add(0, 2, &[Tensor::new(vec![2], vec![4.0, 4.0])]);
        let avg = b.take_stage_averaged(0);
        assert_eq!(avg[0].data, vec![3.0, 3.0]);
        assert!(!b.stage_complete(1));
    }
}
