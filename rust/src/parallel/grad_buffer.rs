//! Gradient accumulation with a *fixed, deterministic* reduction order
//! (micro-batch order 1..N).  This is the order the cyclic ring reduction
//! produces naturally (micro-batch i finishes stage-j backward before
//! micro-batch i+1), so the single-process reference, the threaded CDP
//! ring and the python mirror all sum in the same order — bit-for-bit.
//!
//! The sums live in one model-wide flat arena (stage-major, see
//! [`super::arena`]): accumulation is a single fused pass per stage run,
//! averaging is an in-place scale, and consumers read the per-stage slices
//! directly — no per-tensor `Vec` churn and no allocation after
//! construction.

use std::sync::Arc;

use crate::parallel::arena::{AlignedBuf, ArenaLayout};
use crate::tensor::ops;
use crate::tensor::Tensor;

/// Sentinel for `next_mb`: stage sums are averaged and awaiting `reset`.
const AVERAGED: usize = 0;

#[derive(Clone, Debug)]
pub struct GradBuffer {
    layout: Arc<ArenaLayout>,
    /// Model-wide stage-major running sums (64-byte-aligned base so the
    /// vectorized reduction kernels start on full SIMD lanes).
    sums: AlignedBuf,
    /// Which micro-batch index is expected next per stage (1-based;
    /// `AVERAGED` after `average` until `reset`).
    next_mb: Vec<usize>,
    n_microbatches: usize,
}

impl GradBuffer {
    pub fn new(layout: Arc<ArenaLayout>, n_microbatches: usize) -> Self {
        let sums = layout.zeros_aligned();
        let next_mb = vec![1; layout.n_stages()];
        Self { layout, sums, next_mb, n_microbatches }
    }

    pub fn from_params(params: &[Vec<Tensor>], n_microbatches: usize) -> Self {
        Self::new(ArenaLayout::from_params(params), n_microbatches)
    }

    pub fn layout(&self) -> &Arc<ArenaLayout> {
        &self.layout
    }

    fn bump(&mut self, stage: usize, mb: usize) {
        assert_ne!(
            self.next_mb[stage], AVERAGED,
            "stage {stage}: add after average, before reset"
        );
        assert_eq!(
            mb, self.next_mb[stage],
            "stage {stage}: gradient for mb {mb} arrived out of order (expected {})",
            self.next_mb[stage]
        );
        self.next_mb[stage] += 1;
    }

    /// Accumulate micro-batch `mb`'s (1-based) flat gradients for `stage`.
    /// Panics if called out of micro-batch order — the order *is* the
    /// determinism contract.
    pub fn add_flat(&mut self, stage: usize, mb: usize, grads: &[f32]) {
        self.bump(stage, mb);
        let r = self.layout.stage_range(stage);
        assert_eq!(grads.len(), r.len(), "stage {stage}: grad run length");
        ops::add_into(&mut self.sums[r], grads);
    }

    /// Accumulate micro-batch `mb`'s gradients for every stage at once
    /// from a model-wide flat run.
    pub fn add_all_flat(&mut self, mb: usize, grads: &[f32]) {
        assert_eq!(grads.len(), self.layout.total_len);
        for stage in 0..self.layout.n_stages() {
            let r = self.layout.stage_range(stage);
            self.add_flat(stage, mb, &grads[r]);
        }
    }

    /// Accumulate per-tensor gradients (edge-of-system convenience).
    pub fn add(&mut self, stage: usize, mb: usize, grads: &[Tensor]) {
        self.bump(stage, mb);
        let base = self.layout.stage_offsets[stage];
        let views = &self.layout.stages[stage].views;
        assert_eq!(grads.len(), views.len(), "stage {stage}: tensor count");
        for (g, v) in grads.iter().zip(views) {
            debug_assert_eq!(g.shape, v.shape);
            let start = base + v.offset;
            ops::add_into(&mut self.sums[start..start + v.len], &g.data);
        }
    }

    pub fn stage_complete(&self, stage: usize) -> bool {
        self.next_mb[stage] == self.n_microbatches + 1
    }

    pub fn all_complete(&self) -> bool {
        (0..self.next_mb.len()).all(|s| self.stage_complete(s))
    }

    /// Average all stages (divide by N) in place.  Read the result through
    /// [`Self::stage`] / [`Self::flat`]; call [`Self::reset`] before the
    /// next step's accumulation.
    pub fn average(&mut self) {
        assert!(self.all_complete(), "average before all micro-batches");
        let inv = 1.0 / self.n_microbatches as f32;
        ops::scale(&mut self.sums, inv);
        self.next_mb.iter_mut().for_each(|x| *x = AVERAGED);
    }

    /// Average a single stage in place (trainers that update stages
    /// independently, e.g. CDP-v2's per-stage hand-off).
    pub fn average_stage(&mut self, stage: usize) {
        assert!(self.stage_complete(stage), "average_stage before complete");
        let inv = 1.0 / self.n_microbatches as f32;
        let r = self.layout.stage_range(stage);
        ops::scale(&mut self.sums[r], inv);
        self.next_mb[stage] = AVERAGED;
    }

    /// One stage's (possibly averaged) sums, contiguous.
    pub fn stage(&self, stage: usize) -> &[f32] {
        &self.sums[self.layout.stage_range(stage)]
    }

    /// The model-wide flat sums.
    pub fn flat(&self) -> &[f32] {
        &self.sums
    }

    /// Zero the sums and re-arm accumulation from micro-batch 1.
    pub fn reset(&mut self) {
        self.sums.fill(0.0);
        self.next_mb.iter_mut().for_each(|x| *x = 1);
    }

    /// Materialize one stage's current sums as tensors (tests/tools only).
    pub fn stage_tensors(&self, stage: usize) -> Vec<Tensor> {
        self.layout.read_stage(stage, self.stage(stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf() -> GradBuffer {
        GradBuffer::new(
            ArenaLayout::from_stage_shapes(&[vec![vec![2]], vec![vec![1]]]),
            2,
        )
    }

    #[test]
    fn accumulates_in_order_and_averages() {
        let mut b = buf();
        b.add(0, 1, &[Tensor::new(vec![2], vec![1.0, 2.0])]);
        b.add_flat(0, 2, &[3.0, 4.0]);
        b.add(1, 1, &[Tensor::new(vec![1], vec![10.0])]);
        assert!(!b.all_complete());
        b.add(1, 2, &[Tensor::new(vec![1], vec![30.0])]);
        assert!(b.all_complete());
        b.average();
        assert_eq!(b.stage(0), &[2.0, 3.0]);
        assert_eq!(b.stage(1), &[20.0]);
        assert_eq!(b.flat(), &[2.0, 3.0, 20.0]);
        // reset: accepts mb 1 again, sums cleared
        b.reset();
        b.add(0, 1, &[Tensor::new(vec![2], vec![1.0, 1.0])]);
        assert_eq!(b.stage(0), &[1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn rejects_out_of_order() {
        let mut b = buf();
        b.add(0, 2, &[Tensor::new(vec![2], vec![1.0, 1.0])]);
    }

    #[test]
    #[should_panic(expected = "add after average")]
    fn rejects_add_between_average_and_reset() {
        let mut b = buf();
        b.add_flat(0, 1, &[1.0, 1.0]);
        b.add_flat(0, 2, &[1.0, 1.0]);
        b.average_stage(0);
        b.add_flat(0, 1, &[1.0, 1.0]);
    }

    #[test]
    fn per_stage_average() {
        let mut b = buf();
        b.add(0, 1, &[Tensor::new(vec![2], vec![2.0, 2.0])]);
        b.add(0, 2, &[Tensor::new(vec![2], vec![4.0, 4.0])]);
        b.average_stage(0);
        assert_eq!(b.stage(0), &[3.0, 3.0]);
        assert!(!b.stage_complete(1));
    }

    #[test]
    fn add_all_flat_covers_every_stage() {
        let mut b = buf();
        b.add_all_flat(1, &[1.0, 2.0, 3.0]);
        b.add_all_flat(2, &[1.0, 2.0, 3.0]);
        b.average();
        assert_eq!(b.flat(), &[1.0, 2.0, 3.0]);
        assert_eq!(b.stage_tensors(1)[0].data, vec![3.0]);
    }
}
