//! θ-version-boundary checkpointing (ROADMAP item 5a, DESIGN-ROBUSTNESS.md).
//!
//! The cyclic schedule has exactly one globally consistent recovery
//! point: the θ-version boundary right after [`ParamStore::commit_step`],
//! where every worker holds the same `{θ_t, θ_{t−1}, momentum, t}` and no
//! message is in flight.  A [`Checkpoint`] is that state, nothing more:
//!
//! - the three flat arenas (current params, stale params, momentum),
//! - the step counter `t` — which *is* the schedule position and,
//!   because every data stream is derived as a pure function
//!   `microbatch_seed(base, step, mb)` of it, the complete RNG state
//!   (nothing else to serialize — the counter-based design from
//!   `util::rng` pays off here),
//! - the update-rule name and per-stage arena lengths as a fingerprint,
//!   so resuming against the wrong model or rule is a typed error, not
//!   silent corruption.
//!
//! ## Wire format (version 1)
//!
//! ```text
//! magic    8  b"CDPCKPT1"
//! version  u32 (= 1)
//! step     u64
//! rule     u32 len + UTF-8
//! n_stages u32
//! lens     n_stages × u64          per-stage arena lengths
//! cur      Σlens × f32 LE          θ_t
//! prev     Σlens × f32 LE          θ_{t−1}
//! moms     Σlens × f32 LE          momentum
//! checksum u64                     FNV-1a64 of all preceding bytes
//! ```
//!
//! Everything little-endian via `util::binio`; round-trip is bit-exact
//! (property-tested) — a resumed run's loss trajectory is bit-identical
//! to the uninterrupted one (tests/robustness.rs).

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::parallel::arena::ArenaLayout;
use crate::parallel::param_store::ParamStore;
use crate::parallel::update_rule::Rule;
use crate::util::binio::{fnv1a64, ByteReader, ByteWriter};

const MAGIC: &[u8; 8] = b"CDPCKPT1";
const FORMAT_VERSION: u32 = 1;

/// Complete trainer state at a θ-version boundary.  See module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// The run resumes at this step (state is "about to run step `step`").
    pub step: u64,
    /// Update-rule name ([`Rule::name`]) — validated on resume.
    pub rule: String,
    /// Per-stage flat arena lengths — the layout fingerprint.
    pub stage_lens: Vec<u64>,
    /// θ_t, model-wide stage-major flat.
    pub cur: Vec<f32>,
    /// θ_{t−1}.
    pub prev: Vec<f32>,
    /// Momentum.
    pub moms: Vec<f32>,
}

impl Checkpoint {
    /// Snapshot a store at its current θ-version boundary (call right
    /// after `commit_step`; the store's own step counter is the boundary).
    pub fn capture(store: &ParamStore, rule: &Rule) -> Self {
        let layout = store.layout();
        Self {
            step: store.step(),
            rule: rule.name().to_string(),
            stage_lens: (0..layout.n_stages())
                .map(|s| layout.stage_len(s) as u64)
                .collect(),
            cur: store.flat_params().to_vec(),
            prev: store.stale_flat().to_vec(),
            moms: store.momentum_flat().to_vec(),
        }
    }

    /// Assemble from already-gathered flat arenas (threaded trainers
    /// gather the owner's momentum over the fabric before building this).
    pub fn from_arenas(
        layout: &ArenaLayout,
        rule: &Rule,
        step: u64,
        cur: Vec<f32>,
        prev: Vec<f32>,
        moms: Vec<f32>,
    ) -> Self {
        Self {
            step,
            rule: rule.name().to_string(),
            stage_lens: (0..layout.n_stages())
                .map(|s| layout.stage_len(s) as u64)
                .collect(),
            cur,
            prev,
            moms,
        }
    }

    pub fn total_len(&self) -> usize {
        self.stage_lens.iter().map(|&l| l as usize).sum()
    }

    /// Validate this checkpoint against a target layout and rule, then
    /// rebuild the store.  Mismatches are diagnosable errors.
    pub fn into_store(self, layout: Arc<ArenaLayout>, rule: &Rule) -> Result<ParamStore> {
        anyhow::ensure!(
            self.rule == rule.name(),
            "checkpoint was written under rule `{}`, resuming under `{}`",
            self.rule,
            rule.name()
        );
        let want: Vec<u64> = (0..layout.n_stages())
            .map(|s| layout.stage_len(s) as u64)
            .collect();
        anyhow::ensure!(
            self.stage_lens == want,
            "checkpoint layout {:?} does not match target layout {:?}",
            self.stage_lens,
            want
        );
        Ok(ParamStore::restore(
            layout,
            self.cur,
            self.prev,
            Some(self.moms),
            self.step,
        ))
    }

    /// Serialize (see the wire format in the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let total = self.total_len();
        debug_assert_eq!(self.cur.len(), total);
        debug_assert_eq!(self.prev.len(), total);
        debug_assert_eq!(self.moms.len(), total);
        let mut w = ByteWriter::with_capacity(64 + self.rule.len() + total * 12);
        w.bytes(MAGIC);
        w.u32(FORMAT_VERSION);
        w.u64(self.step);
        w.str(&self.rule);
        w.u32(self.stage_lens.len() as u32);
        for &l in &self.stage_lens {
            w.u64(l);
        }
        w.f32_slice(&self.cur);
        w.f32_slice(&self.prev);
        w.f32_slice(&self.moms);
        let sum = fnv1a64(w.as_slice());
        w.u64(sum);
        w.finish()
    }

    /// Deserialize + integrity-check.  Truncation, magic/version
    /// mismatches and checksum failures are all typed errors.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let magic = r.bytes(8).context("checkpoint header")?;
        anyhow::ensure!(
            magic == MAGIC,
            "not a CDP checkpoint (bad magic {magic:02x?})"
        );
        let version = r.u32()?;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "checkpoint format version {version} unsupported (this build reads {FORMAT_VERSION})"
        );
        let step = r.u64()?;
        let rule = r.str()?;
        let n_stages = r.u32()? as usize;
        let mut stage_lens = Vec::with_capacity(n_stages);
        for _ in 0..n_stages {
            stage_lens.push(r.u64()?);
        }
        let total: usize = stage_lens.iter().map(|&l| l as usize).sum();
        let cur = r.f32_vec(total).context("checkpoint cur arena")?;
        let prev = r.f32_vec(total).context("checkpoint prev arena")?;
        let moms = r.f32_vec(total).context("checkpoint momentum arena")?;
        let want_sum = fnv1a64(r.consumed());
        let got_sum = r.u64().context("checkpoint checksum")?;
        anyhow::ensure!(
            want_sum == got_sum,
            "checkpoint checksum mismatch (file {got_sum:#018x}, computed {want_sum:#018x}) — truncated or corrupt"
        );
        anyhow::ensure!(r.remaining() == 0, "trailing bytes after checkpoint");
        Ok(Self { step, rule, stage_lens, cur, prev, moms })
    }

    /// Write to a file (atomic-enough for the local fault model: written
    /// to a sibling temp path, then renamed over the target).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("ckpt.tmp");
        std::fs::write(&tmp, self.to_bytes())
            .with_context(|| format!("write checkpoint {tmp:?}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename checkpoint into {path:?}"))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read checkpoint {path:?}"))?;
        Self::from_bytes(&bytes).with_context(|| format!("parse checkpoint {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::testing::check;

    fn store() -> ParamStore {
        ParamStore::new(vec![
            vec![Tensor::new(vec![3], vec![1.0, -2.0, 0.5])],
            vec![Tensor::new(vec![2], vec![4.0, 5.0])],
        ])
    }

    #[test]
    fn capture_restore_round_trips_through_store() {
        let mut s = store();
        s.write_next(0, &[9.0, 8.0, 7.0]);
        s.write_next(1, &[6.0, 5.5]);
        s.commit_step();
        let ck = Checkpoint::capture(&s, &Rule::CdpV2);
        assert_eq!(ck.step, 1);
        assert_eq!(ck.rule, "cdp_v2");
        let restored = ck
            .clone()
            .into_store(s.layout().clone(), &Rule::CdpV2)
            .unwrap();
        assert_eq!(restored.step(), 1);
        assert_eq!(restored.flat_params(), s.flat_params());
        assert_eq!(restored.stale_flat(), s.stale_flat());
        assert_eq!(restored.momentum_flat(), s.momentum_flat());
    }

    #[test]
    fn rule_and_layout_mismatches_are_typed_errors() {
        let s = store();
        let ck = Checkpoint::capture(&s, &Rule::Dp);
        let err = ck
            .clone()
            .into_store(s.layout().clone(), &Rule::CdpV1)
            .unwrap_err();
        assert!(err.to_string().contains("rule"), "{err}");
        let other = ArenaLayout::from_stage_shapes(&[vec![vec![4]]]);
        let err2 = ck.into_store(other, &Rule::Dp).unwrap_err();
        assert!(err2.to_string().contains("layout"), "{err2}");
    }

    #[test]
    fn corrupt_bytes_are_rejected() {
        let ck = Checkpoint::capture(&store(), &Rule::Dp);
        let mut bytes = ck.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 3]).is_err(), "truncation");
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(Checkpoint::from_bytes(b"NOTACKPT").is_err(), "bad magic");
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let dir = std::env::temp_dir().join("cdp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("state.ckpt");
        let ck = Checkpoint::capture(&store(), &Rule::CdpV2);
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&p).unwrap();
    }

    /// Property (ISSUE satellite): arbitrary arena layouts + θ-versions
    /// serialize → deserialize bit-identically, including NaN payloads
    /// and denormals.
    #[test]
    fn prop_round_trip_is_bit_exact() {
        check("ckpt-roundtrip", 40, |g| {
            let n = g.usize_in(1, 5);
            let stage_lens: Vec<u64> =
                (0..n).map(|_| g.usize_in(1, 32) as u64).collect();
            let total: usize = stage_lens.iter().map(|&l| l as usize).sum();
            let mut arena = |g: &mut crate::testing::Gen| -> Vec<f32> {
                (0..total)
                    .map(|_| {
                        // cover exact bit patterns, not just nice floats
                        match g.usize_in(0, 9) {
                            0 => f32::from_bits(g.u64() as u32),
                            1 => f32::MIN_POSITIVE / 2.0, // denormal
                            _ => g.f32_in(-1e6, 1e6),
                        }
                    })
                    .collect()
            };
            let ck = Checkpoint {
                step: g.u64() & 0xFFFF_FFFF,
                rule: ["dp", "cdp_v1", "cdp_v2"][g.usize_in(0, 2)].to_string(),
                stage_lens,
                cur: arena(g),
                prev: arena(g),
                moms: arena(g),
            };
            let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
            assert_eq!(back.step, ck.step);
            assert_eq!(back.rule, ck.rule);
            assert_eq!(back.stage_lens, ck.stage_lens);
            for (a, b) in [(&back.cur, &ck.cur), (&back.prev, &ck.prev), (&back.moms, &ck.moms)] {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        });
    }
}
