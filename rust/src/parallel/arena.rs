//! Flat parameter/gradient arenas (DESIGN-PERF.md): each stage's state is
//! one contiguous `f32` run with precomputed `(offset, len, shape)` views,
//! and the whole model is one stage-major flat vector.
//!
//! The layout is derived once — from the manifest or from an initial
//! per-tensor parameter set — and shared (`Arc`) by every consumer:
//! [`super::ParamStore`], [`super::GradBuffer`], the trainers' scratch
//! buffers and the comm fabric all address the *same* offsets, so gradient
//! reduction, collectives and parameter hand-off operate directly on arena
//! slices with no per-tensor `Vec` churn, no `flatten`/`unflatten` copies,
//! and no steady-state allocation.
//!
//! Tensors still exist at the edges (the XLA literal boundary, tests,
//! checkpoints); [`ArenaLayout::read_stage`] / [`ArenaLayout::write_stage`]
//! convert between the two representations and are property-tested to be
//! exact round-trips.

use std::ops::Range;
use std::sync::Arc;

use crate::model::Manifest;
use crate::tensor::Tensor;

/// One fixed-size bucket's view into a stage's contiguous run
/// (offsets are within the *stage* run, like [`ViewSpec`]).  Buckets are
/// the unit of the eager gradient reduction in [`crate::comm::bucketed`]:
/// bucket `index` of a stage can enter the ring the moment its backward
/// output lands, independent of the rest of the stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub stage: usize,
    /// 0-based bucket index within the stage.
    pub index: usize,
    pub start: usize,
    pub end: usize,
}

impl Bucket {
    pub fn range(&self) -> Range<usize> {
        self.start..self.end
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// One tensor's view into its stage's contiguous run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ViewSpec {
    /// Offset within the *stage* run (not the model-wide vector).
    pub offset: usize,
    pub len: usize,
    pub shape: Vec<usize>,
}

/// Per-stage layout: tensor views plus the stage's total length.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageLayout {
    pub views: Vec<ViewSpec>,
    pub len: usize,
}

impl StageLayout {
    pub fn from_shapes(shapes: &[Vec<usize>]) -> Self {
        let mut views = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for s in shapes {
            let len = s.iter().product();
            views.push(ViewSpec { offset: off, len, shape: s.clone() });
            off += len;
        }
        Self { views, len: off }
    }
}

/// Whole-model layout: per-stage layouts plus each stage's offset in the
/// stage-major model-wide flat vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArenaLayout {
    pub stages: Vec<StageLayout>,
    /// Start of each stage's run in the model-wide vector.
    pub stage_offsets: Vec<usize>,
    pub total_len: usize,
}

impl ArenaLayout {
    fn from_stage_layouts(stages: Vec<StageLayout>) -> Arc<Self> {
        let mut stage_offsets = Vec::with_capacity(stages.len());
        let mut off = 0usize;
        for st in &stages {
            stage_offsets.push(off);
            off += st.len;
        }
        Arc::new(Self { stages, stage_offsets, total_len: off })
    }

    pub fn from_stage_shapes(shapes: &[Vec<Vec<usize>>]) -> Arc<Self> {
        Self::from_stage_layouts(
            shapes.iter().map(|st| StageLayout::from_shapes(st)).collect(),
        )
    }

    /// Layout of the model the manifest describes (stage-major, params in
    /// manifest order — the same order `params.bin` is serialized in).
    pub fn from_manifest(m: &Manifest) -> Arc<Self> {
        Self::from_stage_layouts(
            m.stages
                .iter()
                .map(|st| {
                    StageLayout::from_shapes(
                        &st.params.iter().map(|p| p.shape.clone()).collect::<Vec<_>>(),
                    )
                })
                .collect(),
        )
    }

    /// Layout matching an existing per-tensor parameter set.
    pub fn from_params(params: &[Vec<Tensor>]) -> Arc<Self> {
        Self::from_stage_layouts(
            params
                .iter()
                .map(|st| {
                    StageLayout::from_shapes(
                        &st.iter().map(|t| t.shape.clone()).collect::<Vec<_>>(),
                    )
                })
                .collect(),
        )
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn stage_len(&self, stage: usize) -> usize {
        self.stages[stage].len
    }

    /// Range of stage `stage` within the model-wide flat vector.
    pub fn stage_range(&self, stage: usize) -> Range<usize> {
        let start = self.stage_offsets[stage];
        start..start + self.stages[stage].len
    }

    /// Fresh zero-filled model-wide buffer.
    pub fn zeros(&self) -> Vec<f32> {
        vec![0.0; self.total_len]
    }

    /// Fresh zero-filled model-wide buffer with its base 64-byte aligned,
    /// so SIMD loads on arena runs start on full-vector boundaries
    /// (DESIGN-PERF.md §Kernel architecture).  View offsets within the
    /// buffer are unchanged — alignment of the *base* is all the blocked
    /// kernels want, and keeping offsets identical to [`Self::zeros`]
    /// preserves the on-disk `params.bin` mapping.
    pub fn zeros_aligned(&self) -> AlignedBuf {
        AlignedBuf::zeroed(self.total_len)
    }

    /// Fresh zero-filled buffer for one stage.
    pub fn stage_zeros(&self, stage: usize) -> Vec<f32> {
        vec![0.0; self.stages[stage].len]
    }

    /// Materialize a stage run as tensors (edge-of-system only: tests,
    /// checkpoints, golden comparisons — never the training hot path).
    pub fn read_stage(&self, stage: usize, run: &[f32]) -> Vec<Tensor> {
        let st = &self.stages[stage];
        assert_eq!(run.len(), st.len, "stage {stage}: run/layout mismatch");
        st.views
            .iter()
            .map(|v| Tensor::new(v.shape.clone(), run[v.offset..v.offset + v.len].to_vec()))
            .collect()
    }

    /// Write tensors into a stage run (inverse of [`Self::read_stage`]).
    pub fn write_stage(&self, stage: usize, tensors: &[Tensor], run: &mut [f32]) {
        let st = &self.stages[stage];
        assert_eq!(run.len(), st.len, "stage {stage}: run/layout mismatch");
        assert_eq!(tensors.len(), st.views.len(), "stage {stage}: tensor count");
        for (t, v) in tensors.iter().zip(&st.views) {
            assert_eq!(t.shape, v.shape, "stage {stage}: shape mismatch");
            run[v.offset..v.offset + v.len].copy_from_slice(&t.data);
        }
    }

    /// Flatten a whole per-tensor parameter set into a model-wide vector.
    pub fn flatten(&self, params: &[Vec<Tensor>]) -> Vec<f32> {
        assert_eq!(params.len(), self.n_stages());
        let mut flat = self.zeros();
        for (j, st) in params.iter().enumerate() {
            self.write_stage(j, st, &mut flat[self.stage_range(j)]);
        }
        flat
    }

    /// Materialize every stage of a model-wide vector as tensors.
    pub fn unflatten(&self, flat: &[f32]) -> Vec<Vec<Tensor>> {
        assert_eq!(flat.len(), self.total_len);
        (0..self.n_stages())
            .map(|j| self.read_stage(j, &flat[self.stage_range(j)]))
            .collect()
    }

    /// Total bytes of one model-wide buffer.
    pub fn bytes(&self) -> u64 {
        self.total_len as u64 * 4
    }

    /// Number of fixed-size buckets tiling stage `stage`'s run.  Zero for
    /// an empty stage (nothing to communicate).
    pub fn n_buckets(&self, stage: usize, bucket_elems: usize) -> usize {
        assert!(bucket_elems > 0, "bucket_elems must be positive");
        self.stages[stage].len.div_ceil(bucket_elems)
    }

    /// Fixed-size bucket partition of stage `stage`'s run: every bucket
    /// except possibly the last has exactly `bucket_elems` elements, and
    /// together they tile the run exactly — no gap, no overlap (property-
    /// tested below).  Allocation-free: the iterator computes each bucket
    /// from the stage length, so hot loops can walk buckets per step
    /// without materializing a plan.
    pub fn stage_buckets(
        &self,
        stage: usize,
        bucket_elems: usize,
    ) -> impl Iterator<Item = Bucket> {
        let n = self.n_buckets(stage, bucket_elems);
        let len = self.stages[stage].len;
        (0..n).map(move |index| {
            let start = index * bucket_elems;
            Bucket { stage, index, start, end: (start + bucket_elems).min(len) }
        })
    }
}

/// 64-byte-aligned chunk of 16 f32: the allocation unit of [`AlignedBuf`].
/// `repr(C)` + the element count matching the alignment make a `Vec` of
/// these one gapless f32 run (stride == size == alignment == 64 bytes).
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct AlignedChunk([f32; 16]);

/// A heap `f32` buffer whose base address is 64-byte (cache-line / full
/// AVX-512 vector) aligned, dereferencing to `[f32]`.
///
/// `Vec<f32>`'s 4-byte alignment is legal for every kernel in this crate
/// (the blocked kernels use unaligned-tolerant accesses), but an aligned
/// base lets the autovectorizer emit aligned loads for run-starting
/// slices and keeps hot accumulator rows from straddling cache lines.
/// Arena consumers on the training hot path ([`super::GradBuffer`]) use
/// this via [`ArenaLayout::zeros_aligned`]; edges that need a real
/// `Vec<f32>` (checkpoint IO, XLA literals) keep [`ArenaLayout::zeros`].
///
/// Implemented as a `Vec` of 64-byte `repr(C, align(64))` chunks — safe
/// stable Rust, no custom allocator — over-allocating at most 15 floats.
pub struct AlignedBuf {
    chunks: Vec<AlignedChunk>,
    len: usize,
}

impl AlignedBuf {
    /// Zero-filled buffer of `len` f32 with a 64-byte-aligned base.
    pub fn zeroed(len: usize) -> Self {
        let chunks = vec![AlignedChunk([0.0; 16]); len.div_ceil(16)];
        Self { chunks, len }
    }

    /// Number of f32 elements (not the rounded-up capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for AlignedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: `chunks` is a contiguous run of `repr(C)` 16-f32 arrays
        // whose stride equals their size (align == size == 64), so the
        // first `len` f32 reads are in bounds and correctly typed.
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr() as *const f32, self.len) }
    }
}

impl std::ops::DerefMut for AlignedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in `deref`, plus `&mut self` guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr() as *mut f32, self.len) }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        Self { chunks: self.chunks.clone(), len: self.len }
    }
}

/// Empty buffer — lets owners `std::mem::take` the scratch for the
/// duration of a step without an allocation.
impl Default for AlignedBuf {
    fn default() -> Self {
        Self { chunks: Vec::new(), len: 0 }
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBuf").field("len", &self.len).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    fn layout3() -> Arc<ArenaLayout> {
        ArenaLayout::from_stage_shapes(&[
            vec![vec![2, 3], vec![3]],
            vec![vec![4]],
            vec![vec![1, 2], vec![2], vec![2]],
        ])
    }

    #[test]
    fn offsets_are_stage_major_and_contiguous() {
        let l = layout3();
        assert_eq!(l.n_stages(), 3);
        assert_eq!(l.stage_len(0), 9);
        assert_eq!(l.stage_len(1), 4);
        assert_eq!(l.stage_len(2), 6);
        assert_eq!(l.total_len, 19);
        assert_eq!(l.stage_range(0), 0..9);
        assert_eq!(l.stage_range(1), 9..13);
        assert_eq!(l.stage_range(2), 13..19);
        assert_eq!(l.stages[0].views[1].offset, 6);
        assert_eq!(l.bytes(), 19 * 4);
    }

    #[test]
    fn layout_from_params_matches_shapes() {
        let params = vec![
            vec![Tensor::zeros(vec![2, 3]), Tensor::zeros(vec![3])],
            vec![Tensor::zeros(vec![4])],
        ];
        let l = ArenaLayout::from_params(&params);
        assert_eq!(l.total_len, 13);
        assert_eq!(l.stages[0].views[0].shape, vec![2, 3]);
    }

    /// Property: arena ↔ tensor conversion preserves every element, for
    /// random stage counts, tensor counts, shapes and values.
    #[test]
    fn prop_roundtrip_preserves_every_element() {
        check("arena-roundtrip", 50, |g| {
            let n_stages = g.usize_in(1, 4);
            let shapes: Vec<Vec<Vec<usize>>> = (0..n_stages)
                .map(|_| {
                    (0..g.usize_in(1, 4))
                        .map(|_| {
                            (0..g.usize_in(1, 3))
                                .map(|_| g.usize_in(1, 5))
                                .collect()
                        })
                        .collect()
                })
                .collect();
            let l = ArenaLayout::from_stage_shapes(&shapes);
            // random per-tensor params
            let params: Vec<Vec<Tensor>> = shapes
                .iter()
                .map(|st| {
                    st.iter()
                        .map(|s| {
                            let len = s.iter().product();
                            Tensor::new(s.clone(), g.vec_f32(len, -10.0, 10.0))
                        })
                        .collect()
                })
                .collect();
            // tensors → flat → tensors is the identity
            let flat = l.flatten(&params);
            assert_eq!(flat.len(), l.total_len);
            let back = l.unflatten(&flat);
            assert_eq!(back, params);
            // flat → tensors → flat is the identity
            let mut flat2 = l.zeros();
            for j in 0..n_stages {
                l.write_stage(j, &back[j], &mut flat2[l.stage_range(j)]);
            }
            assert_eq!(flat2, flat);
            // element-exact view addressing: every tensor element appears
            // at stage_offset + view offset + index
            for (j, st) in params.iter().enumerate() {
                for (t, v) in st.iter().zip(&l.stages[j].views) {
                    for (k, x) in t.data.iter().enumerate() {
                        assert_eq!(flat[l.stage_offsets[j] + v.offset + k], *x);
                    }
                }
            }
        });
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn write_stage_rejects_wrong_shape() {
        let l = layout3();
        let mut run = l.stage_zeros(1);
        l.write_stage(1, &[Tensor::zeros(vec![2, 2])], &mut run);
    }

    #[test]
    fn buckets_tile_known_layout() {
        let l = layout3(); // stage lens 9, 4, 6
        let b: Vec<Bucket> = l.stage_buckets(0, 4).collect();
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].range(), 0..4);
        assert_eq!(b[1].range(), 4..8);
        assert_eq!(b[2].range(), 8..9); // short tail
        assert_eq!(l.n_buckets(0, 4), 3);
        // bucket larger than the run: one bucket covering everything
        let b: Vec<Bucket> = l.stage_buckets(1, 1000).collect();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].range(), 0..4);
    }

    /// Property: for adversarial bucket sizes, the buckets of every stage
    /// tile the stage run exactly — contiguous from 0 to len, no gap, no
    /// overlap, no empty bucket, and all but the last are full-size.
    #[test]
    fn prop_buckets_tile_stage_runs_exactly() {
        check("arena-bucket-tiling", 60, |g| {
            let n_stages = g.usize_in(1, 4);
            let shapes: Vec<Vec<Vec<usize>>> = (0..n_stages)
                .map(|_| {
                    (0..g.usize_in(1, 4))
                        .map(|_| vec![g.usize_in(1, 97)])
                        .collect()
                })
                .collect();
            let l = ArenaLayout::from_stage_shapes(&shapes);
            for stage in 0..n_stages {
                let len = l.stage_len(stage);
                // adversarial sizes: 1, len±1, len, primes, oversized
                for bucket_elems in
                    [1, 2, 3, 7, 13, len.saturating_sub(1).max(1), len, len + 1, 10 * len + 1]
                {
                    let buckets: Vec<Bucket> =
                        l.stage_buckets(stage, bucket_elems).collect();
                    assert_eq!(buckets.len(), l.n_buckets(stage, bucket_elems));
                    let mut covered = 0usize;
                    for (k, b) in buckets.iter().enumerate() {
                        assert_eq!(b.stage, stage);
                        assert_eq!(b.index, k);
                        assert_eq!(b.start, covered, "gap or overlap");
                        assert!(!b.is_empty(), "empty bucket");
                        assert!(b.len() <= bucket_elems);
                        if k + 1 < buckets.len() {
                            assert_eq!(b.len(), bucket_elems, "only the tail may be short");
                        }
                        covered = b.end;
                    }
                    assert_eq!(covered, len, "buckets must cover the whole run");
                }
            }
        });
    }

    #[test]
    #[should_panic(expected = "bucket_elems must be positive")]
    fn zero_bucket_size_rejected() {
        let l = layout3();
        let _ = l.n_buckets(0, 0);
    }

    #[test]
    fn aligned_buf_is_aligned_and_slice_compatible() {
        check("aligned-buf", 30, |g| {
            let len = g.usize_in(0, 100);
            let mut buf = AlignedBuf::zeroed(len);
            assert_eq!(buf.len(), len);
            assert_eq!(buf.is_empty(), len == 0);
            assert_eq!(buf.as_ptr() as usize % 64, 0, "base must be 64-byte aligned");
            assert!(buf.iter().all(|x| *x == 0.0));
            for (i, x) in buf.iter_mut().enumerate() {
                *x = i as f32;
            }
            let copy = buf.clone();
            for i in 0..len {
                assert_eq!(copy[i], i as f32);
            }
        });
    }

    #[test]
    fn zeros_aligned_matches_layout_len() {
        let l = layout3();
        let buf = l.zeros_aligned();
        assert_eq!(buf.len(), l.total_len);
        assert_eq!(buf.as_ptr() as usize % 64, 0);
        // slices through stage ranges work exactly as on Vec<f32>
        assert_eq!(buf[l.stage_range(1)].len(), l.stage_len(1));
    }
}
