//! Versioned parameter store: θ_t ("fresh") and θ_{t−1} ("stale") per
//! stage, plus momentum — all held as flat arenas (one contiguous `f32`
//! run per stage, stage-major; see [`super::arena`]).  The bootstrap
//! convention θ_{−1} := θ_0 makes all rules coincide at step 0 (tested
//! here and in the python mirror).
//!
//! `commit_step` is a buffer *rotation*, not a copy (DESIGN-PERF.md): the
//! optimizer writes θ_{t+1} into the store's `next` arena via
//! [`ParamStore::update_parts`]; committing rotates next → cur → prev →
//! next-scratch.  Steady-state training neither allocates nor copies
//! parameter state.

use std::sync::Arc;

use crate::parallel::arena::ArenaLayout;
use crate::parallel::update_rule::{Rule, Version};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct ParamStore {
    layout: Arc<ArenaLayout>,
    /// θ_t, model-wide stage-major flat.
    cur: Vec<f32>,
    /// θ_{t−1}.
    prev: Vec<f32>,
    /// Scratch the optimizer writes θ_{t+1} into before `commit_step`.
    next: Vec<f32>,
    /// Momentum, same layout.
    moms: Vec<f32>,
    /// Which stages have had their `next` slot handed out/written this
    /// step — commit_step asserts full coverage (debug), restoring the
    /// old whole-set-commit API's "no stage silently recycles stale
    /// scratch" invariant.
    next_written: Vec<bool>,
    step: u64,
}

impl ParamStore {
    pub fn new(init: Vec<Vec<Tensor>>) -> Self {
        let layout = ArenaLayout::from_params(&init);
        let cur = layout.flatten(&init);
        Self::from_flat(layout, cur)
    }

    /// Build from an already-flat θ_0 (must match `layout`).
    pub fn from_flat(layout: Arc<ArenaLayout>, cur: Vec<f32>) -> Self {
        assert_eq!(cur.len(), layout.total_len, "init params/layout mismatch");
        let prev = cur.clone(); // θ_{−1} := θ_0
        Self::restore(layout, cur, prev, None, 0)
    }

    /// Rebuild a store mid-run from checkpointed state: θ_t (`cur`),
    /// θ_{t−1} (`prev`), momentum (zeros when `None` — e.g. a ring
    /// non-owner that never reads it) and the step counter.  A store
    /// restored from a θ-version-boundary checkpoint continues the run
    /// bit-identically (`parallel::checkpoint`, tests/robustness.rs).
    pub fn restore(
        layout: Arc<ArenaLayout>,
        cur: Vec<f32>,
        prev: Vec<f32>,
        moms: Option<Vec<f32>>,
        step: u64,
    ) -> Self {
        assert_eq!(cur.len(), layout.total_len, "cur/layout mismatch");
        assert_eq!(prev.len(), layout.total_len, "prev/layout mismatch");
        let moms = moms.unwrap_or_else(|| layout.zeros());
        assert_eq!(moms.len(), layout.total_len, "moms/layout mismatch");
        let next = layout.zeros();
        let next_written = vec![false; layout.n_stages()];
        Self { layout, cur, prev, next, moms, next_written, step }
    }

    pub fn layout(&self) -> &Arc<ArenaLayout> {
        &self.layout
    }

    pub fn n_stages(&self) -> usize {
        self.layout.n_stages()
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    /// θ_t of one stage, contiguous.
    pub fn fresh(&self, stage: usize) -> &[f32] {
        &self.cur[self.layout.stage_range(stage)]
    }

    /// θ_{t−1} of one stage, contiguous.
    pub fn stale(&self, stage: usize) -> &[f32] {
        &self.prev[self.layout.stage_range(stage)]
    }

    pub fn momentum(&self, stage: usize) -> &[f32] {
        &self.moms[self.layout.stage_range(stage)]
    }

    /// θ̂_{i}^j for micro-batch `i` (1-based) under `rule`.
    pub fn select(&self, rule: &Rule, i: usize, stage: usize) -> &[f32] {
        match rule.version(i, stage + 1, self.n_stages()) {
            Version::Fresh => self.fresh(stage),
            Version::Stale => self.stale(stage),
        }
    }

    /// Split borrows for the optimizer: (θ_t input, momentum in/out,
    /// θ_{t+1} output slot) of one stage.  The optimizer reads `cur`,
    /// updates `moms` in place and writes the new parameters into `next`;
    /// [`Self::commit_step`] then makes them current — no clone of θ_t,
    /// no allocation.
    pub fn update_parts(&mut self, stage: usize) -> (&[f32], &mut [f32], &mut [f32]) {
        self.next_written[stage] = true;
        let r = self.layout.stage_range(stage);
        (
            &self.cur[r.clone()],
            &mut self.moms[r.clone()],
            &mut self.next[r],
        )
    }

    /// θ_{t+1} of one stage as already written into the `next` slot
    /// (valid between `update_parts` and `commit_step` — e.g. to hand the
    /// fresh parameters to a ring neighbour).
    pub fn next_stage(&self, stage: usize) -> &[f32] {
        &self.next[self.layout.stage_range(stage)]
    }

    /// Write externally received θ_{t+1} for one stage into the `next`
    /// slot (ring hand-off receivers).
    pub fn write_next(&mut self, stage: usize, src: &[f32]) {
        self.next_written[stage] = true;
        let r = self.layout.stage_range(stage);
        self.next[r].copy_from_slice(src);
    }

    /// Finish training step t: the parameters accumulated in the `next`
    /// slot become θ_{t+1}, current θ_t becomes the stale version, and the
    /// old stale buffer is recycled as the next scratch.  Pure pointer
    /// rotation — zero copies, zero allocation.
    pub fn commit_step(&mut self) {
        debug_assert!(
            self.next_written.iter().all(|w| *w),
            "commit_step: stages {:?} never wrote their next slot — the \
             rotation would promote recycled θ_{{t−1}} scratch as θ_{{t+1}}",
            self.next_written
                .iter()
                .enumerate()
                .filter(|(_, w)| !**w)
                .map(|(s, _)| s)
                .collect::<Vec<_>>()
        );
        self.next_written.iter_mut().for_each(|w| *w = false);
        std::mem::swap(&mut self.prev, &mut self.cur); // prev ← θ_t
        std::mem::swap(&mut self.cur, &mut self.next); // cur ← θ_{t+1}
        self.step += 1;
    }

    /// Total parameter bytes held (cur + prev + next scratch + momentum).
    pub fn bytes(&self) -> u64 {
        4 * self.layout.bytes()
    }

    /// Flatten θ_t for checkpointing / equivalence checks (already flat —
    /// this is a borrow, not a copy).
    pub fn flat_params(&self) -> &[f32] {
        &self.cur
    }

    /// Model-wide flat θ_{t−1} (checkpointing).
    pub fn stale_flat(&self) -> &[f32] {
        &self.prev
    }

    /// Model-wide flat momentum (checkpointing).
    pub fn momentum_flat(&self) -> &[f32] {
        &self.moms
    }

    /// Materialize θ_t of one stage as tensors (edge-of-system only).
    pub fn fresh_tensors(&self, stage: usize) -> Vec<Tensor> {
        self.layout.read_stage(stage, self.fresh(stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    fn store() -> ParamStore {
        ParamStore::new(vec![
            vec![Tensor::new(vec![2], vec![1.0, 2.0])],
            vec![Tensor::new(vec![1], vec![5.0])],
        ])
    }

    /// Emulate an optimizer writing `new` into the next slot.
    fn write_all_next(s: &mut ParamStore, new: &[&[f32]]) {
        for (j, st) in new.iter().enumerate() {
            s.write_next(j, st);
        }
    }

    #[test]
    fn bootstrap_prev_equals_cur() {
        let s = store();
        assert_eq!(s.fresh(0), s.stale(0));
        assert_eq!(s.step(), 0);
        assert_eq!(s.flat_params(), &[1.0, 2.0, 5.0]);
    }

    #[test]
    fn commit_rotates_versions() {
        let mut s = store();
        write_all_next(&mut s, &[&[10.0, 20.0], &[50.0]]);
        s.commit_step();
        assert_eq!(s.fresh(0), &[10.0, 20.0]);
        assert_eq!(s.stale(0), &[1.0, 2.0]);
        assert_eq!(s.fresh(1), &[50.0]);
        assert_eq!(s.step(), 1);
        // second step: the recycled scratch must not leak old values
        write_all_next(&mut s, &[&[11.0, 21.0], &[51.0]]);
        s.commit_step();
        assert_eq!(s.fresh(0), &[11.0, 21.0]);
        assert_eq!(s.stale(0), &[10.0, 20.0]);
    }

    #[test]
    fn select_follows_rule() {
        let mut s = store();
        write_all_next(&mut s, &[&[10.0, 20.0], &[50.0]]);
        s.commit_step();
        // N=2 stages. CDP-v2: mb 1 → stale for stage 1 (j=1 < N-i+1=2),
        // fresh for stage 2.
        assert_eq!(s.select(&Rule::CdpV2, 1, 0), &[1.0, 2.0]);
        assert_eq!(s.select(&Rule::CdpV2, 1, 1), &[50.0]);
        assert_eq!(s.select(&Rule::Dp, 1, 0), &[10.0, 20.0]);
        assert_eq!(s.select(&Rule::CdpV1, 2, 1), &[5.0]);
    }

    #[test]
    fn update_parts_are_disjoint_stage_slices() {
        let mut s = store();
        {
            let (cur, moms, next) = s.update_parts(0);
            assert_eq!(cur, &[1.0, 2.0]);
            moms.copy_from_slice(&[0.5, 0.5]);
            next.copy_from_slice(&[7.0, 8.0]);
        }
        assert_eq!(s.momentum(0), &[0.5, 0.5]);
        assert_eq!(s.next_stage(0), &[7.0, 8.0]);
        assert_eq!(s.momentum(1), &[0.0]); // other stage untouched
    }

    #[test]
    #[should_panic(expected = "never wrote their next slot")]
    fn commit_without_full_coverage_panics() {
        let mut s = store();
        s.write_next(0, &[9.0, 9.0]); // stage 1 never written
        s.commit_step();
    }

    #[test]
    fn bytes_counts_four_buffers() {
        let s = store();
        assert_eq!(s.bytes(), 4 * (2 + 1) * 4);
    }

    /// Property: select/commit semantics over random models match a naive
    /// two-version per-tensor reference implementation.
    #[test]
    fn prop_select_commit_matches_naive_reference() {
        check("store-vs-naive", 30, |g| {
            let n = g.usize_in(1, 4);
            let init: Vec<Vec<Tensor>> = (0..n)
                .map(|_| {
                    (0..g.usize_in(1, 3))
                        .map(|_| {
                            let len = g.usize_in(1, 6);
                            Tensor::new(vec![len], g.vec_f32(len, -1.0, 1.0))
                        })
                        .collect()
                })
                .collect();
            let mut s = ParamStore::new(init.clone());
            // naive model: per-step full copies
            let flat = |p: &Vec<Vec<Tensor>>, j: usize| -> Vec<f32> {
                p[j].iter().flat_map(|t| t.data.iter().copied()).collect()
            };
            let mut naive_cur = init;
            let mut naive_prev: Vec<Vec<Tensor>>;
            for _step in 0..3 {
                // random "update": new = cur scaled per stage
                let scale = g.f32_in(0.5, 1.5);
                let new: Vec<Vec<Tensor>> = naive_cur
                    .iter()
                    .map(|st| {
                        st.iter()
                            .map(|t| {
                                let mut c = t.clone();
                                c.scale(scale);
                                c
                            })
                            .collect()
                    })
                    .collect();
                for j in 0..n {
                    let flat_new: Vec<f32> =
                        new[j].iter().flat_map(|t| t.data.iter().copied()).collect();
                    s.write_next(j, &flat_new);
                }
                s.commit_step();
                naive_prev = std::mem::replace(&mut naive_cur, new);
                // all rules, all micro-batches, all stages agree
                for rule in [Rule::Dp, Rule::CdpV1, Rule::CdpV2] {
                    for i in 1..=n {
                        for j in 0..n {
                            let want = match rule.version(i, j + 1, n) {
                                Version::Fresh => flat(&naive_cur, j),
                                Version::Stale => flat(&naive_prev, j),
                            };
                            assert_eq!(s.select(&rule, i, j), &want[..]);
                        }
                    }
                }
            }
        });
    }
}
