//! Versioned parameter store: θ_t ("fresh") and θ_{t−1} ("stale") per
//! stage, plus momentum.  The bootstrap convention θ_{−1} := θ_0 makes all
//! rules coincide at step 0 (tested here and in the python mirror).
//!
//! `commit_step` is a buffer *swap*, not a copy (DESIGN.md §Perf-L3): the
//! outgoing θ_t becomes θ_{t−1} by move.

use crate::parallel::update_rule::{Rule, Version};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct ParamStore {
    cur: Vec<Vec<Tensor>>,
    prev: Vec<Vec<Tensor>>,
    moms: Vec<Vec<Tensor>>,
    step: u64,
}

impl ParamStore {
    pub fn new(init: Vec<Vec<Tensor>>) -> Self {
        let prev = init.clone(); // θ_{−1} := θ_0
        let moms = init
            .iter()
            .map(|st| st.iter().map(|t| Tensor::zeros(t.shape.clone())).collect())
            .collect();
        Self { cur: init, prev, moms, step: 0 }
    }

    pub fn n_stages(&self) -> usize {
        self.cur.len()
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn fresh(&self, stage: usize) -> &Vec<Tensor> {
        &self.cur[stage]
    }

    pub fn stale(&self, stage: usize) -> &Vec<Tensor> {
        &self.prev[stage]
    }

    pub fn momentum(&self, stage: usize) -> &Vec<Tensor> {
        &self.moms[stage]
    }

    /// θ̂_{i}^j for micro-batch `i` (1-based) under `rule`.
    pub fn select(&self, rule: &Rule, i: usize, stage: usize) -> &Vec<Tensor> {
        match rule.version(i, stage + 1, self.n_stages()) {
            Version::Fresh => self.fresh(stage),
            Version::Stale => self.stale(stage),
        }
    }

    /// Mutable access for the optimizer (params + momentum of one stage).
    /// Used by trainers that update in place before committing.
    pub fn stage_mut(&mut self, stage: usize) -> (&mut Vec<Tensor>, &mut Vec<Tensor>) {
        (&mut self.cur[stage], &mut self.moms[stage])
    }

    /// Finish training step t: the provided `new` parameters become θ_{t+1},
    /// current θ_t becomes the stale version.  Momentum was already updated
    /// in place by the optimizer.
    pub fn commit_step(&mut self, new: Vec<Vec<Tensor>>) {
        debug_assert_eq!(new.len(), self.cur.len());
        self.prev = std::mem::replace(&mut self.cur, new);
        self.step += 1;
    }

    /// Total parameter bytes held (both versions).
    pub fn bytes(&self) -> u64 {
        let one = |v: &Vec<Vec<Tensor>>| {
            v.iter()
                .flat_map(|st| st.iter().map(|t| t.bytes() as u64))
                .sum::<u64>()
        };
        one(&self.cur) + one(&self.prev) + one(&self.moms)
    }

    /// Flatten θ_t for checkpointing / equivalence checks.
    pub fn flat_params(&self) -> Vec<f32> {
        self.cur
            .iter()
            .flat_map(|st| st.iter().flat_map(|t| t.data.iter().copied()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::new(vec![
            vec![Tensor::new(vec![2], vec![1.0, 2.0])],
            vec![Tensor::new(vec![1], vec![5.0])],
        ])
    }

    #[test]
    fn bootstrap_prev_equals_cur() {
        let s = store();
        assert_eq!(s.fresh(0), s.stale(0));
        assert_eq!(s.step(), 0);
    }

    #[test]
    fn commit_swaps_versions() {
        let mut s = store();
        let new = vec![
            vec![Tensor::new(vec![2], vec![10.0, 20.0])],
            vec![Tensor::new(vec![1], vec![50.0])],
        ];
        s.commit_step(new.clone());
        assert_eq!(s.fresh(0)[0].data, vec![10.0, 20.0]);
        assert_eq!(s.stale(0)[0].data, vec![1.0, 2.0]);
        assert_eq!(s.step(), 1);
    }

    #[test]
    fn select_follows_rule() {
        let mut s = store();
        s.commit_step(vec![
            vec![Tensor::new(vec![2], vec![10.0, 20.0])],
            vec![Tensor::new(vec![1], vec![50.0])],
        ]);
        // N=2 stages. CDP-v2: mb 1 → stale for stage 1 (j=1 < N-i+1=2),
        // fresh for stage 2.
        assert_eq!(s.select(&Rule::CdpV2, 1, 0)[0].data, vec![1.0, 2.0]);
        assert_eq!(s.select(&Rule::CdpV2, 1, 1)[0].data, vec![50.0]);
        assert_eq!(s.select(&Rule::Dp, 1, 0)[0].data, vec![10.0, 20.0]);
        assert_eq!(s.select(&Rule::CdpV1, 2, 1)[0].data, vec![5.0]);
    }

    #[test]
    fn bytes_counts_three_copies() {
        let s = store();
        assert_eq!(s.bytes(), 3 * (2 + 1) * 4);
    }
}
