//! Time-step schedules (paper Fig. 1).
//!
//! One *time step* = one stage-granularity forward or backward.  A training
//! step spans 2N time steps.  DP runs all N workers in lockstep; CDP delays
//! worker i by 2·(i−1) time steps, producing the cyclic pattern in which,
//! in steady state, each stage index is being computed by exactly one
//! worker at every time step, and the total number of retained activation
//! stashes is constant instead of peaking at N·N.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Forward of `stage` for micro-batch `mb` of training step `tstep`.
    Fwd { mb: usize, stage: usize, tstep: u64 },
    /// Backward of `stage` for micro-batch `mb` of training step `tstep`.
    Bwd { mb: usize, stage: usize, tstep: u64 },
    /// Worker has not started yet (cyclic warm-up) or waits on a barrier.
    Idle,
}

impl Op {
    pub fn is_idle(&self) -> bool {
        matches!(self, Op::Idle)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Dp,
    Cyclic,
}

/// A generated timeline: `grid[time][worker]` = op.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub kind: Kind,
    pub n: usize,
    pub grid: Vec<Vec<Op>>,
}

impl Schedule {
    /// DP (Fig 1a): all workers in lockstep, barrier after each training
    /// step (the barrier is *between* time steps and does not occupy a
    /// slot; the all-reduce happens there).
    pub fn dp(n: usize, horizon: usize) -> Self {
        let mut grid = Vec::with_capacity(horizon);
        for k in 0..horizon {
            let tstep = (k / (2 * n)) as u64;
            let phase = k % (2 * n);
            let row: Vec<Op> = (0..n)
                .map(|w| {
                    if phase < n {
                        Op::Fwd { mb: w + 1, stage: phase + 1, tstep }
                    } else {
                        Op::Bwd { mb: w + 1, stage: 2 * n - phase, tstep }
                    }
                })
                .collect();
            grid.push(row);
        }
        Self { kind: Kind::Dp, n, grid }
    }

    /// CDP (Fig 1b/1c): worker i delayed by 2·(i−1) time steps.
    pub fn cyclic(n: usize, horizon: usize) -> Self {
        let mut grid = Vec::with_capacity(horizon);
        for k in 0..horizon {
            let row: Vec<Op> = (0..n)
                .map(|w| {
                    let delay = 2 * w;
                    if k < delay {
                        return Op::Idle;
                    }
                    let local = k - delay;
                    let tstep = (local / (2 * n)) as u64;
                    let phase = local % (2 * n);
                    if phase < n {
                        Op::Fwd { mb: w + 1, stage: phase + 1, tstep }
                    } else {
                        Op::Bwd { mb: w + 1, stage: 2 * n - phase, tstep }
                    }
                })
                .collect();
            grid.push(row);
        }
        Self { kind: Kind::Cyclic, n, grid }
    }

    /// Number of activation stashes worker `w` holds *after* time step `k`
    /// (stage inputs stored awaiting backward).
    pub fn stashes_after(&self, k: usize, w: usize) -> usize {
        match self.grid[k][w] {
            Op::Idle => 0,
            Op::Fwd { stage, .. } => stage,
            Op::Bwd { stage, .. } => stage - 1,
        }
    }

    /// Total stashes across workers after time step `k` — the quantity the
    /// paper plots in Fig 4 (in units of per-stage activation memory).
    pub fn total_stashes_after(&self, k: usize) -> usize {
        (0..self.n).map(|w| self.stashes_after(k, w)).sum()
    }

    /// Peak and steady-state stash totals over the horizon.
    pub fn stash_stats(&self) -> (usize, f64) {
        let totals: Vec<usize> = (0..self.grid.len())
            .map(|k| self.total_stashes_after(k))
            .collect();
        let peak = totals.iter().copied().max().unwrap_or(0);
        // steady state: skip the first 2N warm-up steps
        let skip = (2 * self.n).min(totals.len());
        let steady = &totals[skip..];
        let mean = if steady.is_empty() {
            0.0
        } else {
            steady.iter().sum::<usize>() as f64 / steady.len() as f64
        };
        (peak, mean)
    }

    /// Time steps at which a *global barrier* exists (all workers must have
    /// finished the same training step before any proceeds).  DP: after
    /// every 2N steps.  Cyclic: none.
    pub fn barrier_steps(&self, horizon: usize) -> Vec<usize> {
        match self.kind {
            Kind::Dp => (1..=horizon).filter(|k| k % (2 * self.n) == 0).collect(),
            Kind::Cyclic => Vec::new(),
        }
    }

    /// Gradient hand-off events after time step `k`: (from_worker,
    /// to_worker, stage).  In the cyclic schedule, a worker that completed
    /// `Bwd{stage}` sends its partial gradient fragment for that stage to
    /// the next worker (ring, modulo N) — this is the balanced p2p pattern
    /// of Fig 1c.  In DP all communication is deferred to the barrier.
    pub fn handoffs_after(&self, k: usize) -> Vec<(usize, usize, usize)> {
        if self.kind == Kind::Dp {
            return Vec::new();
        }
        (0..self.n)
            .filter_map(|w| match self.grid[k][w] {
                Op::Bwd { stage, mb, .. } if mb < self.n => {
                    Some((w, (w + 1) % self.n, stage))
                }
                _ => None,
            })
            .collect()
    }

    /// Render the timeline like Fig 1 (rows = workers, cols = time steps).
    pub fn render(&self, upto: usize) -> String {
        let mut out = String::new();
        let upto = upto.min(self.grid.len());
        out.push_str("       ");
        for k in 0..upto {
            out.push_str(&format!("{k:>4}"));
        }
        out.push('\n');
        for w in 0..self.n {
            out.push_str(&format!("mb {:>2} |", w + 1));
            for k in 0..upto {
                let cell = match self.grid[k][w] {
                    Op::Idle => "   .".to_string(),
                    Op::Fwd { stage, .. } => format!("  F{stage}"),
                    Op::Bwd { stage, .. } => format!("  B{stage}"),
                };
                out.push_str(&cell);
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn dp_is_lockstep() {
        let s = Schedule::dp(3, 12);
        for k in 0..12 {
            let first = s.grid[k][0];
            for w in 1..3 {
                match (first, s.grid[k][w]) {
                    (Op::Fwd { stage: a, .. }, Op::Fwd { stage: b, .. }) => {
                        assert_eq!(a, b)
                    }
                    (Op::Bwd { stage: a, .. }, Op::Bwd { stage: b, .. }) => {
                        assert_eq!(a, b)
                    }
                    other => panic!("workers diverged: {other:?}"),
                }
            }
        }
        assert_eq!(s.barrier_steps(12), vec![6, 12]);
    }

    #[test]
    fn dp_stash_peaks_at_n_times_n() {
        let s = Schedule::dp(4, 8);
        let (peak, _) = s.stash_stats();
        assert_eq!(peak, 16); // N workers × N stages at the fwd/bwd turn
    }

    #[test]
    fn cyclic_matches_fig1_pattern() {
        // Fig 1b, N=3: worker 1 starts at 0, worker 2 at 2, worker 3 at 4.
        let s = Schedule::cyclic(3, 10);
        assert_eq!(s.grid[0][0], Op::Fwd { mb: 1, stage: 1, tstep: 0 });
        assert_eq!(s.grid[0][1], Op::Idle);
        assert_eq!(s.grid[2][1], Op::Fwd { mb: 2, stage: 1, tstep: 0 });
        assert_eq!(s.grid[4][2], Op::Fwd { mb: 3, stage: 1, tstep: 0 });
        assert!(s.barrier_steps(10).is_empty());
    }

    #[test]
    fn cyclic_steady_state_stashes_near_half_dp() {
        // Paper: CDP total ≈ (N+1)/2 · B·Ψ_A vs DP peak N · B·Ψ_A.  Our
        // discrete count (a stash exists after a stage's fwd completes and
        // is freed when its bwd completes) gives steady ≈ N²/2 stage-units
        // vs the DP peak of N² — the same "half of DP" claim under a
        // counting convention that excludes the stage currently computing.
        for n in [3usize, 4, 8] {
            let cyc = Schedule::cyclic(n, 8 * n);
            let (peak, steady) = cyc.stash_stats();
            let (dp_peak, _) = Schedule::dp(n, 8 * n).stash_stats();
            assert_eq!(dp_peak, n * n);
            let half = (n * n) as f64 / 2.0;
            assert!(
                (steady - half).abs() <= n as f64 / 2.0 + 1.0,
                "n={n}: steady {steady}, expected ≈{half}"
            );
            // near-constant: peak within one stage-unit of the mean
            assert!((peak as f64 - steady).abs() <= 1.0 + n as f64 * 0.2);
            assert!(peak < dp_peak);
        }
    }

    #[test]
    fn cyclic_one_worker_per_stage_in_steady_state() {
        // After warm-up, at every time step the busy workers compute
        // pairwise-distinct (stage, direction) — the "pyramid sharing"
        // property that lets MP+CDP use N(N+1)/2 devices.
        let n = 4;
        let s = Schedule::cyclic(n, 8 * n);
        for k in (2 * n)..(8 * n) {
            let mut seen = std::collections::HashSet::new();
            for w in 0..n {
                match s.grid[k][w] {
                    Op::Fwd { stage, .. } => assert!(seen.insert((stage, 'f'))),
                    Op::Bwd { stage, .. } => assert!(seen.insert((stage, 'b'))),
                    Op::Idle => panic!("idle in steady state"),
                }
            }
        }
    }

    #[test]
    fn prop_cyclic_every_fwd_has_matching_bwd() {
        check("fwd-bwd-pairing", 30, |g| {
            let n = g.usize_in(1, 8);
            let steps = g.usize_in(1, 4);
            let horizon = 2 * n * steps + 2 * n;
            let s = Schedule::cyclic(n, horizon);
            // for every completed training step of every worker, each stage
            // is forwarded exactly once and backwarded exactly once
            for w in 0..n {
                let mut fwd = vec![0usize; n + 1];
                let mut bwd = vec![0usize; n + 1];
                for k in 0..horizon {
                    match s.grid[k][w] {
                        Op::Fwd { stage, tstep: 0, .. } => fwd[stage] += 1,
                        Op::Bwd { stage, tstep: 0, .. } => bwd[stage] += 1,
                        _ => {}
                    }
                }
                for stage in 1..=n {
                    assert_eq!(fwd[stage], 1, "w={w} stage={stage}");
                    assert_eq!(bwd[stage], 1);
                }
            }
        });
    }

    #[test]
    fn prop_handoffs_are_ring_ordered() {
        check("ring-handoffs", 20, |g| {
            let n = g.usize_in(2, 8);
            let s = Schedule::cyclic(n, 6 * n);
            for k in 0..6 * n {
                for (from, to, stage) in s.handoffs_after(k) {
                    assert_eq!(to, (from + 1) % n);
                    assert!((1..=n).contains(&stage));
                }
            }
        });
    }

    #[test]
    fn render_contains_expected_cells() {
        let s = Schedule::cyclic(3, 8);
        let r = s.render(8);
        assert!(r.contains("F1"));
        assert!(r.contains("B3"));
        assert!(r.contains('.'));
    }
}
