//! Update rules u_{i,j} (paper Eq. CDP): for micro-batch i ∈ [1, N] and
//! stage j ∈ [1, N], choose which parameter version θ̂_{i}^j the gradient
//! is evaluated at: θ_t (Fresh) or θ_{t−1} (Stale).
//!
//! The rule may depend on (i, j) but not on the training step t — that is
//! the paper's stationarity requirement, and what makes the rules
//! realizable by the fixed cyclic timing of Fig 1.  The paper's two edge
//! cases:
//!
//! - CDP-v1: u ≡ stale (max delay; equals PipeDream-2BW's rule under PP).
//! - CDP-v2: u = fresh iff j ≥ N−i+1 (min delay; micro-batch i sees fresh
//!   parameters for the last i stages).
//!
//! `Randomized` implements the future-work extension (random delays),
//! stationary in t by hashing (i, j).

use crate::util::rng::splitmix64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Version {
    Fresh,
    Stale,
}

/// A stationary parameter-version rule.
#[derive(Clone, Debug, PartialEq)]
pub enum Rule {
    /// Synchronous data parallelism: every micro-batch sees θ_t.
    Dp,
    /// CDP-v1: every micro-batch sees θ_{t−1}.
    CdpV1,
    /// CDP-v2: micro-batch i sees θ_t for stages j ≥ N−i+1.
    CdpV2,
    /// Future-work extension: stage j of micro-batch i is fresh with
    /// probability `p_fresh`, decided once per (i, j) from `seed`.
    Randomized { p_fresh: f64, seed: u64 },
}

impl Rule {
    pub fn name(&self) -> &'static str {
        match self {
            Rule::Dp => "dp",
            Rule::CdpV1 => "cdp_v1",
            Rule::CdpV2 => "cdp_v2",
            Rule::Randomized { .. } => "cdp_rand",
        }
    }

    /// Version for micro-batch `i` (1-based), stage `j` (1-based), with
    /// `n` stages == micro-batches.
    pub fn version(&self, i: usize, j: usize, n: usize) -> Version {
        debug_assert!((1..=n).contains(&i) && (1..=n).contains(&j));
        match self {
            Rule::Dp => Version::Fresh,
            Rule::CdpV1 => Version::Stale,
            Rule::CdpV2 => {
                if j >= n - i + 1 {
                    Version::Fresh
                } else {
                    Version::Stale
                }
            }
            Rule::Randomized { p_fresh, seed } => {
                let h = splitmix64(seed ^ ((i as u64) << 32 | j as u64));
                // map to [0, 1)
                let u = (h >> 40) as f64 / (1u64 << 24) as f64;
                if u < *p_fresh {
                    Version::Fresh
                } else {
                    Version::Stale
                }
            }
        }
    }

    /// Number of stale (i, j) pairs — the rule's total delay mass.
    pub fn staleness(&self, n: usize) -> usize {
        (1..=n)
            .flat_map(|i| (1..=n).map(move |j| (i, j)))
            .filter(|&(i, j)| self.version(i, j, n) == Version::Stale)
            .count()
    }

    /// Is this rule realizable by the cyclic timing?  DP is *not* (it
    /// needs all micro-batches to see θ_t simultaneously, which the
    /// staggered execution cannot provide); it is listed for reference.
    pub fn cyclic_realizable(&self) -> bool {
        !matches!(self, Rule::Dp)
    }
}

pub fn rule_by_name(name: &str) -> anyhow::Result<Rule> {
    match name {
        "dp" => Ok(Rule::Dp),
        "cdp_v1" | "v1" => Ok(Rule::CdpV1),
        "cdp_v2" | "v2" => Ok(Rule::CdpV2),
        "cdp_rand" | "rand" => Ok(Rule::Randomized { p_fresh: 0.5, seed: 0xDE1A7 }),
        other => anyhow::bail!("unknown update rule `{other}` (dp|cdp_v1|cdp_v2|cdp_rand)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn dp_always_fresh_v1_always_stale() {
        for n in 1..=8 {
            assert_eq!(Rule::Dp.staleness(n), 0);
            assert_eq!(Rule::CdpV1.staleness(n), n * n);
        }
    }

    #[test]
    fn v2_suffix_pattern_matches_paper() {
        // N = 4, paper Sec 3.2: mb 1 fresh only at stage 4; mb 4 all fresh.
        let n = 4;
        let pat: Vec<Vec<bool>> = (1..=n)
            .map(|i| {
                (1..=n)
                    .map(|j| Rule::CdpV2.version(i, j, n) == Version::Fresh)
                    .collect()
            })
            .collect();
        assert_eq!(pat[0], vec![false, false, false, true]);
        assert_eq!(pat[1], vec![false, false, true, true]);
        assert_eq!(pat[3], vec![true, true, true, true]);
    }

    #[test]
    fn v2_staleness_is_triangular() {
        // #stale = Σ_{i=1..N} (N − i) ... = N(N−1)/2
        for n in 1..=10 {
            assert_eq!(Rule::CdpV2.staleness(n), n * (n - 1) / 2);
        }
    }

    #[test]
    fn v2_monotone_in_microbatch_and_stage() {
        check("v2-monotone", 100, |g| {
            let n = g.usize_in(1, 12);
            let i = g.usize_in(1, n);
            let j = g.usize_in(1, n);
            let v = Rule::CdpV2.version(i, j, n);
            // fresh set grows with i (later micro-batches never lose freshness)
            if v == Version::Fresh && i < n {
                assert_eq!(Rule::CdpV2.version(i + 1, j, n), Version::Fresh);
            }
            // and with j (freshness is a suffix in stages)
            if v == Version::Fresh && j < n {
                assert_eq!(Rule::CdpV2.version(i, j + 1, n), Version::Fresh);
            }
        });
    }

    #[test]
    fn n1_degenerate_all_rules_fresh_or_harmless() {
        // With N=1 the only micro-batch is the last one: v2 is fresh;
        // v1 is stale but θ_{t−1} bootstraps to θ_t at every step only
        // at t=0 — staleness still exists for N=1 in v1 (paper's delayed
        // SGD), the *trainer-level* N=1 equivalence is asserted in the
        // coordinator tests where the full update is exercised.
        assert_eq!(Rule::CdpV2.version(1, 1, 1), Version::Fresh);
        assert_eq!(Rule::CdpV1.version(1, 1, 1), Version::Stale);
    }

    #[test]
    fn randomized_is_stationary_and_seeded() {
        let r = Rule::Randomized { p_fresh: 0.5, seed: 7 };
        for i in 1..=6 {
            for j in 1..=6 {
                assert_eq!(r.version(i, j, 6), r.version(i, j, 6));
            }
        }
        let r2 = Rule::Randomized { p_fresh: 0.5, seed: 8 };
        let diff = (1..=6)
            .flat_map(|i| (1..=6).map(move |j| (i, j)))
            .filter(|&(i, j)| r.version(i, j, 6) != r2.version(i, j, 6))
            .count();
        assert!(diff > 0, "different seeds should differ somewhere");
    }

    #[test]
    fn rand_extreme_probabilities() {
        let all = Rule::Randomized { p_fresh: 1.0, seed: 3 };
        let none = Rule::Randomized { p_fresh: 0.0, seed: 3 };
        assert_eq!(all.staleness(8), 0);
        assert_eq!(none.staleness(8), 64);
    }

    #[test]
    fn names_roundtrip() {
        for n in ["dp", "cdp_v1", "cdp_v2", "cdp_rand"] {
            assert_eq!(rule_by_name(n).unwrap().name(), n);
        }
        assert!(rule_by_name("bogus").is_err());
    }
}
