//! Host-side tensors: the coordinator's unit of parameter, gradient and
//! activation state.  Deliberately simple — contiguous f32 (or i32) with a
//! shape — because everything numeric runs in HLO; the host side only
//! stores, versions, communicates and reduces.

pub mod bf16;
pub mod ops;

/// Contiguous f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data len {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![1], data: vec![v] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// In-place elementwise add (gradient accumulation hot path —
    /// DESIGN.md §Perf-L3: no temporaries).
    pub fn add_assign(&mut self, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

/// Contiguous i32 tensor (token ids, class labels).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }
}

/// A tensor of either dtype, as it crosses the HLO boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Tensor),
    I32(IntTensor),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(t) => &t.shape,
            HostTensor::I32(t) => &t.shape,
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            HostTensor::F32(t) => t.data.len() * 4,
            HostTensor::I32(t) => t.data.len() * 4,
        }
    }

    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            HostTensor::F32(t) => Some(t),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accounting() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.bytes(), 24);
        let h = HostTensor::F32(t);
        assert_eq!(h.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn add_and_scale() {
        let mut a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![0.5, 0.5, 0.5]);
        a.add_assign(&b);
        a.scale(2.0);
        assert_eq!(a.data, vec![3.0, 5.0, 7.0]);
        assert!(a.is_finite());
        assert_eq!(a.max_abs(), 7.0);
    }
}
