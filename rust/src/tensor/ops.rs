//! Flat-slice reductions used by the communication fabric, plus the dense
//! kernels the pure-Rust [`crate::runtime::NativeBackend`] executes stage
//! graphs with (matmul / relu / bias / softmax-CE and their backward
//! forms).
//!
//! The reductions implement the *reduce* in all-reduce.  The fixed,
//! deterministic accumulation order — of the reductions *and* of the
//! dense kernels — is a correctness feature: it is what lets the
//! multi-worker trainers be bit-identical to the single-process reference
//! (DESIGN.md invariants).  Every kernel here walks its inputs in one
//! fixed order, so the same f32 inputs always produce the same f32 bits,
//! independent of which worker thread runs them.

/// dst += src, elementwise.
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// y += a·x, fused (gradient accumulation / weighted reduction hot path).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (d, s) in y.iter_mut().zip(x) {
        *d += a * *s;
    }
}

/// dst = (dst + src) · s, fused — the "add last contribution and average"
/// step of a ring reduction in one pass over the data.  Element-for-element
/// this computes exactly `dst += src; dst *= s`, so it preserves the
/// bit-identical reduction contract.
pub fn add_scale(dst: &mut [f32], src: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, x) in dst.iter_mut().zip(src) {
        *d = (*d + *x) * s;
    }
}

/// dst = (a + b) · s, elementwise, into a separate destination — the ring
/// owner's bucket-assembly step: fold the received partial sum (`a`), its
/// own contribution (`b`) and the 1/N average into one pass that lands
/// directly in the stage-run scratch.  Element-for-element identical to
/// `dst.copy_from_slice(a); add_scale(dst, b, s)`, so the bit-identical
/// reduction contract holds.
pub fn add_scale_into(dst: &mut [f32], a: &[f32], b: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    for ((d, x), y) in dst.iter_mut().zip(a).zip(b) {
        *d = (*x + *y) * s;
    }
}

/// Cache-block size for multi-row reductions: 16 KiB of f32 per row chunk
/// keeps the accumulator chunk plus one source chunk resident in L1/L2
/// while streaming over many rows.
const REDUCE_CHUNK: usize = 4096;

/// dst += Σ rows, chunked: all rows are consumed chunk-by-chunk so the
/// accumulator chunk stays hot instead of being re-streamed from memory
/// once per row.  Per-element the sum order is still row order, so the
/// result is bit-identical to repeated [`add_into`].
pub fn chunked_sum_into(dst: &mut [f32], rows: &[&[f32]]) {
    for r in rows {
        debug_assert_eq!(dst.len(), r.len());
    }
    let mut start = 0;
    while start < dst.len() {
        let end = (start + REDUCE_CHUNK).min(dst.len());
        let d = &mut dst[start..end];
        for r in rows {
            add_into(d, &r[start..end]);
        }
        start = end;
    }
}

/// dst = sum of all rows, reduced in row order (deterministic).
pub fn reduce_rows(rows: &[&[f32]]) -> Vec<f32> {
    assert!(!rows.is_empty());
    let mut out = rows[0].to_vec();
    chunked_sum_into(&mut out, &rows[1..]);
    out
}

/// dst *= s.
pub fn scale(dst: &mut [f32], s: f32) {
    for d in dst.iter_mut() {
        *d *= s;
    }
}

// ---- dense kernels (NativeBackend stage graphs) ---------------------------

/// dst[m,n] = a[m,k] @ b[k,n].  i-k-j loop order: the k-accumulation into
/// each dst row is sequential (deterministic f32 sum order) and the inner
/// loop streams b's rows — cache-friendly without tiling machinery.
pub fn matmul(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(dst.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    dst.fill(0.0);
    for i in 0..m {
        let drow = &mut dst[i * n..(i + 1) * n];
        for (p, brow) in b.chunks_exact(n).enumerate() {
            // skipping exact zeros (common after ReLU) is bit-neutral for
            // finite accumulators: x + 0·y == x in f32 unless x is NaN
            let aip = a[i * k + p];
            if aip != 0.0 {
                for (d, bv) in drow.iter_mut().zip(brow) {
                    *d += aip * *bv;
                }
            }
        }
    }
}

/// dst[m,k] += a[m,n] @ b[k,n]ᵀ  (accumulating) — the `dx += dy @ Wᵀ`
/// step of a linear layer's backward.
pub fn matmul_nt_acc(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(dst.len(), m * k);
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let drow = &mut dst[i * k..(i + 1) * k];
        for (d, brow) in drow.iter_mut().zip(b.chunks_exact(n)) {
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *d += acc;
        }
    }
}

/// dst[k,n] = a[m,k]ᵀ @ b[m,n] — the `dW = xᵀ @ dy` step of a linear
/// layer's backward.  Row-major accumulation over m in fixed order.
pub fn matmul_tn(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(dst.len(), k * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    dst.fill(0.0);
    for i in 0..m {
        let brow = &b[i * n..(i + 1) * n];
        for p in 0..k {
            let aip = a[i * k + p];
            if aip != 0.0 {
                let drow = &mut dst[p * n..(p + 1) * n];
                for (d, bv) in drow.iter_mut().zip(brow) {
                    *d += aip * *bv;
                }
            }
        }
    }
}

/// dst[m,n] += bias[n], broadcast over rows.
pub fn bias_add(dst: &mut [f32], bias: &[f32]) {
    debug_assert_eq!(dst.len() % bias.len(), 0);
    for row in dst.chunks_exact_mut(bias.len()) {
        for (d, b) in row.iter_mut().zip(bias) {
            *d += *b;
        }
    }
}

/// dst[n] = Σ_rows a[m,n] — the `db = Σ dy` step (row order, deterministic).
pub fn col_sums(dst: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len() % dst.len(), 0);
    dst.fill(0.0);
    for row in a.chunks_exact(dst.len()) {
        for (d, v) in dst.iter_mut().zip(row) {
            *d += *v;
        }
    }
}

/// In-place ReLU.
pub fn relu(dst: &mut [f32]) {
    for d in dst.iter_mut() {
        *d = d.max(0.0);
    }
}

/// dst[i] = pre[i] > 0 ? s·g[i] : 0 — fused ReLU-mask + scale of the
/// residual-branch backward (`pre` is the pre-activation).
pub fn relu_bwd_scaled(dst: &mut [f32], g: &[f32], pre: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), g.len());
    debug_assert_eq!(dst.len(), pre.len());
    for ((d, gv), u) in dst.iter_mut().zip(g).zip(pre) {
        *d = if *u > 0.0 { s * *gv } else { 0.0 };
    }
}

/// Softmax cross-entropy over `logits[b, c]` with integer `targets[b]`:
/// returns the batch-mean loss and writes d(loss)/d(logits) — already
/// scaled by 1/b — into `dlogits`.  Row-stable (max-subtracted) and
/// summed in fixed row/column order.
pub fn softmax_ce(
    logits: &[f32],
    targets: &[i32],
    classes: usize,
    dlogits: &mut [f32],
) -> f32 {
    let b = targets.len();
    debug_assert_eq!(logits.len(), b * classes);
    debug_assert_eq!(dlogits.len(), b * classes);
    let inv_b = 1.0 / b as f32;
    let mut loss_sum = 0.0f32;
    for (r, (row, drow)) in logits
        .chunks_exact(classes)
        .zip(dlogits.chunks_exact_mut(classes))
        .enumerate()
    {
        let t = targets[r] as usize;
        debug_assert!(t < classes, "target {t} out of range ({classes} classes)");
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
        let mut z = 0.0f32;
        for (d, x) in drow.iter_mut().zip(row) {
            let e = (*x - mx).exp();
            *d = e;
            z += e;
        }
        let logz = mx + z.ln();
        loss_sum += logz - row[t];
        let inv_z = 1.0 / z;
        for (c, d) in drow.iter_mut().enumerate() {
            let p = *d * inv_z;
            *d = (p - if c == t { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    loss_sum * inv_b
}

/// Loss-only form of [`softmax_ce`] for forward-only evaluation: same
/// row-stable computation and summation order, no gradient buffer.
pub fn softmax_ce_loss(logits: &[f32], targets: &[i32], classes: usize) -> f32 {
    let b = targets.len();
    debug_assert_eq!(logits.len(), b * classes);
    let mut loss_sum = 0.0f32;
    for (r, row) in logits.chunks_exact(classes).enumerate() {
        let t = targets[r] as usize;
        debug_assert!(t < classes, "target {t} out of range ({classes} classes)");
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
        let mut z = 0.0f32;
        for x in row {
            z += (*x - mx).exp();
        }
        loss_sum += mx + z.ln() - row[t];
    }
    // same final scaling op as `softmax_ce` (multiply by the rounded
    // reciprocal), so the two forms agree bit-for-bit
    loss_sum * (1.0 / b as f32)
}

/// Mean absolute difference — used by equivalence tests.
pub fn mean_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
}

/// Relative L2 distance ‖a−b‖ / max(‖a‖, ε).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = a.iter().map(|x| x * x).sum();
    (num / den.max(1e-12)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_is_ordered_sum() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let c = [100.0f32, 200.0];
        assert_eq!(reduce_rows(&[&a, &b, &c]), vec![111.0, 222.0]);
    }

    #[test]
    fn fused_kernels_match_two_pass_forms() {
        let x = [1.0f32, -2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(&mut y, 2.0, &x);
        assert_eq!(y, [12.0, 6.0, 16.0]);

        let mut d = [4.0f32, 8.0];
        add_scale(&mut d, &[2.0, 2.0], 0.5);
        assert_eq!(d, [3.0, 5.0]);

        let mut o = [0.0f32, 0.0];
        add_scale_into(&mut o, &[4.0, 8.0], &[2.0, 2.0], 0.5);
        assert_eq!(o, [3.0, 5.0]); // same result as the in-place form
    }

    #[test]
    fn chunked_sum_is_bit_identical_to_naive() {
        // longer than one chunk so the blocking path is exercised
        let len = REDUCE_CHUNK + 37;
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..len).map(|i| ((r * len + i) as f32).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut naive = vec![0.0f32; len];
        for r in &refs {
            add_into(&mut naive, r);
        }
        let mut chunked = vec![0.0f32; len];
        chunked_sum_into(&mut chunked, &refs);
        for (a, b) in naive.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn matmul_known_values() {
        // [2,3] @ [3,2]
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut c = [0.0f32; 4];
        matmul(&mut c, &a, &b, 2, 3, 2);
        assert_eq!(c, [58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transposes() {
        let m = 3;
        let k = 4;
        let n = 5;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul(&mut c, &a, &b, m, k, n);
        // dx = c @ bᵀ: compare against naive
        let mut dx = vec![0.0f32; m * k];
        matmul_nt_acc(&mut dx, &c, &b, m, n, k);
        for i in 0..m {
            for p in 0..k {
                let want: f32 = (0..n).map(|j| c[i * n + j] * b[p * n + j]).sum();
                assert!((dx[i * k + p] - want).abs() < 1e-5);
            }
        }
        // dw = aᵀ @ c: compare against naive
        let mut dw = vec![0.0f32; k * n];
        matmul_tn(&mut dw, &a, &c, m, k, n);
        for p in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + p] * c[i * n + j]).sum();
                assert!((dw[p * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn bias_relu_colsums() {
        let mut x = [1.0f32, -2.0, 3.0, -4.0];
        bias_add(&mut x, &[0.5, 0.5]);
        assert_eq!(x, [1.5, -1.5, 3.5, -3.5]);
        let mut r = x;
        relu(&mut r);
        assert_eq!(r, [1.5, 0.0, 3.5, 0.0]);
        let mut s = [0.0f32; 2];
        col_sums(&mut s, &x);
        assert_eq!(s, [5.0, -5.0]);
        let mut d = [0.0f32; 4];
        relu_bwd_scaled(&mut d, &[10.0, 10.0, 10.0, 10.0], &x, 0.3);
        assert_eq!(d, [3.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn softmax_ce_uniform_and_gradient_sign() {
        // uniform logits over 4 classes: loss = ln 4, grad = (1/4 - 1{t})/b
        let logits = [0.0f32; 8]; // b=2, c=4
        let targets = [1i32, 3];
        let mut d = [0.0f32; 8];
        let loss = softmax_ce(&logits, &targets, 4, &mut d);
        assert!((loss - 4.0f32.ln()).abs() < 1e-6);
        for (i, dv) in d.iter().enumerate() {
            let (r, c) = (i / 4, i % 4);
            let want = (0.25 - if c == targets[r] as usize { 1.0 } else { 0.0 }) / 2.0;
            assert!((dv - want).abs() < 1e-6, "d[{i}] = {dv}, want {want}");
        }
        // gradient rows sum to zero
        assert!(d[..4].iter().sum::<f32>().abs() < 1e-6);
        // loss-only form agrees with the gradient form
        assert_eq!(loss, softmax_ce_loss(&logits, &targets, 4));
        let logits2 = [0.3f32, -0.7, 1.2, 0.1, -0.4, 0.9];
        let t2 = [2i32, 0];
        let mut d2 = [0.0f32; 6];
        let l_grad = softmax_ce(&logits2, &t2, 3, &mut d2);
        let l_only = softmax_ce_loss(&logits2, &t2, 3);
        assert!((l_grad - l_only).abs() < 1e-6);
    }

    #[test]
    fn softmax_ce_matches_finite_differences() {
        let mut logits = [0.3f32, -0.7, 1.2, 0.1, -0.4, 0.9];
        let targets = [2i32, 0];
        let mut d = [0.0f32; 6];
        let loss = softmax_ce(&logits, &targets, 3, &mut d);
        assert!(loss.is_finite());
        let eps = 1e-3f32;
        for i in 0..6 {
            let orig = logits[i];
            logits[i] = orig + eps;
            let mut scratch = [0.0f32; 6];
            let lp = softmax_ce(&logits, &targets, 3, &mut scratch);
            logits[i] = orig - eps;
            let lm = softmax_ce(&logits, &targets, 3, &mut scratch);
            logits[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((d[i] - fd).abs() < 1e-3, "dlogits[{i}] {} vs fd {fd}", d[i]);
        }
    }

    #[test]
    fn distances() {
        let a = [1.0f32, 0.0];
        let b = [1.0f32, 1.0];
        assert_eq!(mean_abs_diff(&a, &b), 0.5);
        assert!((rel_l2(&a, &b) - 1.0).abs() < 1e-6);
        assert_eq!(rel_l2(&a, &a), 0.0);
    }
}
