//! Flat-slice reductions used by the communication fabric.
//!
//! These implement the *reduce* in all-reduce.  The fixed, deterministic
//! reduction order is a correctness feature: it is what lets the
//! multi-worker trainers be bit-identical to the single-process reference
//! (DESIGN.md invariants).

/// dst += src, elementwise.
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// dst = sum of all rows, reduced in row order (deterministic).
pub fn reduce_rows(rows: &[&[f32]]) -> Vec<f32> {
    assert!(!rows.is_empty());
    let mut out = rows[0].to_vec();
    for r in &rows[1..] {
        add_into(&mut out, r);
    }
    out
}

/// dst *= s.
pub fn scale(dst: &mut [f32], s: f32) {
    for d in dst.iter_mut() {
        *d *= s;
    }
}

/// Mean absolute difference — used by equivalence tests.
pub fn mean_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
}

/// Relative L2 distance ‖a−b‖ / max(‖a‖, ε).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = a.iter().map(|x| x * x).sum();
    (num / den.max(1e-12)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_is_ordered_sum() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let c = [100.0f32, 200.0];
        assert_eq!(reduce_rows(&[&a, &b, &c]), vec![111.0, 222.0]);
    }

    #[test]
    fn distances() {
        let a = [1.0f32, 0.0];
        let b = [1.0f32, 1.0];
        assert_eq!(mean_abs_diff(&a, &b), 0.5);
        assert!((rel_l2(&a, &b) - 1.0).abs() < 1e-6);
        assert_eq!(rel_l2(&a, &a), 0.0);
    }
}
