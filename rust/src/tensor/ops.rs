//! Flat-slice reductions used by the communication fabric, plus the dense
//! kernels the pure-Rust [`crate::runtime::NativeBackend`] executes stage
//! graphs with (matmul / relu / bias / softmax-CE and their backward
//! forms).
//!
//! The reductions implement the *reduce* in all-reduce.  The fixed,
//! deterministic accumulation order — of the reductions *and* of the
//! dense kernels — is a correctness feature: it is what lets the
//! multi-worker trainers be bit-identical to the single-process reference
//! (DESIGN.md invariants).  Every kernel here computes each output
//! element with one fixed accumulation order, so the same f32 inputs
//! always produce the same f32 bits, independent of which worker thread
//! runs them and of how many pool threads partition the work.
//!
//! # Two implementations, one order
//!
//! The dense kernels exist twice (DESIGN-PERF.md §Kernel architecture):
//!
//! * [`scalar`] — the retained readable reference: single-threaded plain
//!   loops whose source *is* the canonical accumulation-order spec.
//! * `fast` (private) — cache-blocked, 4-way-unrolled, auto-vectorizable
//!   loops partitioned across the [`crate::util::par`] worker pool.
//!
//! The two produce **bit-identical f32 outputs for finite inputs**: the
//! fast kernels only restructure loops in order-preserving ways (row /
//! element partitioning plus left-associated unrolling), and where a dot
//! product is lane-split for SIMD (`split_dot8`) the reference
//! implements the *same* split order.  `tests/kernel_equivalence.rs`
//! property-checks this and the pinned-order tests below keep it true.
//!
//! The top-level kernel entry points dispatch on [`kernel_mode`]
//! (default [`KernelMode::Fast`]; `CDP_KERNELS=scalar` or
//! [`set_kernel_mode`] selects the reference — used by the scalar
//! baseline sections of `benches/hotpath.rs`).  The flat reductions are
//! not dispatched: they sit inside asserted zero-allocation windows and
//! on every trainer's bit-audited reduction path, and are already
//! single-pass streaming loops the compiler vectorizes.
#![deny(missing_docs)]

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::par;

/// dst += src, elementwise.
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// y += a·x, fused (gradient accumulation / weighted reduction hot path).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (d, s) in y.iter_mut().zip(x) {
        *d += a * *s;
    }
}

/// dst = (dst + src) · s, fused — the "add last contribution and average"
/// step of a ring reduction in one pass over the data.  Element-for-element
/// this computes exactly `dst += src; dst *= s`, so it preserves the
/// bit-identical reduction contract.
pub fn add_scale(dst: &mut [f32], src: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, x) in dst.iter_mut().zip(src) {
        *d = (*d + *x) * s;
    }
}

/// dst = (a + b) · s, elementwise, into a separate destination — the ring
/// owner's bucket-assembly step: fold the received partial sum (`a`), its
/// own contribution (`b`) and the 1/N average into one pass that lands
/// directly in the stage-run scratch.  Element-for-element identical to
/// `dst.copy_from_slice(a); add_scale(dst, b, s)`, so the bit-identical
/// reduction contract holds.
pub fn add_scale_into(dst: &mut [f32], a: &[f32], b: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    for ((d, x), y) in dst.iter_mut().zip(a).zip(b) {
        *d = (*x + *y) * s;
    }
}

/// Cache-block size for multi-row reductions: 16 KiB of f32 per row chunk
/// keeps the accumulator chunk plus one source chunk resident in L1/L2
/// while streaming over many rows.
const REDUCE_CHUNK: usize = 4096;

/// dst += Σ rows, chunked: all rows are consumed chunk-by-chunk so the
/// accumulator chunk stays hot instead of being re-streamed from memory
/// once per row.  Per-element the sum order is still row order, so the
/// result is bit-identical to repeated [`add_into`].
pub fn chunked_sum_into(dst: &mut [f32], rows: &[&[f32]]) {
    for r in rows {
        debug_assert_eq!(dst.len(), r.len());
    }
    let mut start = 0;
    while start < dst.len() {
        let end = (start + REDUCE_CHUNK).min(dst.len());
        let d = &mut dst[start..end];
        for r in rows {
            add_into(d, &r[start..end]);
        }
        start = end;
    }
}

/// dst = sum of all rows, reduced in row order (deterministic).
pub fn reduce_rows(rows: &[&[f32]]) -> Vec<f32> {
    assert!(!rows.is_empty());
    let mut out = rows[0].to_vec();
    chunked_sum_into(&mut out, &rows[1..]);
    out
}

/// dst *= s.
pub fn scale(dst: &mut [f32], s: f32) {
    for d in dst.iter_mut() {
        *d *= s;
    }
}

// ---- kernel-mode dispatch -------------------------------------------------

/// Which implementation family the dense-kernel entry points use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Cache-blocked, unrolled, pool-parallel kernels (the default).
    Fast,
    /// The retained reference: single-threaded plain loops whose source
    /// is the canonical accumulation-order spec.  Bit-identical to
    /// [`KernelMode::Fast`] for finite f32 inputs.
    ScalarReference,
}

const MODE_UNSET: u8 = 0;
const MODE_FAST: u8 = 1;
const MODE_SCALAR: u8 = 2;
static KERNEL_MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// The active [`KernelMode`].  Initialized lazily from `CDP_KERNELS`
/// (`scalar` selects the reference; anything else, or unset, selects
/// fast); after that, whatever [`set_kernel_mode`] last stored.
pub fn kernel_mode() -> KernelMode {
    match KERNEL_MODE.load(Ordering::Relaxed) {
        MODE_FAST => KernelMode::Fast,
        MODE_SCALAR => KernelMode::ScalarReference,
        _ => {
            let m = match std::env::var("CDP_KERNELS").as_deref() {
                Ok("scalar") => KernelMode::ScalarReference,
                _ => KernelMode::Fast,
            };
            set_kernel_mode(m);
            m
        }
    }
}

/// Select the [`KernelMode`] process-wide (benches' scalar-baseline
/// sections; tests).  Both modes produce the same bits for finite f32
/// inputs, so flipping this mid-run changes speed, not results.
pub fn set_kernel_mode(m: KernelMode) {
    let v = match m {
        KernelMode::Fast => MODE_FAST,
        KernelMode::ScalarReference => MODE_SCALAR,
    };
    KERNEL_MODE.store(v, Ordering::Relaxed);
}

// ---- canonical lane-split dot --------------------------------------------

/// The canonical 8-lane split dot product Σⱼ a[j]·b[j], the one place the
/// kernels' accumulation order differs from a plain sequential sum:
///
/// 1. lane `l` accumulates `a[8c+l]·b[8c+l]` over full 8-chunks `c`, in
///    ascending `c`;
/// 2. lanes combine in the fixed pairwise tree
///    `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`;
/// 3. the `< 8` tail is added sequentially in ascending `j`.
///
/// Both the reference and the fast kernels compute dots in exactly this
/// order, so lane-splitting never breaks bit-identity.  The split is what
/// lets the hot loop vectorize: each lane maps to one SIMD lane with no
/// cross-lane dependency until the final tree.
#[inline]
fn split_dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let c = n & !7;
    let mut acc = [0.0f32; 8];
    let mut j = 0;
    while j < c {
        let (ca, cb) = (&a[j..j + 8], &b[j..j + 8]);
        for ((s, x), y) in acc.iter_mut().zip(ca).zip(cb) {
            *s += *x * *y;
        }
        j += 8;
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    while j < n {
        s += a[j] * b[j];
        j += 1;
    }
    s
}

// ---- reference kernels ----------------------------------------------------

/// The retained scalar reference kernels: single-threaded plain loops
/// whose source is the canonical accumulation-order specification the
/// fast kernels must reproduce bit-for-bit (finite inputs).  Selected via
/// [`KernelMode::ScalarReference`](super::KernelMode); also the baseline the trainstep bench
/// measures speedup against.
pub mod scalar {
    use super::split_dot8;

    /// dst[m,n] = a[m,k] @ b[k,n].  i-k-j loop order: the k-accumulation
    /// into each dst row is sequential (deterministic f32 sum order) and
    /// the inner loop streams b's rows — cache-friendly without tiling
    /// machinery.
    pub fn matmul(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(dst.len(), m * n);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        dst.fill(0.0);
        for i in 0..m {
            let drow = &mut dst[i * n..(i + 1) * n];
            for (p, brow) in b.chunks_exact(n).enumerate() {
                // skipping exact zeros (common after ReLU) is bit-neutral
                // for finite accumulators: x + 0·y == x in f32 unless x
                // is NaN, and the accumulator can never become −0.0
                let aip = a[i * k + p];
                if aip != 0.0 {
                    for (d, bv) in drow.iter_mut().zip(brow) {
                        *d += aip * *bv;
                    }
                }
            }
        }
    }

    /// dst[m,k] += a[m,n] @ b[k,n]ᵀ  (accumulating) — the `dx += dy @ Wᵀ`
    /// step of a linear layer's backward.  Each element is the canonical
    /// lane-split dot (see the module docs) of a row of `a` and a row of
    /// `b`.
    pub fn matmul_nt_acc(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
        debug_assert_eq!(dst.len(), m * k);
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
        if m == 0 || k == 0 {
            return;
        }
        for (arow, drow) in a.chunks_exact(n.max(1)).zip(dst.chunks_exact_mut(k)) {
            for (d, brow) in drow.iter_mut().zip(b.chunks_exact(n.max(1))) {
                *d += split_dot8(arow, brow);
            }
        }
    }

    /// dst[k,n] = a[m,k]ᵀ @ b[m,n] — the `dW = xᵀ @ dy` step of a linear
    /// layer's backward.  Row-major accumulation over m in fixed order.
    pub fn matmul_tn(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(dst.len(), k * n);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        dst.fill(0.0);
        for i in 0..m {
            let brow = &b[i * n..(i + 1) * n];
            for p in 0..k {
                let aip = a[i * k + p];
                if aip != 0.0 {
                    let drow = &mut dst[p * n..(p + 1) * n];
                    for (d, bv) in drow.iter_mut().zip(brow) {
                        *d += aip * *bv;
                    }
                }
            }
        }
    }

    /// dst = relu(dst + bias), rows × broadcast bias — the fused form of
    /// `bias_add` then `relu`, element-for-element the same two ops.
    pub fn bias_add_relu(dst: &mut [f32], bias: &[f32]) {
        super::bias_add(dst, bias);
        super::relu(dst);
    }
}

// ---- fast kernels ---------------------------------------------------------

/// Cache-blocked, 4-way-unrolled, pool-parallel kernels.  Private: reach
/// them through the dispatching entry points.  Order-preservation notes
/// live on each function; DESIGN-PERF.md §Kernel architecture has the
/// full argument.
mod fast {
    use super::{par, split_dot8};

    /// One dst row of the i-k-j matmul, k unrolled ×4.  The unrolled body
    /// writes `d += a0·b0; d += a1·b1; …` as explicit sequential adds, so
    /// per element the k-accumulation order is exactly the reference's
    /// (left-associated, ascending p) — bit-identical for finite inputs
    /// (dropping the reference's zero-skip is bit-neutral, see there).
    #[inline]
    fn matmul_row(drow: &mut [f32], arow: &[f32], b: &[f32], n: usize) {
        drow.fill(0.0);
        let k = arow.len();
        let kc = k & !3;
        let mut p = 0;
        while p < kc {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for ((((d, v0), v1), v2), v3) in drow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3) {
                let mut s = *d;
                s += a0 * *v0;
                s += a1 * *v1;
                s += a2 * *v2;
                s += a3 * *v3;
                *d = s;
            }
            p += 4;
        }
        while p < k {
            let ap = arow[p];
            for (d, bv) in drow.iter_mut().zip(&b[p * n..(p + 1) * n]) {
                *d += ap * *bv;
            }
            p += 1;
        }
    }

    /// dst[m,n] = a[m,k] @ b[k,n], partitioned across dst row blocks —
    /// every output row is computed entirely by one pool task, so the
    /// partition never affects bits.
    pub fn matmul(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(dst.len(), m * n);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        if m == 0 || n == 0 {
            return;
        }
        let rows_per_block = m.div_ceil(par::partition(m, 1));
        par::par_chunks_mut(dst, rows_per_block * n, |blk, dblock| {
            let i0 = blk * rows_per_block;
            for (r, drow) in dblock.chunks_exact_mut(n).enumerate() {
                let i = i0 + r;
                matmul_row(drow, &a[i * k..(i + 1) * k], b, n);
            }
        });
    }

    /// dst[m,k] += a[m,n] @ b[k,n]ᵀ, partitioned across dst element
    /// blocks; every element is one canonical [`split_dot8`] computed
    /// entirely by one pool task.
    pub fn matmul_nt_acc(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
        debug_assert_eq!(dst.len(), m * k);
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
        if m == 0 || k == 0 {
            return;
        }
        let total = m * k;
        let per_block = total.div_ceil(par::partition(total, 64));
        par::par_chunks_mut(dst, per_block, |blk, dblock| {
            let e0 = blk * per_block;
            for (off, d) in dblock.iter_mut().enumerate() {
                let e = e0 + off;
                let (i, p) = (e / k, e % k);
                *d += split_dot8(&a[i * n..(i + 1) * n], &b[p * n..(p + 1) * n]);
            }
        });
    }

    /// dst[k,n] = a[m,k]ᵀ @ b[m,n], partitioned across dst row blocks
    /// (rows of dst are columns p of a), m unrolled ×4 with explicit
    /// sequential adds — per element the m-accumulation order is exactly
    /// the reference's ascending-i order, so bits match (the reference's
    /// zero-skip is bit-neutral as in `matmul`).
    pub fn matmul_tn(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(dst.len(), k * n);
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        if k == 0 || n == 0 {
            return;
        }
        let rows_per_block = k.div_ceil(par::partition(k, 1));
        par::par_chunks_mut(dst, rows_per_block * n, |blk, dblock| {
            let p0 = blk * rows_per_block;
            for (r, drow) in dblock.chunks_exact_mut(n).enumerate() {
                let p = p0 + r;
                drow.fill(0.0);
                let mc = m & !3;
                let mut i = 0;
                while i < mc {
                    let a0 = a[i * k + p];
                    let a1 = a[(i + 1) * k + p];
                    let a2 = a[(i + 2) * k + p];
                    let a3 = a[(i + 3) * k + p];
                    let b0 = &b[i * n..(i + 1) * n];
                    let b1 = &b[(i + 1) * n..(i + 2) * n];
                    let b2 = &b[(i + 2) * n..(i + 3) * n];
                    let b3 = &b[(i + 3) * n..(i + 4) * n];
                    for ((((d, v0), v1), v2), v3) in
                        drow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                    {
                        let mut s = *d;
                        s += a0 * *v0;
                        s += a1 * *v1;
                        s += a2 * *v2;
                        s += a3 * *v3;
                        *d = s;
                    }
                    i += 4;
                }
                while i < m {
                    let ai = a[i * k + p];
                    for (d, bv) in drow.iter_mut().zip(&b[i * n..(i + 1) * n]) {
                        *d += ai * *bv;
                    }
                    i += 1;
                }
            }
        });
    }

    /// dst = relu(dst + bias) in one fused pass — same per-element ops as
    /// `bias_add` then `relu`, so bit-identical to the two-pass reference;
    /// the single pass halves the memory traffic and the straight-line
    /// body auto-vectorizes on the same 8-wide lanes as the matmuls.
    pub fn bias_add_relu(dst: &mut [f32], bias: &[f32]) {
        debug_assert_eq!(dst.len() % bias.len().max(1), 0);
        for row in dst.chunks_exact_mut(bias.len()) {
            for (d, bv) in row.iter_mut().zip(bias) {
                *d = (*d + *bv).max(0.0);
            }
        }
    }
}

// ---- dense kernel entry points (dispatching) ------------------------------

/// dst[m,n] = a[m,k] @ b[k,n].  Dispatches on [`kernel_mode`]; both modes
/// accumulate k sequentially per element, so the bits agree for finite
/// inputs.
pub fn matmul(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    match kernel_mode() {
        KernelMode::Fast => fast::matmul(dst, a, b, m, k, n),
        KernelMode::ScalarReference => scalar::matmul(dst, a, b, m, k, n),
    }
}

/// dst[m,k] += a[m,n] @ b[k,n]ᵀ  (accumulating) — the `dx += dy @ Wᵀ`
/// step of a linear layer's backward.  Dispatches on [`kernel_mode`];
/// both modes compute each element with the canonical lane-split dot.
pub fn matmul_nt_acc(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    match kernel_mode() {
        KernelMode::Fast => fast::matmul_nt_acc(dst, a, b, m, n, k),
        KernelMode::ScalarReference => scalar::matmul_nt_acc(dst, a, b, m, n, k),
    }
}

/// dst[k,n] = a[m,k]ᵀ @ b[m,n] — the `dW = xᵀ @ dy` step of a linear
/// layer's backward.  Dispatches on [`kernel_mode`]; both modes
/// accumulate over m in ascending order per element.
pub fn matmul_tn(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    match kernel_mode() {
        KernelMode::Fast => fast::matmul_tn(dst, a, b, m, k, n),
        KernelMode::ScalarReference => scalar::matmul_tn(dst, a, b, m, k, n),
    }
}

/// dst = relu(dst + bias[n]), broadcast over rows — the fused
/// linear-layer epilogue.  Dispatches on [`kernel_mode`]; the fused fast
/// form performs the identical two ops per element in one pass.
pub fn bias_add_relu(dst: &mut [f32], bias: &[f32]) {
    match kernel_mode() {
        KernelMode::Fast => fast::bias_add_relu(dst, bias),
        KernelMode::ScalarReference => scalar::bias_add_relu(dst, bias),
    }
}

/// dst[m,n] += bias[n], broadcast over rows.
pub fn bias_add(dst: &mut [f32], bias: &[f32]) {
    debug_assert_eq!(dst.len() % bias.len(), 0);
    for row in dst.chunks_exact_mut(bias.len()) {
        for (d, b) in row.iter_mut().zip(bias) {
            *d += *b;
        }
    }
}

/// dst[n] = Σ_rows a[m,n] — the `db = Σ dy` step (row order, deterministic).
pub fn col_sums(dst: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len() % dst.len(), 0);
    dst.fill(0.0);
    for row in a.chunks_exact(dst.len()) {
        for (d, v) in dst.iter_mut().zip(row) {
            *d += *v;
        }
    }
}

/// In-place ReLU.
pub fn relu(dst: &mut [f32]) {
    for d in dst.iter_mut() {
        *d = d.max(0.0);
    }
}

/// dst[i] = pre[i] > 0 ? s·g[i] : 0 — fused ReLU-mask + scale of the
/// residual-branch backward (`pre` is the pre-activation).
pub fn relu_bwd_scaled(dst: &mut [f32], g: &[f32], pre: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), g.len());
    debug_assert_eq!(dst.len(), pre.len());
    for ((d, gv), u) in dst.iter_mut().zip(g).zip(pre) {
        *d = if *u > 0.0 { s * *gv } else { 0.0 };
    }
}

/// Softmax cross-entropy over `logits[b, c]` with integer `targets[b]`:
/// returns the batch-mean loss and writes d(loss)/d(logits) — already
/// scaled by 1/b — into `dlogits`.  Row-stable (max-subtracted) and
/// summed in fixed row/column order.  Not dispatched: the cost is the
/// transcendentals, and the strict row-sequential loss sum is the
/// determinism contract itself.
pub fn softmax_ce(logits: &[f32], targets: &[i32], classes: usize, dlogits: &mut [f32]) -> f32 {
    let b = targets.len();
    debug_assert_eq!(logits.len(), b * classes);
    debug_assert_eq!(dlogits.len(), b * classes);
    let inv_b = 1.0 / b as f32;
    let mut loss_sum = 0.0f32;
    for (r, (row, drow)) in logits
        .chunks_exact(classes)
        .zip(dlogits.chunks_exact_mut(classes))
        .enumerate()
    {
        let t = targets[r] as usize;
        debug_assert!(t < classes, "target {t} out of range ({classes} classes)");
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
        let mut z = 0.0f32;
        for (d, x) in drow.iter_mut().zip(row) {
            let e = (*x - mx).exp();
            *d = e;
            z += e;
        }
        let logz = mx + z.ln();
        loss_sum += logz - row[t];
        let inv_z = 1.0 / z;
        for (c, d) in drow.iter_mut().enumerate() {
            let p = *d * inv_z;
            *d = (p - if c == t { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    loss_sum * inv_b
}

/// Loss-only form of [`softmax_ce`] for forward-only evaluation: same
/// row-stable computation and summation order, no gradient buffer.
pub fn softmax_ce_loss(logits: &[f32], targets: &[i32], classes: usize) -> f32 {
    let b = targets.len();
    debug_assert_eq!(logits.len(), b * classes);
    let mut loss_sum = 0.0f32;
    for (r, row) in logits.chunks_exact(classes).enumerate() {
        let t = targets[r] as usize;
        debug_assert!(t < classes, "target {t} out of range ({classes} classes)");
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
        let mut z = 0.0f32;
        for x in row {
            z += (*x - mx).exp();
        }
        loss_sum += mx + z.ln() - row[t];
    }
    // same final scaling op as `softmax_ce` (multiply by the rounded
    // reciprocal), so the two forms agree bit-for-bit
    loss_sum * (1.0 / b as f32)
}

/// Mean absolute difference — used by equivalence tests.
pub fn mean_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
}

/// Relative L2 distance ‖a−b‖ / max(‖a‖, ε).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = a.iter().map(|x| x * x).sum();
    (num / den.max(1e-12)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_is_ordered_sum() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let c = [100.0f32, 200.0];
        assert_eq!(reduce_rows(&[&a, &b, &c]), vec![111.0, 222.0]);
    }

    #[test]
    fn fused_kernels_match_two_pass_forms() {
        let x = [1.0f32, -2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(&mut y, 2.0, &x);
        assert_eq!(y, [12.0, 6.0, 16.0]);

        let mut d = [4.0f32, 8.0];
        add_scale(&mut d, &[2.0, 2.0], 0.5);
        assert_eq!(d, [3.0, 5.0]);

        let mut o = [0.0f32, 0.0];
        add_scale_into(&mut o, &[4.0, 8.0], &[2.0, 2.0], 0.5);
        assert_eq!(o, [3.0, 5.0]); // same result as the in-place form
    }

    #[test]
    fn chunked_sum_is_bit_identical_to_naive() {
        // longer than one chunk so the blocking path is exercised
        let len = REDUCE_CHUNK + 37;
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..len).map(|i| ((r * len + i) as f32).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut naive = vec![0.0f32; len];
        for r in &refs {
            add_into(&mut naive, r);
        }
        let mut chunked = vec![0.0f32; len];
        chunked_sum_into(&mut chunked, &refs);
        for (a, b) in naive.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn matmul_known_values() {
        // [2,3] @ [3,2]
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut c = [0.0f32; 4];
        matmul(&mut c, &a, &b, 2, 3, 2);
        assert_eq!(c, [58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transposes() {
        let m = 3;
        let k = 4;
        let n = 5;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul(&mut c, &a, &b, m, k, n);
        // dx = c @ bᵀ: compare against naive
        let mut dx = vec![0.0f32; m * k];
        matmul_nt_acc(&mut dx, &c, &b, m, n, k);
        for i in 0..m {
            for p in 0..k {
                let want: f32 = (0..n).map(|j| c[i * n + j] * b[p * n + j]).sum();
                assert!((dx[i * k + p] - want).abs() < 1e-5);
            }
        }
        // dw = aᵀ @ c: compare against naive
        let mut dw = vec![0.0f32; k * n];
        matmul_tn(&mut dw, &a, &c, m, k, n);
        for p in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + p] * c[i * n + j]).sum();
                assert!((dw[p * n + j] - want).abs() < 1e-5);
            }
        }
    }

    /// Deterministic pseudo-random test matrix with zeros sprinkled in
    /// (so the reference's zero-skip paths are exercised).
    fn test_mat(len: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let u = (s >> 40) as u32;
                if u % 5 == 0 {
                    0.0
                } else {
                    (u as f32 / (1u64 << 24) as f32) - 0.5
                }
            })
            .collect()
    }

    /// Covers both the fast-vs-reference bit identity and the mode
    /// dispatch in ONE test: `set_kernel_mode` is process-global, and two
    /// tests flipping it concurrently under the parallel test runner
    /// would race (harmlessly for results — the modes agree bit-for-bit —
    /// but not for asserts that read the mode back).
    #[test]
    fn fast_kernels_bit_match_scalar_reference() {
        // dispatch: the scalar mode routes to the reference and agrees
        {
            let a = test_mat(6 * 10, 1);
            let b = test_mat(10 * 8, 2);
            let mut via_scalar = vec![0.0f32; 6 * 8];
            set_kernel_mode(KernelMode::ScalarReference);
            assert_eq!(kernel_mode(), KernelMode::ScalarReference);
            matmul(&mut via_scalar, &a, &b, 6, 10, 8);
            let mut via_fast = vec![0.0f32; 6 * 8];
            set_kernel_mode(KernelMode::Fast);
            assert_eq!(kernel_mode(), KernelMode::Fast);
            matmul(&mut via_fast, &a, &b, 6, 10, 8);
            assert_bits_eq(&via_scalar, &via_fast, "dispatch");
        }
        // shapes chosen to hit unroll remainders (k % 4, n % 8 ≠ 0) and
        // multi-block parallel partitions
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (8, 16, 9), (17, 33, 12), (4, 64, 64)] {
            let a = test_mat(m * k, 0xA5);
            let b = test_mat(k * n, 0x5A);
            let g = test_mat(m * n, 0x77);
            let mut want = vec![0.0f32; m * n];
            scalar::matmul(&mut want, &a, &b, m, k, n);
            let mut got = vec![0.0f32; m * n];
            set_kernel_mode(KernelMode::Fast);
            matmul(&mut got, &a, &b, m, k, n);
            assert_bits_eq(&want, &got, "matmul");

            let mut want_dx = test_mat(m * k, 0x11);
            let mut got_dx = want_dx.clone();
            scalar::matmul_nt_acc(&mut want_dx, &g, &b, m, n, k);
            matmul_nt_acc(&mut got_dx, &g, &b, m, n, k);
            assert_bits_eq(&want_dx, &got_dx, "matmul_nt_acc");

            let mut want_dw = vec![0.0f32; k * n];
            scalar::matmul_tn(&mut want_dw, &a, &g, m, k, n);
            let mut got_dw = vec![0.0f32; k * n];
            matmul_tn(&mut got_dw, &a, &g, m, k, n);
            assert_bits_eq(&want_dw, &got_dw, "matmul_tn");

            let bias = test_mat(n, 0x33);
            let mut want_h = g.clone();
            scalar::bias_add_relu(&mut want_h, &bias);
            let mut got_h = g.clone();
            bias_add_relu(&mut got_h, &bias);
            assert_bits_eq(&want_h, &got_h, "bias_add_relu");
        }
    }

    fn assert_bits_eq(want: &[f32], got: &[f32], what: &str) {
        assert_eq!(want.len(), got.len());
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "{what}[{i}]: {w} vs {g}");
        }
    }

    #[test]
    fn split_dot_order_is_pinned() {
        // 11 elements: one full 8-chunk + a 3-tail.  Recompute the
        // documented order by hand and demand exact bits.
        let a: Vec<f32> = (0..11).map(|i| (i as f32 * 0.9).sin()).collect();
        let b: Vec<f32> = (0..11).map(|i| (i as f32 * 1.3).cos()).collect();
        let mut lanes = [0.0f32; 8];
        for l in 0..8 {
            lanes[l] += a[l] * b[l];
        }
        let mut want = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        for j in 8..11 {
            want += a[j] * b[j];
        }
        assert_eq!(split_dot8(&a, &b).to_bits(), want.to_bits());
    }

    #[test]
    fn bias_relu_colsums() {
        let mut x = [1.0f32, -2.0, 3.0, -4.0];
        bias_add(&mut x, &[0.5, 0.5]);
        assert_eq!(x, [1.5, -1.5, 3.5, -3.5]);
        let mut r = x;
        relu(&mut r);
        assert_eq!(r, [1.5, 0.0, 3.5, 0.0]);
        let mut s = [0.0f32; 2];
        col_sums(&mut s, &x);
        assert_eq!(s, [5.0, -5.0]);
        let mut d = [0.0f32; 4];
        relu_bwd_scaled(&mut d, &[10.0, 10.0, 10.0, 10.0], &x, 0.3);
        assert_eq!(d, [3.0, 0.0, 3.0, 0.0]);
        // fused epilogue == bias_add then relu
        let mut f1 = [1.0f32, -2.0, 3.0, -4.0];
        bias_add_relu(&mut f1, &[0.5, 0.5]);
        assert_eq!(f1, r);
    }

    #[test]
    fn softmax_ce_uniform_and_gradient_sign() {
        // uniform logits over 4 classes: loss = ln 4, grad = (1/4 - 1{t})/b
        let logits = [0.0f32; 8]; // b=2, c=4
        let targets = [1i32, 3];
        let mut d = [0.0f32; 8];
        let loss = softmax_ce(&logits, &targets, 4, &mut d);
        assert!((loss - 4.0f32.ln()).abs() < 1e-6);
        for (i, dv) in d.iter().enumerate() {
            let (r, c) = (i / 4, i % 4);
            let want = (0.25 - if c == targets[r] as usize { 1.0 } else { 0.0 }) / 2.0;
            assert!((dv - want).abs() < 1e-6, "d[{i}] = {dv}, want {want}");
        }
        // gradient rows sum to zero
        assert!(d[..4].iter().sum::<f32>().abs() < 1e-6);
        // loss-only form agrees with the gradient form
        assert_eq!(loss, softmax_ce_loss(&logits, &targets, 4));
        let logits2 = [0.3f32, -0.7, 1.2, 0.1, -0.4, 0.9];
        let t2 = [2i32, 0];
        let mut d2 = [0.0f32; 6];
        let l_grad = softmax_ce(&logits2, &t2, 3, &mut d2);
        let l_only = softmax_ce_loss(&logits2, &t2, 3);
        assert!((l_grad - l_only).abs() < 1e-6);
    }

    #[test]
    fn softmax_ce_matches_finite_differences() {
        let mut logits = [0.3f32, -0.7, 1.2, 0.1, -0.4, 0.9];
        let targets = [2i32, 0];
        let mut d = [0.0f32; 6];
        let loss = softmax_ce(&logits, &targets, 3, &mut d);
        assert!(loss.is_finite());
        let eps = 1e-3f32;
        for i in 0..6 {
            let orig = logits[i];
            logits[i] = orig + eps;
            let mut scratch = [0.0f32; 6];
            let lp = softmax_ce(&logits, &targets, 3, &mut scratch);
            logits[i] = orig - eps;
            let lm = softmax_ce(&logits, &targets, 3, &mut scratch);
            logits[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((d[i] - fd).abs() < 1e-3, "dlogits[{i}] {} vs fd {fd}", d[i]);
        }
    }

    #[test]
    fn distances() {
        let a = [1.0f32, 0.0];
        let b = [1.0f32, 1.0];
        assert_eq!(mean_abs_diff(&a, &b), 0.5);
        assert!((rel_l2(&a, &b) - 1.0).abs() < 1e-6);
        assert_eq!(rel_l2(&a, &a), 0.0);
    }
}
