//! Flat-slice reductions used by the communication fabric.
//!
//! These implement the *reduce* in all-reduce.  The fixed, deterministic
//! reduction order is a correctness feature: it is what lets the
//! multi-worker trainers be bit-identical to the single-process reference
//! (DESIGN.md invariants).

/// dst += src, elementwise.
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// y += a·x, fused (gradient accumulation / weighted reduction hot path).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (d, s) in y.iter_mut().zip(x) {
        *d += a * *s;
    }
}

/// dst = (dst + src) · s, fused — the "add last contribution and average"
/// step of a ring reduction in one pass over the data.  Element-for-element
/// this computes exactly `dst += src; dst *= s`, so it preserves the
/// bit-identical reduction contract.
pub fn add_scale(dst: &mut [f32], src: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, x) in dst.iter_mut().zip(src) {
        *d = (*d + *x) * s;
    }
}

/// dst = (a + b) · s, elementwise, into a separate destination — the ring
/// owner's bucket-assembly step: fold the received partial sum (`a`), its
/// own contribution (`b`) and the 1/N average into one pass that lands
/// directly in the stage-run scratch.  Element-for-element identical to
/// `dst.copy_from_slice(a); add_scale(dst, b, s)`, so the bit-identical
/// reduction contract holds.
pub fn add_scale_into(dst: &mut [f32], a: &[f32], b: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    for ((d, x), y) in dst.iter_mut().zip(a).zip(b) {
        *d = (*x + *y) * s;
    }
}

/// Cache-block size for multi-row reductions: 16 KiB of f32 per row chunk
/// keeps the accumulator chunk plus one source chunk resident in L1/L2
/// while streaming over many rows.
const REDUCE_CHUNK: usize = 4096;

/// dst += Σ rows, chunked: all rows are consumed chunk-by-chunk so the
/// accumulator chunk stays hot instead of being re-streamed from memory
/// once per row.  Per-element the sum order is still row order, so the
/// result is bit-identical to repeated [`add_into`].
pub fn chunked_sum_into(dst: &mut [f32], rows: &[&[f32]]) {
    for r in rows {
        debug_assert_eq!(dst.len(), r.len());
    }
    let mut start = 0;
    while start < dst.len() {
        let end = (start + REDUCE_CHUNK).min(dst.len());
        let d = &mut dst[start..end];
        for r in rows {
            add_into(d, &r[start..end]);
        }
        start = end;
    }
}

/// dst = sum of all rows, reduced in row order (deterministic).
pub fn reduce_rows(rows: &[&[f32]]) -> Vec<f32> {
    assert!(!rows.is_empty());
    let mut out = rows[0].to_vec();
    chunked_sum_into(&mut out, &rows[1..]);
    out
}

/// dst *= s.
pub fn scale(dst: &mut [f32], s: f32) {
    for d in dst.iter_mut() {
        *d *= s;
    }
}

/// Mean absolute difference — used by equivalence tests.
pub fn mean_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
}

/// Relative L2 distance ‖a−b‖ / max(‖a‖, ε).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = a.iter().map(|x| x * x).sum();
    (num / den.max(1e-12)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_is_ordered_sum() {
        let a = [1.0f32, 2.0];
        let b = [10.0f32, 20.0];
        let c = [100.0f32, 200.0];
        assert_eq!(reduce_rows(&[&a, &b, &c]), vec![111.0, 222.0]);
    }

    #[test]
    fn fused_kernels_match_two_pass_forms() {
        let x = [1.0f32, -2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(&mut y, 2.0, &x);
        assert_eq!(y, [12.0, 6.0, 16.0]);

        let mut d = [4.0f32, 8.0];
        add_scale(&mut d, &[2.0, 2.0], 0.5);
        assert_eq!(d, [3.0, 5.0]);

        let mut o = [0.0f32, 0.0];
        add_scale_into(&mut o, &[4.0, 8.0], &[2.0, 2.0], 0.5);
        assert_eq!(o, [3.0, 5.0]); // same result as the in-place form
    }

    #[test]
    fn chunked_sum_is_bit_identical_to_naive() {
        // longer than one chunk so the blocking path is exercised
        let len = REDUCE_CHUNK + 37;
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..len).map(|i| ((r * len + i) as f32).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut naive = vec![0.0f32; len];
        for r in &refs {
            add_into(&mut naive, r);
        }
        let mut chunked = vec![0.0f32; len];
        chunked_sum_into(&mut chunked, &refs);
        for (a, b) in naive.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn distances() {
        let a = [1.0f32, 0.0];
        let b = [1.0f32, 1.0];
        assert_eq!(mean_abs_diff(&a, &b), 0.5);
        assert!((rel_l2(&a, &b) - 1.0).abs() < 1e-6);
        assert_eq!(rel_l2(&a, &a), 0.0);
    }
}
