//! bfloat16 storage emulation for the mixed-precision training mode.
//!
//! The native backend's bf16 mode keeps an **f32 master copy** of every
//! parameter (the optimizer state and SGD update run in full f32) and
//! emulates bf16 *storage* by rounding values to the nearest bf16 at the
//! points where a bf16 system would store them: parameters as read by
//! compute, and activations/gradient-inputs crossing a stage boundary.
//! Rounding is round-to-nearest-even on the top 16 bits of the f32
//! representation — the standard bf16 conversion — implemented with the
//! classic bit trick and no table lookups, so it is branch-light and
//! auto-vectorizes.
//!
//! Everything here is deterministic pure bit manipulation: the same f32
//! always rounds to the same bf16, so bf16 runs are exactly as
//! reproducible (bit-identical across trainers, thread counts and
//! processes) as f32 runs — just against a different, coarser value
//! lattice.  f32 remains the oracle the equivalence suite pins.

/// Round an f32 to the nearest bf16 (ties to even) and return its 16 raw
/// bits (the high half of the rounded f32).  NaNs are quieted so the
/// payload truncation can't produce an infinity bit pattern.
#[inline]
pub fn to_bits(x: f32) -> u16 {
    let u = x.to_bits();
    if x.is_nan() {
        return ((u >> 16) as u16) | 0x0040;
    }
    // Add 0x7FFF plus the lowest kept bit, then truncate: rounds the
    // discarded 16 bits to nearest, ties to even.  Overflow into the
    // exponent correctly rounds up to the next binade / infinity.
    let round = ((u >> 16) & 1) + 0x7FFF;
    ((u + round) >> 16) as u16
}

/// Expand 16 raw bf16 bits to the f32 with the same value (exact —
/// every bf16 value is representable in f32).
#[inline]
pub fn from_bits(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round an f32 through bf16 and back: the value a bf16 store would
/// hand to the next kernel.
#[inline]
pub fn round(x: f32) -> f32 {
    from_bits(to_bits(x))
}

/// Round a whole buffer through bf16 in place — the stage-boundary /
/// parameter-read quantization pass of the bf16 storage model.
pub fn round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(round(v).to_bits(), v.to_bits(), "{v} should be exact in bf16");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between bf16(1.0) and the next
        // bf16 up (1.0 + 2^-7 steps... the bf16 mantissa has 7 bits, so
        // the step above 1.0 is 2^-7).  Halfway = 1.0 + 2^-8: ties to the
        // even mantissa, which is 1.0 itself.
        let half_step = 1.0f32 + (0.5f32).powi(8);
        assert_eq!(round(half_step), 1.0);
        // Just above halfway rounds up.
        let above = f32::from_bits(half_step.to_bits() + 1);
        assert_eq!(round(above), 1.0 + (0.5f32).powi(7));
    }

    #[test]
    fn relative_error_is_bounded() {
        // bf16 has 8 significand bits (1 implicit + 7 stored): relative
        // rounding error ≤ 2^-8 for normal values.
        let mut x = 1.337e-3f32;
        for _ in 0..60 {
            let q = round(x);
            assert!((q - x).abs() <= x.abs() * 0.00390625 + f32::MIN_POSITIVE);
            x *= 1.7;
        }
    }

    #[test]
    fn idempotent() {
        for i in 0..1000u32 {
            let x = f32::from_bits(0x3F00_0000 + i * 7919);
            let q = round(x);
            assert_eq!(round(q).to_bits(), q.to_bits());
        }
    }

    #[test]
    fn nan_stays_nan_inf_stays_inf() {
        assert!(round(f32::NAN).is_nan());
        assert_eq!(round(f32::MAX), f32::INFINITY); // rounds up past the bf16 max
        let mut v = [1.0f32, f32::NAN, 3.5e38];
        round_slice(&mut v);
        assert_eq!(v[0], 1.0);
        assert!(v[1].is_nan());
        assert_eq!(v[2], f32::INFINITY);
    }
}
