//! Closed-form Table 1 (paper Sec. 4): memory per GPU, communication
//! volume, max communication steps between two time steps, and device
//! count, for every implementation ± CDP.
//!
//! Units are the paper's symbols: Ψ_P (parameter+optimizer bytes of the
//! whole model), B·Ψ_A (activation bytes of one micro-batch through the
//! whole model), B·Ψ_A^int (the stage-boundary subset communicated by MP).

#[derive(Clone, Debug, PartialEq)]
pub struct Table1Row {
    pub implementation: &'static str,
    pub cyclic: bool,
    /// Activation memory per GPU in units of B·Ψ_A.
    pub act_mem: f64,
    /// Parameter memory per GPU in units of Ψ_P.
    pub param_mem: f64,
    /// Communication volume per training step in units of Ψ_P …
    pub comm_psi_p: f64,
    /// … plus this many units of B·Ψ_A^int.
    pub comm_psi_a_int: f64,
    /// Max communication steps between two time steps.  O(1) rows are
    /// uniformly `1.0`; log-N rows carry the *unclamped* `log₂ N`, so the
    /// degenerate cases stay honest (`0.0` at N=1, `1.0` at N=2).
    pub max_comm_steps: f64,
    pub n_gpus: f64,
    pub rule: &'static str,
}

/// All rows of Table 1 for a given N.
pub fn table1_rows(n: usize) -> Vec<Table1Row> {
    let nf = n as f64;
    // Honest log₂N: 0.0 at N=1 (no peers, no comm rounds), 1.0 at N=2.
    // The old `.max(1.0)` clamp erased the N=1/N=2 distinction and made
    // log-N rows indistinguishable from O(1) rows at small N.
    let logn = nf.log2();
    vec![
        Table1Row {
            implementation: "Single-GPU DP",
            cyclic: false,
            act_mem: nf, // N micro-batches' activations peak together
            param_mem: 1.0,
            comm_psi_p: 0.0,
            comm_psi_a_int: 0.0,
            max_comm_steps: 1.0,
            n_gpus: 1.0,
            rule: "DP",
        },
        Table1Row {
            implementation: "Single-GPU + Cyclic",
            cyclic: true,
            act_mem: (nf + 1.0) / 2.0,
            param_mem: 1.0,
            comm_psi_p: 0.0,
            comm_psi_a_int: 0.0,
            max_comm_steps: 1.0,
            n_gpus: 1.0,
            rule: "CDP",
        },
        Table1Row {
            implementation: "Multi-GPU DP",
            cyclic: false,
            act_mem: 1.0,
            param_mem: 1.0,
            comm_psi_p: 1.0,
            comm_psi_a_int: 0.0,
            max_comm_steps: logn,
            n_gpus: nf,
            rule: "DP",
        },
        Table1Row {
            implementation: "Multi-GPU + Cyclic",
            cyclic: true,
            act_mem: 1.0,
            param_mem: 1.0,
            comm_psi_p: 1.0,
            comm_psi_a_int: 0.0,
            max_comm_steps: 1.0,
            n_gpus: nf,
            rule: "CDP",
        },
        Table1Row {
            implementation: "DP with MP",
            cyclic: false,
            act_mem: 1.0 / nf,
            param_mem: 1.0 / nf,
            comm_psi_p: 1.0,
            comm_psi_a_int: 1.0,
            max_comm_steps: logn,
            n_gpus: nf * nf,
            rule: "DP",
        },
        Table1Row {
            implementation: "DP with MP + Cyclic",
            cyclic: true,
            act_mem: 1.0 / nf,
            param_mem: 1.0 / nf,
            comm_psi_p: 0.5,
            comm_psi_a_int: 1.0,
            max_comm_steps: 1.0,
            n_gpus: (nf + 1.0) * nf / 2.0,
            rule: "CDP",
        },
        Table1Row {
            implementation: "PP",
            cyclic: true, // PP is the N-device specialization of CDP (§4.3)
            act_mem: 1.0,
            param_mem: 1.0 / nf,
            comm_psi_p: 0.0,
            comm_psi_a_int: 1.0,
            max_comm_steps: 1.0,
            n_gpus: nf,
            rule: "CDP",
        },
        Table1Row {
            implementation: "ZeRO-DP",
            cyclic: false,
            act_mem: 1.0,
            param_mem: 1.0 / nf,
            comm_psi_p: 1.0,
            comm_psi_a_int: 0.0,
            max_comm_steps: logn,
            n_gpus: nf,
            rule: "DP",
        },
        Table1Row {
            implementation: "ZeRO-DP + Cyclic",
            cyclic: true,
            act_mem: 1.0,
            param_mem: 1.0 / nf,
            comm_psi_p: 1.0,
            comm_psi_a_int: 0.0,
            max_comm_steps: 1.0,
            n_gpus: nf,
            rule: "CDP",
        },
    ]
}

/// Render the table like the paper.
pub fn render_table1(n: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("Table 1 (N = {n})\n"));
    out.push_str(&format!(
        "{:<22} {:>10} {:>9} {:>18} {:>10} {:>8}  {}\n",
        "Implementation", "Act/GPU", "Par/GPU", "Volume", "MaxSteps", "#GPUs", "Rule"
    ));
    for r in table1_rows(n) {
        let vol = match (r.comm_psi_p > 0.0, r.comm_psi_a_int > 0.0) {
            (true, true) => format!("{:.1}ΨP+{:.0}BΨAint", r.comm_psi_p, r.comm_psi_a_int),
            (true, false) => format!("{:.1}ΨP", r.comm_psi_p),
            (false, true) => format!("{:.0}BΨAint", r.comm_psi_a_int),
            (false, false) => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<22} {:>8.2}BΨA {:>8.2}ΨP {:>18} {:>10.1} {:>8.1}  {}\n",
            r.implementation, r.act_mem, r.param_mem, vol, r.max_comm_steps, r.n_gpus, r.rule
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bold_improvements_hold() {
        for n in [3usize, 4, 8, 16] {
            let rows = table1_rows(n);
            let get = |name: &str| rows.iter().find(|r| r.implementation == name).unwrap();
            // single-GPU: CDP halves activation memory (asymptotically)
            assert!(get("Single-GPU + Cyclic").act_mem < get("Single-GPU DP").act_mem);
            // multi-GPU: comm steps collapse to O(1)
            assert_eq!(get("Multi-GPU + Cyclic").max_comm_steps, 1.0);
            assert!(get("Multi-GPU DP").max_comm_steps >= 1.0);
            // MP: half the gradient volume, half(+) the GPUs
            assert_eq!(get("DP with MP + Cyclic").comm_psi_p, 0.5);
            assert!(
                get("DP with MP + Cyclic").n_gpus
                    <= (get("DP with MP").n_gpus + n as f64) / 2.0 + 1.0
            );
            // ZeRO: volume unchanged, steps collapse
            assert_eq!(
                get("ZeRO-DP + Cyclic").comm_psi_p,
                get("ZeRO-DP").comm_psi_p
            );
            assert_eq!(get("ZeRO-DP + Cyclic").max_comm_steps, 1.0);
        }
    }

    #[test]
    fn degenerate_n_rows_are_pinned() {
        // Every row's max_comm_steps at N = 1, 2, 8.  O(1) rows are
        // uniformly 1.0 at every N; log-N rows are the unclamped log₂N:
        // 0.0 / 1.0 / 3.0.  This pins the fix for the old `.max(1.0)`
        // clamp that hid the N=1 and N=2 distinctions.
        for (n, logn) in [(1usize, 0.0f64), (2, 1.0), (8, 3.0)] {
            let rows = table1_rows(n);
            assert_eq!(rows.len(), 9, "row count at N={n}");
            for r in &rows {
                let expect = match r.implementation {
                    // Log-N rows: synchronized reductions.
                    "Multi-GPU DP" | "DP with MP" | "ZeRO-DP" => logn,
                    // Everything else is O(1) per time step.
                    _ => 1.0,
                };
                assert_eq!(
                    r.max_comm_steps, expect,
                    "{} at N={n}: got {} want {expect}",
                    r.implementation, r.max_comm_steps
                );
            }
        }
        // Degenerate N=1 sanity for the other columns: one micro-batch,
        // one device, nothing to communicate, triangular count collapses.
        let rows = table1_rows(1);
        let get = |name: &str| rows.iter().find(|r| r.implementation == name).unwrap();
        assert_eq!(get("Single-GPU DP").act_mem, 1.0);
        assert_eq!(get("Single-GPU + Cyclic").act_mem, 1.0);
        assert_eq!(get("DP with MP + Cyclic").n_gpus, 1.0);
        assert_eq!(get("Multi-GPU DP").n_gpus, 1.0);
    }

    #[test]
    fn mp_cyclic_gpu_count_is_triangular() {
        assert_eq!(table1_rows(3)[5].n_gpus, 6.0);
        assert_eq!(table1_rows(4)[5].n_gpus, 10.0);
    }

    #[test]
    fn render_contains_all_rows() {
        let s = render_table1(4);
        for name in ["Single-GPU DP", "ZeRO-DP + Cyclic", "PP"] {
            assert!(s.contains(name), "{name} missing:\n{s}");
        }
    }
}
