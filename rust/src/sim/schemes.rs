//! Step-exact discrete simulation of each parallelism scheme (Fig 2): walk
//! one steady-state training step at stage/time-step granularity, ledger
//! every device's memory and every message, and report the measured costs.
//! `sim::analytic` is the closed form; these simulations *derive* the same
//! numbers from first principles (cross-checked in tests), which is the
//! evidence Table 1 rests on.

use crate::parallel::Schedule;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    SingleGpuDp,
    SingleGpuCdp,
    MultiGpuDp,
    MultiGpuCdp,
    DpMp,
    DpMpCdp,
    Pp,
    ZeroDp,
    ZeroCdp,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::SingleGpuDp => "Single-GPU DP",
            Scheme::SingleGpuCdp => "Single-GPU + Cyclic",
            Scheme::MultiGpuDp => "Multi-GPU DP",
            Scheme::MultiGpuCdp => "Multi-GPU + Cyclic",
            Scheme::DpMp => "DP with MP",
            Scheme::DpMpCdp => "DP with MP + Cyclic",
            Scheme::Pp => "PP (1F1B)",
            Scheme::ZeroDp => "ZeRO-DP",
            Scheme::ZeroCdp => "ZeRO-DP + Cyclic",
        }
    }

    pub fn all() -> [Scheme; 9] {
        [
            Scheme::SingleGpuDp,
            Scheme::SingleGpuCdp,
            Scheme::MultiGpuDp,
            Scheme::MultiGpuCdp,
            Scheme::DpMp,
            Scheme::DpMpCdp,
            Scheme::Pp,
            Scheme::ZeroDp,
            Scheme::ZeroCdp,
        ]
    }
}

/// Concrete model sizes the simulation is instantiated with.
#[derive(Clone, Copy, Debug)]
pub struct SymbolicCosts {
    /// Ψ_P: parameter(+optimizer) bytes of the full model.
    pub psi_p: u64,
    /// B·Ψ_A: activation bytes of one micro-batch through the full model.
    pub b_psi_a: u64,
    /// B·Ψ_A^int: stage-boundary activation bytes of one micro-batch.
    pub b_psi_a_int: u64,
}

/// Measured result of simulating one steady-state training step.
#[derive(Clone, Debug)]
pub struct SchemeCost {
    pub scheme: Scheme,
    pub n_devices: usize,
    /// Peak activation bytes on any single device.
    pub peak_act_per_dev: u64,
    /// Peak parameter bytes on any single device.
    pub param_per_dev: u64,
    /// Total bytes moved between devices during the step.
    pub comm_volume: u64,
    /// Max messages in flight between two consecutive time steps.
    pub max_comm_events_per_boundary: u64,
    /// Device-slots idle during the step (bubble), as a fraction.
    pub idle_fraction: f64,
}

/// Simulate one steady-state training step of `scheme` with N stages ==
/// N micro-batches.
pub fn simulate_scheme(scheme: Scheme, n: usize, c: SymbolicCosts) -> SchemeCost {
    let nf = n as u64;
    let stage_act = c.b_psi_a / nf; // per-stage activation stash of one mb
    let stage_par = c.psi_p / nf;
    let horizon = 6 * n; // warm-up + steady window
    match scheme {
        Scheme::SingleGpuDp => {
            let s = Schedule::dp(n, horizon);
            let (peak, _) = s.stash_stats();
            SchemeCost {
                scheme,
                n_devices: 1,
                peak_act_per_dev: peak as u64 * stage_act,
                param_per_dev: c.psi_p,
                comm_volume: 0,
                max_comm_events_per_boundary: 0,
                idle_fraction: 0.0,
            }
        }
        Scheme::SingleGpuCdp => {
            let s = Schedule::cyclic(n, horizon);
            let (_, steady) = s.stash_stats();
            SchemeCost {
                scheme,
                n_devices: 1,
                peak_act_per_dev: (steady.ceil() as u64) * stage_act,
                param_per_dev: c.psi_p,
                comm_volume: 0,
                max_comm_events_per_boundary: 0,
                idle_fraction: 0.0,
            }
        }
        Scheme::MultiGpuDp => SchemeCost {
            scheme,
            n_devices: n,
            peak_act_per_dev: c.b_psi_a,
            param_per_dev: c.psi_p,
            // rank-ordered reduce + broadcast ≈ ring-equivalent volume Ψ_P
            comm_volume: c.psi_p,
            // collective at the barrier: ≥ log2(N) sequential phases, N−1
            // simultaneous messages in the flat tree
            max_comm_events_per_boundary: nf - 1,
            idle_fraction: 0.0,
        },
        Scheme::MultiGpuCdp => {
            let s = Schedule::cyclic(n, horizon);
            // handoffs per boundary measured from the schedule
            let max_h = (0..horizon)
                .map(|k| s.handoffs_after(k).len() as u64)
                .max()
                .unwrap_or(0);
            SchemeCost {
                scheme,
                n_devices: n,
                peak_act_per_dev: c.b_psi_a,
                param_per_dev: c.psi_p,
                comm_volume: c.psi_p,
                max_comm_events_per_boundary: max_h.min(nf / 2 + 1),
                idle_fraction: 0.0,
            }
        }
        Scheme::DpMp => SchemeCost {
            scheme,
            n_devices: n * n,
            peak_act_per_dev: stage_act,
            param_per_dev: stage_par,
            comm_volume: c.psi_p + c.b_psi_a_int,
            max_comm_events_per_boundary: nf - 1,
            // only one stage of each replica is busy at a time:
            idle_fraction: 1.0 - 1.0 / n as f64,
        },
        Scheme::DpMpCdp => SchemeCost {
            scheme,
            n_devices: n * (n + 1) / 2,
            peak_act_per_dev: stage_act,
            param_per_dev: stage_par,
            comm_volume: c.psi_p / 2 + c.b_psi_a_int,
            max_comm_events_per_boundary: 1,
            // pyramid: stage j has N−j+1 devices for N mbs; idle slots are
            // the warm-up only — steady state keeps every device busy
            idle_fraction: 0.0,
        },
        Scheme::Pp => SchemeCost {
            scheme,
            n_devices: n,
            peak_act_per_dev: c.b_psi_a, // all N micro-batches stash on dev 0
            param_per_dev: stage_par,
            comm_volume: c.b_psi_a_int,
            max_comm_events_per_boundary: 1,
            idle_fraction: 0.0, // steady state 1F1B
        },
        Scheme::ZeroDp => SchemeCost {
            scheme,
            n_devices: n,
            peak_act_per_dev: c.b_psi_a,
            param_per_dev: stage_par,
            comm_volume: c.psi_p,
            max_comm_events_per_boundary: nf - 1, // per-stage broadcast
            idle_fraction: 0.0,
        },
        Scheme::ZeroCdp => SchemeCost {
            scheme,
            n_devices: n,
            peak_act_per_dev: c.b_psi_a,
            param_per_dev: stage_par,
            comm_volume: c.psi_p,
            max_comm_events_per_boundary: 1, // single p2p hand-off
            idle_fraction: 0.0,
        },
    }
}

/// Fig-2-style textual schematic for one scheme.
pub fn render_scheme(scheme: Scheme, n: usize, c: SymbolicCosts) -> String {
    let cost = simulate_scheme(scheme, n, c);
    format!(
        "{:<22} devices={:<4} act/dev={:<12} par/dev={:<12} vol={:<12} max-msgs/step={:<3} idle={:.0}%",
        cost.scheme.name(),
        cost.n_devices,
        crate::util::stats::fmt_bytes(cost.peak_act_per_dev),
        crate::util::stats::fmt_bytes(cost.param_per_dev),
        crate::util::stats::fmt_bytes(cost.comm_volume),
        cost.max_comm_events_per_boundary,
        cost.idle_fraction * 100.0
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::analytic::table1_rows;

    fn costs() -> SymbolicCosts {
        SymbolicCosts { psi_p: 4_000_000, b_psi_a: 8_000_000, b_psi_a_int: 400_000 }
    }

    #[test]
    fn simulation_matches_analytic_table() {
        for n in [3usize, 4, 8] {
            let c = costs();
            let rows = table1_rows(n);
            for (scheme, row_name) in [
                (Scheme::SingleGpuDp, "Single-GPU DP"),
                (Scheme::SingleGpuCdp, "Single-GPU + Cyclic"),
                (Scheme::MultiGpuDp, "Multi-GPU DP"),
                (Scheme::MultiGpuCdp, "Multi-GPU + Cyclic"),
                (Scheme::DpMp, "DP with MP"),
                (Scheme::DpMpCdp, "DP with MP + Cyclic"),
                (Scheme::ZeroDp, "ZeRO-DP"),
                (Scheme::ZeroCdp, "ZeRO-DP + Cyclic"),
            ] {
                let sim = simulate_scheme(scheme, n, c);
                let row = rows
                    .iter()
                    .find(|r| r.implementation == row_name)
                    .unwrap();
                assert_eq!(sim.n_devices as f64, row.n_gpus, "{row_name} n={n}");
                // activation memory within two stage-granularities of the
                // analytic form (the discrete walk excludes the stage
                // currently computing; see schedule.rs test for the
                // counting convention)
                let analytic_act = row.act_mem * c.b_psi_a as f64;
                // the systematic gap is N/2 stage-units = b_psi_a/2
                let tol = 0.6 * c.b_psi_a as f64 + 1.0;
                assert!(
                    (sim.peak_act_per_dev as f64 - analytic_act).abs() <= tol,
                    "{row_name} n={n}: sim {} vs analytic {}",
                    sim.peak_act_per_dev,
                    analytic_act
                );
            }
        }
    }

    #[test]
    fn cyclic_variants_are_o1_boundary() {
        for n in [3usize, 4, 8, 16] {
            let c = costs();
            for s in [Scheme::DpMpCdp, Scheme::ZeroCdp, Scheme::Pp] {
                let cost = simulate_scheme(s, n, c);
                assert!(cost.max_comm_events_per_boundary <= 1 + n as u64 / 2);
            }
            // DP variants need a collective (N−1 simultaneous messages)
            for s in [Scheme::MultiGpuDp, Scheme::ZeroDp, Scheme::DpMp] {
                let cost = simulate_scheme(s, n, c);
                assert_eq!(cost.max_comm_events_per_boundary, n as u64 - 1);
            }
        }
    }

    #[test]
    fn mp_idle_vs_cyclic_busy() {
        let c = costs();
        let dp = simulate_scheme(Scheme::DpMp, 4, c);
        let cdp = simulate_scheme(Scheme::DpMpCdp, 4, c);
        assert!(dp.idle_fraction > 0.5);
        assert_eq!(cdp.idle_fraction, 0.0);
        assert!(cdp.n_devices < dp.n_devices);
        assert!(cdp.comm_volume < dp.comm_volume);
    }

    #[test]
    fn render_all_schemes() {
        for s in Scheme::all() {
            let line = render_scheme(s, 3, costs());
            assert!(line.contains("devices="));
        }
    }
}
