//! Discrete-time scheme simulator: reproduces the paper's *analytical*
//! artifacts — Fig 1 (timelines), Fig 2 (per-scheme device/memory/comm
//! schematics) and Table 1 (costs) — by walking the schedules rather than
//! assuming the formulas, then cross-checking against the closed forms.

pub mod analytic;
pub mod schemes;

pub use analytic::{table1_rows, Table1Row};
pub use schemes::{simulate_scheme, Scheme, SchemeCost, SymbolicCosts};
