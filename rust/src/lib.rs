//! # cyclic-dp — Cyclic Data Parallelism
//!
//! Reproduction of *"Cyclic Data Parallelism for Efficient Parallelism of
//! Deep Neural Networks"* (Fournier & Oyallon, 2024) as a three-layer
//! Rust + JAX + Pallas stack.  This crate is the Layer-3 coordinator: it
//! owns schedules, update rules, parameter versioning, the communication
//! fabric, worker lifecycles and all measurement; the numeric compute runs
//! through AOT-compiled HLO artifacts loaded via PJRT (see [`runtime`]).
//!
//! Module map (see DESIGN.md for the full system inventory):
//!
//! - [`util`]      — substrates: JSON, deterministic RNG, binary IO, stats.
//! - [`tensor`]    — host-side tensors (parameter/gradient blobs).
//! - [`cli`]       — argument parsing for the `cdp` binary and examples.
//! - [`runtime`]   — PJRT client, artifact bundles, executable registry.
//! - [`model`]     — bundle manifest model (stages, shapes, arities).
//! - [`data`]      — synthetic datasets, bit-identical with python/compile/datagen.py.
//! - [`parallel`]  — the paper's contribution: schedules + update rules +
//!                   versioned parameter store + gradient buffers.
//! - [`comm`]      — byte-counted channels, ring all-reduce, broadcast.
//! - [`cluster`]   — simulated devices (memory model) and worker threads.
//! - [`coordinator`] — trainers: reference, multi-worker, ZeRO-DP, pipeline.
//! - [`sim`]       — discrete-time scheme simulator (Fig 1, Fig 2, Tab 1).
//! - [`memsim`]    — activation-memory tracking + extrapolation (Fig 4).
//! - [`profile`]   — calibration pass: per-stage costs, fabric probe.
//! - [`plan`]      — auto-planner: search partition × schedule × shard,
//!                   emit a serializable execution [`plan::Plan`].
//! - [`metrics`]   — counters, CSV/JSON emission.
//! - [`trace`]     — structured tracing: ring recorder, CDPTRACE1 JSONL,
//!                   Chrome export, and the paper-claim verifier.
//! - [`testing`]   — property-test mini-framework (no crates.io access).

pub mod cli;
pub mod cluster;
pub mod comm;
pub mod coordinator;
pub mod data;
pub mod memsim;
pub mod metrics;
pub mod model;
pub mod parallel;
pub mod plan;
pub mod profile;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod testing;
pub mod trace;
pub mod util;
