//! Bundle manifest model: the rust-side view of what `python/compile/aot.py`
//! emitted — stage boundaries, parameter shapes, artifact file names, data
//! distribution and optimizer hyper-parameters.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unknown dtype `{other}`"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .arr_field("shape")?
            .iter()
            .map(|v| v.as_usize().context("shape elem"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.str_field("dtype")?)?;
        Ok(Self { shape, dtype })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct StageSpec {
    pub index: usize,
    pub params: Vec<ParamSpec>,
    pub input: IoSpec,
    /// `None` for the loss stage (its "output" is the scalar loss).
    pub output: Option<IoSpec>,
    pub act_bytes: u64,
    pub flops: u64,
    /// artifact kind → file name (fwd, fwdbwd, fwd_loss, predict, sgd)
    pub artifacts: Vec<(String, String)>,
}

impl StageSpec {
    pub fn artifact(&self, kind: &str) -> Option<&str> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, v)| v.as_str())
    }

    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_elems() as u64 * 4
    }
}

#[derive(Clone, Debug)]
pub enum DataSpec {
    Lm { vocab: usize, seq: usize, batch: usize, seed: u64 },
    Class { classes: usize, input_dim: usize, batch: usize, noise: f32, seed: u64 },
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub family: String,
    pub n_stages: usize,
    pub n_microbatches: usize,
    pub lr: f32,
    pub momentum: f32,
    pub data: DataSpec,
    pub target: IoSpec,
    pub stages: Vec<StageSpec>,
    pub total_param_elems: usize,
    pub golden_steps: usize,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(bundle_dir: &Path) -> Result<Self> {
        let path = bundle_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let data_j = j.get("data").context("missing `data`")?;
        let kind = data_j.str_field("kind")?;
        let data = match kind {
            "lm" => DataSpec::Lm {
                vocab: data_j.usize_field("vocab")?,
                seq: data_j.usize_field("seq")?,
                batch: data_j.usize_field("batch")?,
                seed: data_j.f64_field("seed")? as u64,
            },
            "class" => DataSpec::Class {
                classes: data_j.usize_field("classes")?,
                input_dim: data_j.usize_field("input_dim")?,
                batch: data_j.usize_field("batch")?,
                noise: data_j.f64_field("noise")? as f32,
                seed: data_j.f64_field("seed")? as u64,
            },
            other => anyhow::bail!("unknown data kind `{other}`"),
        };

        let mut stages = Vec::new();
        for sj in j.arr_field("stages")? {
            let params = sj
                .arr_field("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.str_field("name")?.to_string(),
                        shape: p
                            .arr_field("shape")?
                            .iter()
                            .map(|v| v.as_usize().context("shape"))
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let output = match sj.get("output") {
                Some(o) if !o.is_null() => Some(IoSpec::from_json(o)?),
                _ => None,
            };
            let artifacts = match sj.get("artifacts") {
                Some(Json::Obj(m)) => m
                    .iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                    .collect(),
                _ => anyhow::bail!("stage missing artifacts"),
            };
            stages.push(StageSpec {
                index: sj.usize_field("index")?,
                params,
                input: IoSpec::from_json(sj.get("input").context("input")?)?,
                output,
                act_bytes: sj.f64_field("act_bytes")? as u64,
                flops: sj.f64_field("flops")? as u64,
                artifacts,
            });
        }

        Ok(Manifest {
            name: j.str_field("name")?.to_string(),
            family: j.str_field("family")?.to_string(),
            n_stages: j.usize_field("n_stages")?,
            n_microbatches: j.usize_field("n_microbatches")?,
            lr: j.f64_field("lr")? as f32,
            momentum: j.f64_field("momentum")? as f32,
            data,
            target: IoSpec::from_json(j.get("target").context("target")?)?,
            stages,
            total_param_elems: j.usize_field("total_param_elems")?,
            golden_steps: j.get("golden_steps").and_then(Json::as_usize).unwrap_or(0),
            dir: bundle_dir.to_path_buf(),
        })
    }

    pub fn params_bin(&self) -> PathBuf {
        self.dir.join("params.bin")
    }

    pub fn artifact_path(&self, stage: usize, kind: &str) -> Result<PathBuf> {
        let name = self.stages[stage]
            .artifact(kind)
            .with_context(|| format!("stage {stage} has no `{kind}` artifact"))?;
        Ok(self.dir.join(name))
    }

    /// Golden losses per rule, if the bundle ships them.
    pub fn load_golden(&self) -> Result<Option<Vec<(String, Vec<f64>)>>> {
        let p = self.dir.join("golden.json");
        if !p.exists() {
            return Ok(None);
        }
        let j = Json::parse(&std::fs::read_to_string(&p)?)
            .map_err(|e| anyhow::anyhow!("{p:?}: {e}"))?;
        let rules = match j.get("rules") {
            Some(Json::Obj(m)) => m,
            _ => anyhow::bail!("golden.json missing rules"),
        };
        let mut out = Vec::new();
        for (rule, losses) in rules {
            let xs = losses
                .as_arr()
                .context("losses array")?
                .iter()
                .map(|v| v.as_f64().context("loss"))
                .collect::<Result<Vec<_>>>()?;
            out.push((rule.clone(), xs));
        }
        Ok(Some(out))
    }

    /// Paper notation Ψ_P: parameter bytes of the entire model.
    pub fn psi_p_bytes(&self) -> u64 {
        self.total_param_elems as u64 * 4
    }

    /// Paper notation B·Ψ_A: activation bytes of one micro-batch across
    /// all stages.
    pub fn b_psi_a_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.act_bytes).sum()
    }
}

/// Default artifacts root: $CDP_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("CDP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> Option<PathBuf> {
        let d = artifacts_root().join("tiny");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_tiny_manifest() {
        let Some(dir) = tiny_dir() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_stages, 4);
        assert_eq!(m.n_microbatches, 4);
        assert_eq!(m.stages.len(), 4);
        assert_eq!(m.stages[0].input.dtype, DType::I32);
        assert!(m.stages[3].output.is_none());
        assert!(m.stages[3].artifact("fwdbwd").is_some());
        assert!(m.stages[0].artifact("fwd").is_some());
        assert!(m.artifact_path(0, "fwd").unwrap().exists());
        assert_eq!(
            m.total_param_elems,
            m.stages.iter().map(|s| s.param_elems()).sum::<usize>()
        );
        assert!(m.psi_p_bytes() > 0 && m.b_psi_a_bytes() > 0);
    }

    #[test]
    fn params_bin_matches_manifest_len() {
        let Some(dir) = tiny_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let raw = crate::util::binio::read_f32_file(&m.params_bin()).unwrap();
        assert_eq!(raw.len(), m.total_param_elems);
    }
}
