//! Crate-wide structured tracing: one event stream for everything the
//! bespoke instrumentation used to measure separately.
//!
//! * [`event`] — the flat [`TraceEvent`] record, its kind vocabulary,
//!   and the versioned (`CDPTRACE1`) JSONL wire format with a tolerant
//!   line-oriented parser (truncation/garbage/unknown-kind lines are
//!   skipped and counted, never fatal).
//! * [`recorder`] — the process-global ring recorder: one relaxed
//!   atomic load when disabled, zero steady-state allocation when
//!   enabled (the contract `benches/hotpath.rs` asserts and records as
//!   `trace_disabled_overhead`).
//! * [`analyze`] — `cdp trace` back-end: summaries, Chrome
//!   trace-event export, and machine-checked verification of the
//!   paper's constant-memory and balanced-communication claims.
//!
//! Producers: all four coordinators (step/fwd/bwd/sgd/loss/checkpoint
//! lifecycles and activation stash accounting), `comm` (every
//! `CommStats::mark` forwards here, making the legacy timeline an
//! adapter), `comm::transport` (frame send/recv and reconnects),
//! `runtime::native` (kernel spans behind [`recorder::set_kernels`]),
//! and the `cdp` CLI (`--trace`).  See `rust/DESIGN-OBS.md`.

pub mod analyze;
pub mod event;
pub mod recorder;

pub use analyze::{
    render_summary, render_verify, summarize, to_chrome, verify, Expect, Summary, VerifyOpts,
    VerifyReport,
};
pub use event::{
    parse_jsonl, parse_jsonl_file, parse_jsonl_reader, render_loss_line, to_jsonl, write_jsonl,
    Fields, ParsedTrace, TraceEvent, TraceKind, TRACE_MAGIC,
};
pub use recorder::{
    capture, drain, enable, enabled, instant, kernel_end, kernel_start, kernels_enabled, loss,
    set_kernels, span, start,
};
