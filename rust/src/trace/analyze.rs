//! Offline analysis over a parsed trace: human summaries, Chrome
//! trace-event export, and — the teeth — machine-checked verification
//! of the paper's two timeline claims:
//!
//! 1. **Constant activation memory**: the peak of live activation
//!    bytes, reconstructed per worker from `act_alloc`/`act_free`
//!    events, is the same every step (max/min per-step peak bounded by
//!    a small factor).  A schedule that stashes more activations as
//!    the run proceeds — or leaks — fails.
//! 2. **Balanced gradient communication**: slicing each worker's step
//!    into the intervals delimited by its backward-stage completions,
//!    the gradient bytes sent per interval have bounded peak-to-mean
//!    ratio for the eager cyclic rules.  The barrier baseline sends
//!    everything in the final interval, so its ratio is the interval
//!    count — far over the bound — and `--expect spike` turns that
//!    demonstrated failure into a passing check.

use std::collections::BTreeMap;

use super::event::{TraceEvent, TraceKind};

/// Aggregate per-stage span time for one compute kind.
#[derive(Clone, Copy, Debug, Default)]
pub struct KindTime {
    /// Summed span duration, ns.
    pub dur_ns: u64,
    /// Number of spans/instants.
    pub count: u64,
}

/// Per-stage fwd/bwd/sgd breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    /// Forward spans on this stage.
    pub fwd: KindTime,
    /// Backward spans/instants on this stage.
    pub bwd: KindTime,
    /// Optimizer spans on this stage.
    pub sgd: KindTime,
    /// Kernel spans on this stage (when the kernel knob was on).
    pub kernel: KindTime,
}

/// What `cdp trace summarize` reports.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Total events analyzed.
    pub events: usize,
    /// Wall-clock span covered, ns (max end − min start).
    pub wall_ns: u64,
    /// Count + summed duration per event kind, keyed by wire name.
    pub per_kind: BTreeMap<&'static str, KindTime>,
    /// fwd/bwd/sgd breakdown per stage.
    pub per_stage: BTreeMap<u32, StageTimes>,
    /// Fraction of gradient sends that depart before the last backward
    /// completes — the comm/compute overlap the cyclic rules exist to
    /// create.  `None` when the trace has no sends or no backwards.
    pub overlap_fraction: Option<f64>,
    /// Peak live activation bytes overall.
    pub peak_live_bytes: u64,
    /// Peak live activation bytes per wall-clock bucket.
    pub live_buckets: Vec<u64>,
}

fn bucket_of(ns: u64, t0: u64, span: u64, buckets: usize) -> usize {
    if span == 0 {
        return 0;
    }
    (((ns - t0) as u128 * buckets as u128 / (span as u128 + 1)) as usize).min(buckets - 1)
}

/// Summarize a trace into [`Summary`]; `buckets` controls the
/// wall-clock resolution of the live-activation curve.
pub fn summarize(events: &[TraceEvent], buckets: usize) -> Summary {
    let buckets = buckets.max(1);
    let mut s = Summary { events: events.len(), ..Summary::default() };
    if events.is_empty() {
        s.live_buckets = vec![0; buckets];
        return s;
    }
    let t0 = events.iter().map(|e| e.ns).min().unwrap_or(0);
    let t1 = events.iter().map(TraceEvent::end_ns).max().unwrap_or(t0);
    s.wall_ns = t1 - t0;

    for ev in events {
        let kt = s.per_kind.entry(ev.kind.name()).or_default();
        kt.count += 1;
        kt.dur_ns += ev.dur_ns;
        let slot = match ev.kind {
            TraceKind::Fwd => Some(0),
            TraceKind::Bwd => Some(1),
            TraceKind::Sgd => Some(2),
            TraceKind::Kernel => Some(3),
            _ => None,
        };
        if let Some(slot) = slot {
            let st = s.per_stage.entry(ev.stage).or_default();
            let kt = match slot {
                0 => &mut st.fwd,
                1 => &mut st.bwd,
                2 => &mut st.sgd,
                _ => &mut st.kernel,
            };
            kt.count += 1;
            kt.dur_ns += ev.dur_ns;
        }
    }

    // Overlap is judged within each (worker, step): a send overlaps
    // compute iff it departs before that worker's last backward of the
    // same step completes.
    let mut last_bwd: BTreeMap<(u32, u64), u64> = BTreeMap::new();
    for e in events.iter().filter(|e| e.kind == TraceKind::Bwd) {
        let end = last_bwd.entry((e.worker, e.step)).or_insert(0);
        *end = (*end).max(e.end_ns());
    }
    let (mut sends, mut overlapped) = (0u64, 0u64);
    for e in events.iter().filter(|e| e.kind == TraceKind::GradSend) {
        sends += 1;
        if last_bwd.get(&(e.worker, e.step)).is_some_and(|&end| e.ns <= end) {
            overlapped += 1;
        }
    }
    s.overlap_fraction = (sends > 0 && !last_bwd.is_empty())
        .then(|| overlapped as f64 / sends as f64);

    // Live-activation sweep: signed deltas in time order, peak per bucket.
    let mut deltas: Vec<(u64, i64)> = events
        .iter()
        .filter_map(|e| match e.kind {
            TraceKind::ActAlloc => Some((e.ns, e.bytes as i64)),
            TraceKind::ActFree => Some((e.end_ns(), -(e.bytes as i64))),
            _ => None,
        })
        .collect();
    deltas.sort_unstable();
    let mut live = 0i64;
    let mut peaks = vec![0u64; buckets];
    let mut cursor = 0usize;
    for (ns, d) in deltas {
        let b = bucket_of(ns, t0, s.wall_ns, buckets);
        // A bucket with no events holds whatever was live entering it.
        for p in peaks.iter_mut().take(b).skip(cursor + 1) {
            *p = (*p).max(live.max(0) as u64);
        }
        live += d;
        peaks[b] = peaks[b].max(live.max(0) as u64);
        s.peak_live_bytes = s.peak_live_bytes.max(live.max(0) as u64);
        cursor = b;
    }
    s.live_buckets = peaks;
    s
}

fn fmt_ms(ns: u64) -> String {
    format!("{:.3}ms", ns as f64 / 1e6)
}

fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1}MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}KiB", b as f64 / 1024.0)
    } else {
        format!("{b}B")
    }
}

/// Render a [`Summary`] as the text `cdp trace summarize` prints.
pub fn render_summary(s: &Summary) -> String {
    let mut out = String::new();
    out.push_str(&format!("events {}  wall {}\n", s.events, fmt_ms(s.wall_ns)));
    out.push_str("per-kind:\n");
    for (name, kt) in &s.per_kind {
        out.push_str(&format!("  {name:<12} n={:<7} dur={}\n", kt.count, fmt_ms(kt.dur_ns)));
    }
    if !s.per_stage.is_empty() {
        out.push_str("per-stage (dur/count):\n");
        out.push_str(&format!(
            "  {:<6} {:<18} {:<18} {:<18} {:<18}\n",
            "stage", "fwd", "bwd", "sgd", "kernel"
        ));
        for (stage, st) in &s.per_stage {
            let cell = |kt: &KindTime| format!("{}/{}", fmt_ms(kt.dur_ns), kt.count);
            out.push_str(&format!(
                "  {:<6} {:<18} {:<18} {:<18} {:<18}\n",
                stage,
                cell(&st.fwd),
                cell(&st.bwd),
                cell(&st.sgd),
                cell(&st.kernel),
            ));
        }
    }
    match s.overlap_fraction {
        Some(f) => out.push_str(&format!(
            "overlap: {:.0}% of grad sends depart before the last backward completes\n",
            f * 100.0
        )),
        None => out.push_str("overlap: n/a (no grad sends or no backward events)\n"),
    }
    out.push_str(&format!(
        "peak live activations: {}\nlive-bytes buckets: [{}]\n",
        fmt_bytes(s.peak_live_bytes),
        s.live_buckets.iter().map(|b| fmt_bytes(*b)).collect::<Vec<_>>().join(", ")
    ));
    out
}

/// Export a trace as Chrome trace-event-format JSON (load in
/// `chrome://tracing` or Perfetto).  `pid` is the worker, `tid` the
/// stage; spans become `ph:"X"`, instants `ph:"i"`.
pub fn to_chrome(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(64 + events.len() * 128);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ts = ev.ns as f64 / 1e3;
        let mut args = format!("\"step\":{}", ev.step);
        if ev.version > 0 {
            args.push_str(&format!(",\"ver\":{}", ev.version));
        }
        if ev.bytes > 0 {
            args.push_str(&format!(",\"bytes\":{}", ev.bytes));
        }
        if ev.bits > 0 {
            args.push_str(&format!(",\"bits\":\"{:016x}\"", ev.bits));
        }
        if ev.dur_ns > 0 {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{},\"args\":{{{args}}}}}",
                ev.kind.name(),
                ts,
                ev.dur_ns as f64 / 1e3,
                ev.worker,
                ev.stage,
            ));
        } else {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\"pid\":{},\"tid\":{},\"args\":{{{args}}}}}",
                ev.kind.name(),
                ts,
                ev.worker,
                ev.stage,
            ));
        }
    }
    out.push_str("]}");
    out
}

/// Which comm shape a verify run expects the trace to exhibit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Expect {
    /// Cyclic rules: gradient bytes must be balanced over the step.
    Balanced,
    /// Barrier baseline: the balance check must *fail* (and gradient
    /// sends must exist) — proving the invariant has teeth.
    Spike,
}

/// Knobs for [`verify`].
#[derive(Clone, Copy, Debug)]
pub struct VerifyOpts {
    /// Max allowed per-interval gradient-bytes peak-to-mean ratio.
    pub balance_ratio: f64,
    /// Max allowed (max step peak)/(min step peak) of live activation
    /// bytes per worker.
    pub mem_factor: f64,
    /// Expected comm shape.
    pub expect: Expect,
}

impl Default for VerifyOpts {
    fn default() -> Self {
        VerifyOpts { balance_ratio: 2.5, mem_factor: 1.5, expect: Expect::Balanced }
    }
}

/// Constant-memory check result.
#[derive(Clone, Copy, Debug)]
pub struct MemCheck {
    /// False when no worker had activation events spanning ≥ 2 steps
    /// (the check is then vacuously passing and reported as skipped).
    pub evaluated: bool,
    /// Largest per-step live-bytes peak seen on the worst worker.
    pub max_step_peak: u64,
    /// Smallest per-step live-bytes peak on that same worker.
    pub min_step_peak: u64,
    /// Worst per-worker max/min per-step-peak ratio.
    pub ratio: f64,
    /// The bound the ratio was held to.
    pub factor: f64,
    /// Whether the check passed.
    pub ok: bool,
}

/// Balanced-communication check result.
#[derive(Clone, Copy, Debug)]
pub struct BalanceCheck {
    /// False when no (worker, step) group had ≥ 2 backward completions
    /// and ≥ 1 gradient send.
    pub evaluated: bool,
    /// Number of (worker, step) groups measured.
    pub groups: usize,
    /// Worst per-interval bytes peak-to-mean ratio across groups.
    pub max_ratio: f64,
    /// The bound a balanced trace must stay under.
    pub threshold: f64,
    /// Whether the measured traffic was balanced (ratio ≤ threshold).
    pub balanced: bool,
}

/// What `cdp trace verify` reports.
#[derive(Clone, Copy, Debug)]
pub struct VerifyReport {
    /// Constant-memory invariant result.
    pub mem: MemCheck,
    /// Balanced-communication invariant result.
    pub balance: BalanceCheck,
    /// The expectation the report was judged against.
    pub expect: Expect,
    /// Overall verdict: memory ok, and the balance shape matched
    /// `expect`.
    pub ok: bool,
}

fn check_memory(events: &[TraceEvent], factor: f64) -> MemCheck {
    // Per worker: sweep alloc/free in time order, track the live-bytes
    // peak attained within each step (keyed by the events' step field).
    let mut per_worker: BTreeMap<u32, Vec<(u64, u64, i64)>> = BTreeMap::new();
    for ev in events {
        match ev.kind {
            TraceKind::ActAlloc => per_worker
                .entry(ev.worker)
                .or_default()
                .push((ev.ns, ev.step, ev.bytes as i64)),
            TraceKind::ActFree => per_worker
                .entry(ev.worker)
                .or_default()
                .push((ev.end_ns(), ev.step, -(ev.bytes as i64))),
            _ => {}
        }
    }
    let mut out = MemCheck {
        evaluated: false,
        max_step_peak: 0,
        min_step_peak: 0,
        ratio: 1.0,
        factor,
        ok: true,
    };
    for deltas in per_worker.values_mut() {
        deltas.sort_unstable();
        let mut live = 0i64;
        let mut step_peak: BTreeMap<u64, u64> = BTreeMap::new();
        for &(_, step, d) in deltas.iter() {
            live += d;
            let p = step_peak.entry(step).or_insert(0);
            *p = (*p).max(live.max(0) as u64);
        }
        if step_peak.len() < 2 {
            continue;
        }
        let max = step_peak.values().copied().max().unwrap_or(0);
        let min = step_peak.values().copied().min().unwrap_or(0);
        let ratio = if min == 0 { f64::INFINITY } else { max as f64 / min as f64 };
        if !out.evaluated || ratio > out.ratio {
            out.evaluated = true;
            out.max_step_peak = max;
            out.min_step_peak = min;
            out.ratio = ratio;
        }
    }
    out.ok = !out.evaluated || out.ratio <= factor;
    out
}

fn check_balance(events: &[TraceEvent], threshold: f64) -> BalanceCheck {
    // Per (worker, step): interval boundaries are the backward-stage
    // completion times; each gradient send's bytes land in the interval
    // containing its departure.  K backwards ⇒ K+1 intervals (the last
    // is the after-all-backwards tail where the barrier baseline dumps
    // everything).
    let mut groups: BTreeMap<(u32, u64), (Vec<u64>, Vec<(u64, u64)>)> = BTreeMap::new();
    for ev in events {
        match ev.kind {
            TraceKind::Bwd => groups
                .entry((ev.worker, ev.step))
                .or_default()
                .0
                .push(ev.end_ns()),
            TraceKind::GradSend => groups
                .entry((ev.worker, ev.step))
                .or_default()
                .1
                .push((ev.ns, ev.bytes)),
            _ => {}
        }
    }
    let mut out = BalanceCheck {
        evaluated: false,
        groups: 0,
        max_ratio: 0.0,
        threshold,
        balanced: true,
    };
    for (ends, sends) in groups.values_mut() {
        if ends.len() < 2 || sends.is_empty() {
            continue;
        }
        ends.sort_unstable();
        let mut interval_bytes = vec![0u64; ends.len() + 1];
        let mut total = 0u64;
        for &(ns, bytes) in sends.iter() {
            let idx = ends.partition_point(|&e| e < ns);
            interval_bytes[idx] += bytes;
            total += bytes;
        }
        if total == 0 {
            continue;
        }
        let peak = interval_bytes.iter().copied().max().unwrap_or(0);
        let mean = total as f64 / interval_bytes.len() as f64;
        let ratio = peak as f64 / mean;
        out.evaluated = true;
        out.groups += 1;
        out.max_ratio = out.max_ratio.max(ratio);
    }
    out.balanced = !out.evaluated || out.max_ratio <= threshold;
    out
}

/// Run both invariant checks over a trace and judge them against the
/// expectation in `opts`.
pub fn verify(events: &[TraceEvent], opts: &VerifyOpts) -> VerifyReport {
    let mem = check_memory(events, opts.mem_factor);
    let balance = check_balance(events, opts.balance_ratio);
    let shape_ok = match opts.expect {
        Expect::Balanced => balance.balanced,
        // A spike must be *demonstrated*: gradient sends measured and
        // over the bound.  A trace with no sends proves nothing.
        Expect::Spike => balance.evaluated && !balance.balanced,
    };
    VerifyReport { mem, balance, expect: opts.expect, ok: mem.ok && shape_ok }
}

/// Render a [`VerifyReport`] as the text `cdp trace verify` prints.
pub fn render_verify(r: &VerifyReport) -> String {
    let mut out = String::new();
    if r.mem.evaluated {
        out.push_str(&format!(
            "memory   {}  per-step live-bytes peak max/min = {}/{} (ratio {:.2} ≤ {:.2})\n",
            if r.mem.ok { "PASS" } else { "FAIL" },
            fmt_bytes(r.mem.max_step_peak),
            fmt_bytes(r.mem.min_step_peak),
            r.mem.ratio,
            r.mem.factor,
        ));
    } else {
        out.push_str("memory   SKIP  (<2 steps with activation events)\n");
    }
    if r.balance.evaluated {
        let shape = if r.balance.balanced { "balanced" } else { "spike" };
        let pass = match r.expect {
            Expect::Balanced => r.balance.balanced,
            Expect::Spike => !r.balance.balanced,
        };
        out.push_str(&format!(
            "comm     {}  {} groups, per-interval grad-bytes peak/mean worst {:.2} (bound {:.2}) → {} (expected {})\n",
            if pass { "PASS" } else { "FAIL" },
            r.balance.groups,
            r.balance.max_ratio,
            r.balance.threshold,
            shape,
            match r.expect {
                Expect::Balanced => "balanced",
                Expect::Spike => "spike",
            },
        ));
    } else {
        let pass = r.expect == Expect::Balanced;
        out.push_str(&format!(
            "comm     {}  (no gradient sends in trace{})\n",
            if pass { "SKIP" } else { "FAIL" },
            if pass { "" } else { "; a spike cannot be demonstrated" },
        ));
    }
    out.push_str(&format!("verify   {}\n", if r.ok { "PASS" } else { "FAIL" }));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::Fields;

    fn ev(kind: TraceKind, ns: u64, worker: u32, stage: u32, step: u64, bytes: u64) -> TraceEvent {
        TraceEvent::new(
            kind,
            ns,
            0,
            Fields { worker, stage, step, bytes, ..Fields::default() },
        )
    }

    /// One worker, `steps` steps, `stages` stages: eager sends right
    /// after each backward (cyclic) or one big send after all of them
    /// (barrier).  Activations alloc on fwd, free on bwd.
    fn synthetic(steps: u64, stages: u32, eager: bool) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        let mut t = 0u64;
        for step in 0..steps {
            for j in 0..stages {
                t += 10;
                out.push(ev(TraceKind::ActAlloc, t, 0, j, step, 1024));
            }
            for j in (0..stages).rev() {
                t += 10;
                out.push(ev(TraceKind::Bwd, t, 0, j, step, 0));
                t += 1;
                out.push(ev(TraceKind::ActFree, t, 0, j, step, 1024));
                if eager {
                    t += 1;
                    out.push(ev(TraceKind::GradSend, t, 0, j, step, 4096));
                }
            }
            if !eager {
                t += 5;
                out.push(ev(TraceKind::GradSend, t, 0, 0, step, 4096 * stages as u64));
            }
        }
        out
    }

    #[test]
    fn eager_trace_is_balanced_and_constant_memory() {
        let evs = synthetic(3, 4, true);
        let r = verify(&evs, &VerifyOpts::default());
        assert!(r.mem.evaluated && r.mem.ok, "{:?}", r.mem);
        assert!((r.mem.ratio - 1.0).abs() < 1e-9);
        assert!(r.balance.evaluated && r.balance.balanced, "{:?}", r.balance);
        assert!(r.ok);
    }

    #[test]
    fn barrier_trace_spikes_and_expect_spike_passes() {
        let evs = synthetic(3, 4, false);
        let balanced = verify(&evs, &VerifyOpts::default());
        assert!(balanced.mem.ok, "barrier still has constant memory");
        assert!(!balanced.ok, "barrier must fail the balance check");
        assert!(balanced.balance.max_ratio > 2.5, "{}", balanced.balance.max_ratio);
        let spike = verify(
            &evs,
            &VerifyOpts { expect: Expect::Spike, ..VerifyOpts::default() },
        );
        assert!(spike.ok, "expect=spike turns the failure into the check");
    }

    #[test]
    fn growing_stash_fails_memory_check() {
        // A leaky schedule: step t allocates t+1 stashes and frees none.
        let mut evs = Vec::new();
        let mut t = 0;
        for step in 0..3u64 {
            for _ in 0..=step {
                t += 10;
                evs.push(ev(TraceKind::ActAlloc, t, 0, 0, step, 1 << 10));
            }
        }
        let r = verify(&evs, &VerifyOpts::default());
        assert!(r.mem.evaluated && !r.mem.ok, "{:?}", r.mem);
        assert!(!r.ok);
    }

    #[test]
    fn no_send_trace_skips_balance_but_cannot_claim_spike() {
        let evs: Vec<TraceEvent> = synthetic(2, 3, true)
            .into_iter()
            .filter(|e| e.kind != TraceKind::GradSend)
            .collect();
        assert!(verify(&evs, &VerifyOpts::default()).ok);
        let spike = verify(
            &evs,
            &VerifyOpts { expect: Expect::Spike, ..VerifyOpts::default() },
        );
        assert!(!spike.ok);
    }

    #[test]
    fn summary_reports_overlap_and_live_curve() {
        let evs = synthetic(2, 3, true);
        let s = summarize(&evs, 8);
        assert_eq!(s.events, evs.len());
        // The final stage's send trails its own backward; the rest overlap.
        assert!(s.overlap_fraction.unwrap() > 0.5, "{:?}", s.overlap_fraction);
        assert_eq!(s.peak_live_bytes, 3 * 1024);
        assert_eq!(s.live_buckets.len(), 8);
        assert_eq!(s.live_buckets.iter().copied().max(), Some(3 * 1024));
        let text = render_summary(&s);
        assert!(text.contains("peak live activations"));
        let barrier = summarize(&synthetic(2, 3, false), 8);
        assert_eq!(barrier.overlap_fraction, Some(0.0));
    }

    #[test]
    fn chrome_export_is_valid_json_with_one_record_per_event() {
        let evs = synthetic(1, 2, true);
        let text = to_chrome(&evs);
        let j = crate::util::json::Json::parse(&text).expect("chrome export parses");
        let arr = j.get("traceEvents").expect("traceEvents");
        match arr {
            crate::util::json::Json::Arr(items) => assert_eq!(items.len(), evs.len()),
            other => panic!("traceEvents not an array: {other:?}"),
        }
    }
}
