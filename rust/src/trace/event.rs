//! The trace event schema and its versioned JSONL wire format.
//!
//! One [`TraceEvent`] is a flat, `Copy`, fixed-size record — no strings,
//! no heap — so the recorder can buffer them in a preallocated ring
//! without allocating (`trace::recorder`).  On disk a trace is JSONL:
//! a header line `{"v":"CDPTRACE1",...}` followed by one event object
//! per line.  The parser is synchronous, line-oriented and *tolerant*
//! (the codex-wrapper `ThreadEvent` contract): a truncated final line,
//! interleaved garbage, CRLF endings, or an event kind from a future
//! format version are all skipped and counted, never an error — only
//! I/O failures are.
//!
//! Versioning rule: the magic (`CDPTRACE1`) names the *line grammar*.
//! Adding event kinds or fields is backward-compatible (old parsers
//! skip-with-count unknown kinds and default missing fields to zero);
//! only a change to the line grammar itself bumps the magic.
//!
//! Numbers ride as JSON numbers (f64-exact for any `ns` below 2⁵³ ≈ 104
//! days of run time); the one field that genuinely needs all 64 bits —
//! `bits`, which carries f64 loss bit patterns — rides as a hex string.

use std::io::BufRead;
use std::path::Path;

use crate::util::json::Json;

/// Trace format magic, written as the `v` field of the header line.
pub const TRACE_MAGIC: &str = "CDPTRACE1";

/// What a [`TraceEvent`] records.  Instants have `dur_ns == 0`; spans
/// carry their measured duration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// A training step started on a worker.
    StepBegin,
    /// A training step committed on a worker.
    StepEnd,
    /// One stage's forward pass (span).
    Fwd,
    /// One stage's backward pass (span from the coordinators, instant
    /// when adapted from a legacy `CommStats` mark).
    Bwd,
    /// One stage's SGD-momentum update (span).
    Sgd,
    /// Gradient data left a worker (bucket partial, shard, or the
    /// barrier baseline's whole-model send).
    GradSend,
    /// Gradient data arrived at its reduction owner.
    GradRecv,
    /// Updated parameters left the optimizer owner.
    ParamSend,
    /// Updated parameters arrived at a worker.
    ParamRecv,
    /// An activation stash was allocated (`bytes` = its size).
    ActAlloc,
    /// An activation stash was freed (`bytes` = its size).
    ActFree,
    /// A per-step scalar loss report; `bits` holds `f64::to_bits`.
    Loss,
    /// A checkpoint was captured/persisted.
    CkptSave,
    /// Training resumed from a checkpoint.
    CkptResume,
    /// A wire edge (re)connected after a dial.
    Reconnect,
    /// A scripted worker kill took effect.
    Kill,
    /// A liveness heartbeat exchange.
    Heartbeat,
    /// A framed message left on a socket edge (`bytes` = frame size).
    FrameSend,
    /// A framed message arrived on a socket edge (`bytes` = frame size).
    FrameRecv,
    /// A kernel-level timing span (`bits` = opcode: 0 fwd, 1 bwd, 2 sgd).
    Kernel,
}

impl TraceKind {
    /// Every kind, for round-trip tests and exhaustive tooling.
    pub const ALL: [TraceKind; 20] = [
        TraceKind::StepBegin,
        TraceKind::StepEnd,
        TraceKind::Fwd,
        TraceKind::Bwd,
        TraceKind::Sgd,
        TraceKind::GradSend,
        TraceKind::GradRecv,
        TraceKind::ParamSend,
        TraceKind::ParamRecv,
        TraceKind::ActAlloc,
        TraceKind::ActFree,
        TraceKind::Loss,
        TraceKind::CkptSave,
        TraceKind::CkptResume,
        TraceKind::Reconnect,
        TraceKind::Kill,
        TraceKind::Heartbeat,
        TraceKind::FrameSend,
        TraceKind::FrameRecv,
        TraceKind::Kernel,
    ];

    /// Canonical wire name (the JSONL `k` field).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::StepBegin => "step_begin",
            TraceKind::StepEnd => "step_end",
            TraceKind::Fwd => "fwd",
            TraceKind::Bwd => "bwd",
            TraceKind::Sgd => "sgd",
            TraceKind::GradSend => "grad_send",
            TraceKind::GradRecv => "grad_recv",
            TraceKind::ParamSend => "param_send",
            TraceKind::ParamRecv => "param_recv",
            TraceKind::ActAlloc => "act_alloc",
            TraceKind::ActFree => "act_free",
            TraceKind::Loss => "loss",
            TraceKind::CkptSave => "ckpt_save",
            TraceKind::CkptResume => "ckpt_resume",
            TraceKind::Reconnect => "reconnect",
            TraceKind::Kill => "kill",
            TraceKind::Heartbeat => "heartbeat",
            TraceKind::FrameSend => "frame_send",
            TraceKind::FrameRecv => "frame_recv",
            TraceKind::Kernel => "kernel",
        }
    }

    /// Inverse of [`TraceKind::name`]; `None` for unknown (future) kinds.
    pub fn parse_name(s: &str) -> Option<TraceKind> {
        TraceKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// The non-timing payload of an event, grouped so recording call sites
/// stay short (`..Default::default()` for the fields they don't carry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fields {
    /// Worker id (micro-batch lane on in-process trainers).
    pub worker: u32,
    /// Pipeline stage (or peer id for wire frame events).
    pub stage: u32,
    /// Training step t.
    pub step: u64,
    /// θ-version id the operation ran at (0 when not applicable).
    pub version: u64,
    /// Byte count moved/allocated (0 when not applicable).
    pub bytes: u64,
    /// Kind-specific bit payload: `f64::to_bits` of the loss for
    /// [`TraceKind::Loss`], the opcode for [`TraceKind::Kernel`].
    pub bits: u64,
}

/// One trace event: a timestamp (+ optional duration) and [`Fields`].
/// `ns` is relative to the recorder's enable instant; events from
/// different OS processes are in different clock domains (the launcher's
/// merge keeps them separated by worker id).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Start timestamp, ns since the recorder was enabled.
    pub ns: u64,
    /// Span duration in ns; 0 for instants.
    pub dur_ns: u64,
    /// What happened.
    pub kind: TraceKind,
    /// Worker id.
    pub worker: u32,
    /// Stage (or peer for frame events).
    pub stage: u32,
    /// Training step.
    pub step: u64,
    /// θ-version id (0 when not applicable).
    pub version: u64,
    /// Bytes moved/allocated (0 when not applicable).
    pub bytes: u64,
    /// Kind-specific bit payload (see [`Fields::bits`]).
    pub bits: u64,
}

impl TraceEvent {
    /// Build an event from its parts (timestamps supplied by the caller).
    pub fn new(kind: TraceKind, ns: u64, dur_ns: u64, f: Fields) -> Self {
        TraceEvent {
            ns,
            dur_ns,
            kind,
            worker: f.worker,
            stage: f.stage,
            step: f.step,
            version: f.version,
            bytes: f.bytes,
            bits: f.bits,
        }
    }

    /// A [`TraceKind::Loss`] event (no timestamp — the recorder stamps
    /// its own copy when recording is enabled).
    pub fn loss(worker: usize, step: u64, loss: f64) -> Self {
        TraceEvent::new(
            TraceKind::Loss,
            0,
            0,
            Fields {
                worker: worker as u32,
                step,
                bits: loss.to_bits(),
                ..Fields::default()
            },
        )
    }

    /// The loss value a [`TraceKind::Loss`] event carries.
    pub fn loss_value(&self) -> Option<f64> {
        (self.kind == TraceKind::Loss).then(|| f64::from_bits(self.bits))
    }

    /// One JSONL line (no trailing newline).  Zero-valued optional
    /// fields (`dur`, `ver`, `b`, `bits`) are omitted; the parser
    /// defaults them back to zero.
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(96);
        s.push_str("{\"k\":\"");
        s.push_str(self.kind.name());
        s.push_str("\",\"ns\":");
        s.push_str(&self.ns.to_string());
        if self.dur_ns > 0 {
            s.push_str(",\"dur\":");
            s.push_str(&self.dur_ns.to_string());
        }
        s.push_str(",\"w\":");
        s.push_str(&self.worker.to_string());
        s.push_str(",\"st\":");
        s.push_str(&self.stage.to_string());
        s.push_str(",\"step\":");
        s.push_str(&self.step.to_string());
        if self.version > 0 {
            s.push_str(",\"ver\":");
            s.push_str(&self.version.to_string());
        }
        if self.bytes > 0 {
            s.push_str(",\"b\":");
            s.push_str(&self.bytes.to_string());
        }
        if self.bits > 0 {
            s.push_str(",\"bits\":\"");
            s.push_str(&format!("{:016x}", self.bits));
            s.push('"');
        }
        s.push('}');
        s
    }

    /// Decode one parsed JSONL object; `None` when it is not a
    /// recognizable event (missing/unknown `k`, non-object, bad `bits`).
    pub fn from_json(j: &Json) -> Option<TraceEvent> {
        let kind = TraceKind::parse_name(j.get("k")?.as_str()?)?;
        let u = |key: &str| j.get(key).and_then(Json::as_u64).unwrap_or(0);
        let bits = match j.get("bits") {
            None => 0,
            Some(Json::Str(s)) => {
                u64::from_str_radix(s.trim_start_matches("0x"), 16).ok()?
            }
            Some(v) => v.as_f64()? as u64,
        };
        Some(TraceEvent {
            ns: u("ns"),
            dur_ns: u("dur"),
            kind,
            worker: u("w") as u32,
            stage: u("st") as u32,
            step: u("step"),
            version: u("ver"),
            bytes: u("b"),
            bits,
        })
    }

    /// End timestamp (start + duration) — what the analyzer orders
    /// completion-sensitive checks by.
    pub fn end_ns(&self) -> u64 {
        self.ns + self.dur_ns
    }
}

/// The launcher's legacy stdout loss line, derived from a
/// [`TraceKind::Loss`] event so the wire protocol has one source of
/// truth (`None` for any other kind).  Format: `CDP_LOSS <step> <hex>`
/// where `<hex>` is the 16-digit `f64::to_bits` of the loss.
pub fn render_loss_line(ev: &TraceEvent) -> Option<String> {
    (ev.kind == TraceKind::Loss).then(|| format!("CDP_LOSS {} {:016x}", ev.step, ev.bits))
}

/// A parsed trace: the events that decoded, plus the bookkeeping the
/// tolerant parser accumulated along the way.
#[derive(Clone, Debug, Default)]
pub struct ParsedTrace {
    /// The header's `v` magic, when a header line was present.
    pub version: Option<String>,
    /// Ring-overflow drop count the header reported (0 when absent).
    pub dropped: u64,
    /// Every line that decoded into an event, in file order.
    pub events: Vec<TraceEvent>,
    /// Lines skipped: truncated/corrupt JSON, non-event objects,
    /// unknown future kinds.  Blank lines are not counted.
    pub skipped: u64,
}

/// Serialize a trace to its JSONL text (header + one line per event).
pub fn to_jsonl(events: &[TraceEvent], dropped: u64) -> String {
    let mut out = String::with_capacity(32 + events.len() * 96);
    out.push_str(&format!("{{\"v\":\"{TRACE_MAGIC}\",\"dropped\":{dropped}}}\n"));
    for ev in events {
        out.push_str(&ev.to_json_line());
        out.push('\n');
    }
    out
}

/// Write a trace file atomically (tmp + rename, the checkpoint
/// discipline) so a crashed run leaves either the old file or the new
/// one, never a half-written trace.
pub fn write_jsonl(path: &Path, events: &[TraceEvent], dropped: u64) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = path.with_extension("jsonl.tmp");
    std::fs::write(&tmp, to_jsonl(events, dropped))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Tolerant line-oriented parse of a whole trace text (see the module
/// docs for exactly what is skipped vs. kept).
pub fn parse_jsonl(text: &str) -> ParsedTrace {
    let mut out = ParsedTrace::default();
    for line in text.lines() {
        parse_line(line, &mut out);
    }
    out
}

/// Tolerant parse from any synchronous reader.  Only I/O errors
/// propagate; malformed content is counted in
/// [`ParsedTrace::skipped`].
pub fn parse_jsonl_reader(r: impl BufRead) -> std::io::Result<ParsedTrace> {
    let mut out = ParsedTrace::default();
    for line in r.lines() {
        parse_line(&line?, &mut out);
    }
    Ok(out)
}

/// Tolerant parse of a trace file on disk.
pub fn parse_jsonl_file(path: &Path) -> anyhow::Result<ParsedTrace> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open trace {}: {e}", path.display()))?;
    Ok(parse_jsonl_reader(std::io::BufReader::new(f))?)
}

fn parse_line(raw: &str, out: &mut ParsedTrace) {
    let line = raw.trim_end_matches('\r').trim();
    if line.is_empty() {
        return; // blank lines are not corruption
    }
    let Ok(j) = Json::parse(line) else {
        out.skipped += 1; // truncated final line, interleaved garbage
        return;
    };
    if let Some(v) = j.get("v").and_then(Json::as_str) {
        if out.version.is_none() {
            out.version = Some(v.to_string());
            out.dropped = j.get("dropped").and_then(Json::as_u64).unwrap_or(0);
        }
        return;
    }
    match TraceEvent::from_json(&j) {
        Some(ev) => out.events.push(ev),
        None => out.skipped += 1, // unknown future kind / non-event object
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip_exhaustively() {
        for k in TraceKind::ALL {
            assert_eq!(TraceKind::parse_name(k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(TraceKind::parse_name("teleport"), None);
    }

    #[test]
    fn event_json_line_round_trips() {
        let ev = TraceEvent::new(
            TraceKind::GradSend,
            123_456,
            789,
            Fields {
                worker: 2,
                stage: 1,
                step: 7,
                version: 30,
                bytes: 4096,
                bits: 0xdead_beef_0000_0001,
            },
        );
        let line = ev.to_json_line();
        let j = Json::parse(&line).expect("emitted line is valid JSON");
        assert_eq!(TraceEvent::from_json(&j), Some(ev));
    }

    #[test]
    fn loss_event_carries_exact_bits() {
        let ev = TraceEvent::loss(0, 3, 2.718281828459045);
        assert_eq!(ev.loss_value(), Some(2.718281828459045));
        let line = render_loss_line(&ev).unwrap();
        assert_eq!(
            line,
            format!("CDP_LOSS 3 {:016x}", 2.718281828459045f64.to_bits())
        );
        assert_eq!(render_loss_line(&TraceEvent::new(
            TraceKind::Fwd,
            0,
            0,
            Fields::default()
        )), None);
    }

    #[test]
    fn header_and_events_round_trip() {
        let evs = vec![
            TraceEvent::new(TraceKind::StepBegin, 1, 0, Fields::default()),
            TraceEvent::loss(1, 0, std::f64::consts::PI),
        ];
        let text = to_jsonl(&evs, 5);
        let p = parse_jsonl(&text);
        assert_eq!(p.version.as_deref(), Some(TRACE_MAGIC));
        assert_eq!(p.dropped, 5);
        assert_eq!(p.events, evs);
        assert_eq!(p.skipped, 0);
    }
}
