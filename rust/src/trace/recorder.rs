//! The process-global ring-buffered trace recorder.
//!
//! Cost contract (asserted by `benches/hotpath.rs` and recorded as the
//! `trace_disabled_overhead` counter in `BENCH_hotpath.json`):
//!
//! * **Disabled** (the default): every recording call is a single
//!   `Relaxed` atomic load and an immediate return — no lock, no clock
//!   read, no allocation.  This is the state every hot path ships in.
//! * **Enabled**: a short mutex hold and one write into a ring buffer
//!   preallocated by [`enable`] — zero steady-state allocation.  When
//!   the ring wraps, the oldest event is overwritten and the drop is
//!   counted, so a bounded trace of the *most recent* activity always
//!   survives; the drop count rides in the JSONL header.
//!
//! [`enable`] publishes the enabled flag *inside* the ring lock — the
//! same discipline `CommStats::enable_timeline` was retrofitted to —
//! so a concurrent recording call can never observe the flag before
//! the buffer it implies exists.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::event::{Fields, TraceEvent, TraceKind};

static ENABLED: AtomicBool = AtomicBool::new(false);
static KERNELS: AtomicBool = AtomicBool::new(false);
static RING: Mutex<Option<Ring>> = Mutex::new(None);
/// Serializes tests that exercise the process-global recorder.
static CAPTURE_GATE: Mutex<()> = Mutex::new(());

struct Ring {
    buf: Vec<TraceEvent>,
    cap: usize,
    head: usize,
    dropped: u64,
    epoch: Instant,
}

fn lock_ring() -> std::sync::MutexGuard<'static, Option<Ring>> {
    RING.lock().unwrap_or_else(|e| e.into_inner())
}

/// Turn recording on with a ring of `capacity` events (clamped to ≥ 1).
/// Allocates the whole ring up front; recording never allocates after
/// this returns.  Re-enabling discards any events from a prior window.
pub fn enable(capacity: usize) {
    let mut g = lock_ring();
    *g = Some(Ring {
        buf: Vec::with_capacity(capacity.max(1)),
        cap: capacity.max(1),
        head: 0,
        dropped: 0,
        epoch: Instant::now(),
    });
    // Published under the lock: no recorder can see ENABLED=true while
    // the ring it implies is still being installed.
    ENABLED.store(true, Ordering::Release);
}

/// Whether recording is on.  This load *is* the entire disabled-path
/// cost of every `instant`/`span` call site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Gate the (hotter, finer-grained) kernel-level spans in
/// `runtime::native` separately from the rest of the trace.
pub fn set_kernels(on: bool) {
    KERNELS.store(on, Ordering::Relaxed);
}

/// True only when recording is on *and* the kernel knob is set.
#[inline]
pub fn kernels_enabled() -> bool {
    enabled() && KERNELS.load(Ordering::Relaxed)
}

/// Nanoseconds since [`enable`]; 0 when disabled.  Use as the start
/// timestamp handed back to [`span`].
#[inline]
pub fn start() -> u64 {
    if !enabled() {
        return 0;
    }
    lock_ring().as_ref().map_or(0, |r| r.epoch.elapsed().as_nanos() as u64)
}

fn push(kind: TraceKind, started_ns: Option<u64>, f: Fields) {
    let mut g = lock_ring();
    let Some(r) = g.as_mut() else { return };
    let now = r.epoch.elapsed().as_nanos() as u64;
    let (ns, dur_ns) = match started_ns {
        Some(t0) => (t0, now.saturating_sub(t0)),
        None => (now, 0),
    };
    let ev = TraceEvent::new(kind, ns, dur_ns, f);
    if r.buf.len() < r.cap {
        r.buf.push(ev); // within preallocated capacity: no allocation
    } else {
        r.buf[r.head] = ev;
        r.head = (r.head + 1) % r.cap;
        r.dropped += 1;
    }
}

/// Record an instant event.  No-op (one atomic load) when disabled.
#[inline]
pub fn instant(kind: TraceKind, f: Fields) {
    if !enabled() {
        return;
    }
    push(kind, None, f);
}

/// Record a span that began at `started_ns` (a value from [`start`])
/// and ends now.  No-op (one atomic load) when disabled.
#[inline]
pub fn span(kind: TraceKind, started_ns: u64, f: Fields) {
    if !enabled() {
        return;
    }
    push(kind, Some(started_ns), f);
}

/// Record a [`TraceKind::Loss`] event and return it (timestamp-free)
/// so callers — the worker CLI's `CDP_LOSS` back-compat line — can
/// derive their output from the very event that entered the stream.
pub fn loss(worker: usize, step: u64, loss: f64) -> TraceEvent {
    let ev = TraceEvent::loss(worker, step, loss);
    instant(
        TraceKind::Loss,
        Fields {
            worker: ev.worker,
            step: ev.step,
            bits: ev.bits,
            ..Fields::default()
        },
    );
    ev
}

/// Start timestamp for a kernel span; 0 (and no later cost) unless the
/// kernel knob is on.
#[inline]
pub fn kernel_start() -> u64 {
    if kernels_enabled() {
        start()
    } else {
        0
    }
}

/// Close a kernel span opened by [`kernel_start`].  `op` is the opcode
/// (0 fwd, 1 bwd, 2 sgd), carried in `bits`.
#[inline]
pub fn kernel_end(started_ns: u64, op: u64, stage: usize, step: u64) {
    if !kernels_enabled() {
        return;
    }
    push(
        TraceKind::Kernel,
        Some(started_ns),
        Fields {
            stage: stage as u32,
            step,
            bits: op,
            ..Fields::default()
        },
    );
}

/// Turn recording off and take everything buffered, oldest first,
/// together with the ring-overflow drop count.
pub fn drain() -> (Vec<TraceEvent>, u64) {
    let mut g = lock_ring();
    ENABLED.store(false, Ordering::Release);
    let Some(mut r) = g.take() else {
        return (Vec::new(), 0);
    };
    if r.buf.len() == r.cap && r.head > 0 {
        r.buf.rotate_left(r.head); // unwrap the ring into time order
    }
    (r.buf, r.dropped)
}

/// The gate [`capture`] serializes on, for tests that feed the
/// process-global recorder *without* capturing (e.g. through
/// `CommStats::mark` forwarding) — hold it so parallel test threads
/// don't pollute another test's capture window.  Do not call [`capture`]
/// while holding it (same mutex).
#[doc(hidden)]
pub fn test_gate() -> std::sync::MutexGuard<'static, ()> {
    CAPTURE_GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with recording enabled (ring of `capacity`), then drain.
/// Returns `(f's result, events, dropped)`.  Holds a process-wide gate
/// so concurrent tests of the global recorder serialize instead of
/// stomping each other's windows.
pub fn capture<R>(capacity: usize, f: impl FnOnce() -> R) -> (R, Vec<TraceEvent>, u64) {
    let _gate = CAPTURE_GATE.lock().unwrap_or_else(|e| e.into_inner());
    enable(capacity);
    let out = f();
    let (events, dropped) = drain();
    (out, events, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let _gate = CAPTURE_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let (evs, dropped) = drain(); // ensure off
        drop((evs, dropped));
        assert!(!enabled());
        instant(TraceKind::Fwd, Fields::default());
        span(TraceKind::Bwd, start(), Fields::default());
        assert_eq!(start(), 0);
        let (evs, dropped) = drain();
        assert!(evs.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn capture_orders_events_and_measures_spans() {
        let ((), evs, dropped) = capture(64, || {
            let t0 = start();
            instant(
                TraceKind::GradSend,
                Fields {
                    worker: 1,
                    stage: 2,
                    step: 3,
                    bytes: 16,
                    ..Fields::default()
                },
            );
            span(TraceKind::Fwd, t0, Fields { stage: 1, ..Fields::default() });
        });
        assert_eq!(dropped, 0);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, TraceKind::GradSend);
        assert_eq!(evs[0].bytes, 16);
        assert_eq!(evs[1].kind, TraceKind::Fwd);
        assert!(evs[1].ns <= evs[0].ns, "span start precedes the instant");
        assert!(evs[1].end_ns() >= evs[1].ns);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let ((), evs, dropped) = capture(4, || {
            for i in 0..10u64 {
                instant(TraceKind::Heartbeat, Fields { step: i, ..Fields::default() });
            }
        });
        assert_eq!(evs.len(), 4);
        assert_eq!(dropped, 6);
        let steps: Vec<u64> = evs.iter().map(|e| e.step).collect();
        assert_eq!(steps, vec![6, 7, 8, 9], "newest events survive, in order");
    }

    #[test]
    fn kernel_knob_gates_kernel_spans() {
        let ((), evs, _) = capture(16, || {
            set_kernels(false);
            let t0 = kernel_start();
            kernel_end(t0, 0, 1, 2);
            set_kernels(true);
            let t1 = kernel_start();
            kernel_end(t1, 2, 3, 4);
            set_kernels(false);
        });
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind, TraceKind::Kernel);
        assert_eq!((evs[0].bits, evs[0].stage, evs[0].step), (2, 3, 4));
    }

    #[test]
    fn enable_under_concurrent_recording_is_safe() {
        // The ordering discipline this module exists to enforce (the
        // CommStats::enable_timeline hazard): threads hammer the
        // recorder while the main thread flips it on and off.
        let _gate = CAPTURE_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let threads: Vec<_> = (0..4u32)
            .map(|w| {
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        instant(
                            TraceKind::Heartbeat,
                            Fields { worker: w, step: n, ..Fields::default() },
                        );
                        n += 1;
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            enable(128);
            std::thread::yield_now();
            let (evs, _) = drain();
            assert!(evs.len() <= 128);
            assert!(evs.iter().all(|e| e.kind == TraceKind::Heartbeat));
        }
        stop.store(true, Ordering::Relaxed);
        for t in threads {
            t.join().expect("recorder stress thread panicked");
        }
    }
}
