//! Synthetic datasets, **bit-identical with `python/compile/datagen.py`**
//! (DESIGN.md substitution #2).  The coordinator pulls micro-batch (t, i)
//! by index; both languages derive the same per-micro-batch seed and the
//! same sample bytes, which is what makes the cross-language golden test
//! exact rather than statistical.

use crate::model::{DataSpec, Manifest};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::{microbatch_seed, splitmix64, XorShift64Star};

/// One micro-batch as fed to stage 0 + the loss stage.
#[derive(Clone, Debug)]
pub enum MicroBatch {
    Lm { tokens: IntTensor, targets: IntTensor },
    Class { x: Tensor, labels: IntTensor },
}

impl MicroBatch {
    pub fn input_bytes(&self) -> usize {
        match self {
            MicroBatch::Lm { tokens, .. } => tokens.data.len() * 4,
            MicroBatch::Class { x, .. } => x.data.len() * 4,
        }
    }
}

/// Deterministic micro-batch source for a bundle's data distribution.
pub struct DataSource {
    spec: DataSpec,
    /// Class prototypes ([C, dim] flattened) for classification tasks.
    protos: Option<Vec<f32>>,
}

impl DataSource {
    pub fn new(spec: DataSpec) -> Self {
        let protos = match &spec {
            DataSpec::Class { classes, input_dim, seed, .. } => {
                Some(class_prototypes(*seed, *classes, *input_dim))
            }
            _ => None,
        };
        Self { spec, protos }
    }

    pub fn from_manifest(m: &Manifest) -> Self {
        Self::new(m.data.clone())
    }

    /// Micro-batch `mb` (0-based) of training step `step`.
    pub fn microbatch(&self, step: u64, mb: u64) -> MicroBatch {
        match &self.spec {
            DataSpec::Lm { vocab, seq, batch, seed } => {
                let (tokens, targets) =
                    lm_microbatch(*seed, step, mb, *batch, *seq, *vocab);
                MicroBatch::Lm { tokens, targets }
            }
            DataSpec::Class { classes, input_dim, batch, noise, seed } => {
                let (x, labels) = class_microbatch(
                    *seed,
                    step,
                    mb,
                    *batch,
                    self.protos.as_ref().unwrap(),
                    *classes,
                    *input_dim,
                    *noise,
                );
                MicroBatch::Class { x, labels }
            }
        }
    }

    /// Held-out micro-batch (classification eval): steps ≥ 1_000_000 are
    /// never used for training, mirroring `MirrorTrainer.accuracy`.
    pub fn eval_microbatch(&self, k: u64) -> MicroBatch {
        self.microbatch(1_000_000 + k, 0)
    }
}

/// Noisy affine Markov chain over the vocab (learnable bigram structure).
pub fn lm_microbatch(
    base_seed: u64,
    step: u64,
    mb: u64,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> (IntTensor, IntTensor) {
    let mut rng = XorShift64Star::new(microbatch_seed(base_seed, step, mb));
    let noise = (vocab / 4).max(1) as u64;
    let v = vocab as u64;
    let mut inputs = vec![0i32; batch * seq];
    let mut targets = vec![0i32; batch * seq];
    for b in 0..batch {
        let mut cur = rng.next_below(v);
        for s in 0..seq {
            let next = (5 * cur + 1 + rng.next_below(noise)) % v;
            inputs[b * seq + s] = cur as i32;
            targets[b * seq + s] = next as i32;
            cur = next;
        }
    }
    (
        IntTensor::new(vec![batch, seq], inputs),
        IntTensor::new(vec![batch, seq], targets),
    )
}

/// [C, dim] prototypes, derived from base_seed ^ 0xC1A55 (as python).
pub fn class_prototypes(base_seed: u64, classes: usize, dim: usize) -> Vec<f32> {
    let mut rng = XorShift64Star::new(splitmix64(base_seed ^ 0xC1A55));
    let mut out = vec![0f32; classes * dim];
    for v in out.iter_mut() {
        *v = rng.normal();
    }
    out
}

#[allow(clippy::too_many_arguments)]
pub fn class_microbatch(
    base_seed: u64,
    step: u64,
    mb: u64,
    batch: usize,
    protos: &[f32],
    classes: usize,
    dim: usize,
    noise: f32,
) -> (Tensor, IntTensor) {
    let mut rng = XorShift64Star::new(microbatch_seed(base_seed, step, mb));
    let mut x = vec![0f32; batch * dim];
    let mut y = vec![0i32; batch];
    for b in 0..batch {
        let c = rng.next_below(classes as u64) as usize;
        y[b] = c as i32;
        for d in 0..dim {
            x[b * dim + d] = protos[c * dim + d] + noise * rng.normal();
        }
    }
    (Tensor::new(vec![batch, dim], x), IntTensor::new(vec![batch], y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DataSpec;

    #[test]
    fn lm_matches_python_structure() {
        let (x, y) = lm_microbatch(42, 3, 1, 4, 16, 64);
        assert_eq!(x.shape, vec![4, 16]);
        // targets are inputs shifted by one
        for b in 0..4 {
            for s in 0..15 {
                assert_eq!(x.data[b * 16 + s + 1], y.data[b * 16 + s]);
            }
        }
        // markov band: (next - (5 cur + 1)) mod V in [0, V/4)
        for (i, t) in x.data.iter().zip(&y.data) {
            let delta = ((*t as i64) - (5 * (*i as i64) + 1)).rem_euclid(64);
            assert!((0..16).contains(&delta), "delta={delta}");
        }
        // determinism + stream independence
        let (x2, _) = lm_microbatch(42, 3, 1, 4, 16, 64);
        assert_eq!(x.data, x2.data);
        let (x3, _) = lm_microbatch(42, 3, 2, 4, 16, 64);
        assert_ne!(x.data, x3.data);
    }

    #[test]
    fn class_near_prototypes() {
        let protos = class_prototypes(99, 10, 64);
        let (x, y) = class_microbatch(99, 0, 0, 32, &protos, 10, 64, 0.3);
        let mut correct = 0;
        for b in 0..32 {
            let xb = &x.data[b * 64..(b + 1) * 64];
            let (mut best, mut best_d) = (0usize, f32::INFINITY);
            for c in 0..10 {
                let pc = &protos[c * 64..(c + 1) * 64];
                let d: f32 = xb.iter().zip(pc).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if best as i32 == y.data[b] {
                correct += 1;
            }
        }
        assert!(correct >= 30, "nearest-proto acc {correct}/32");
    }

    #[test]
    fn datasource_eval_split_disjoint() {
        let ds = DataSource::new(DataSpec::Class {
            classes: 10,
            input_dim: 8,
            batch: 4,
            noise: 0.3,
            seed: 7,
        });
        let train = ds.microbatch(0, 0);
        let eval = ds.eval_microbatch(0);
        match (train, eval) {
            (MicroBatch::Class { x: a, .. }, MicroBatch::Class { x: b, .. }) => {
                assert_ne!(a.data, b.data);
            }
            _ => panic!("wrong kind"),
        }
    }
}
