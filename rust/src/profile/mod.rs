//! `profile` — measure what [`crate::plan`] predicts.
//!
//! [`StageProfiler`] runs a few calibration steps on a [`Backend`]
//! (in practice the [`NativeBackend`]) and records, per stage:
//!
//! - forward / backward / SGD **wall time** (the backward of the loss
//!   stage fuses its forward, mirroring the `last_bwd` artifact contract,
//!   so its forward cost is reported inside `bwd_ns` and `fwd_ns` is 0);
//! - **bytes moved at each stage boundary** (the activation hand-off the
//!   pipeline trainer puts on the fabric), measured from the real
//!   [`Activation::bytes`] of each produced activation;
//! - **gradient bytes per bucket** at the session's bucket size
//!   ([`crate::comm::bucketed::bucket_elems_from_env`]);
//! - **peak activation bytes** of one micro-batch chain (the stage-input
//!   stash that rematerializing backward keeps live).
//!
//! On top of the per-stage pass it calibrates the constants the planner's
//! analytic cost model needs (DESIGN-PERF.md §Auto-planner):
//!
//! - fabric **bandwidth** and **per-hop latency** from a two-endpoint
//!   [`Fabric`] probe (the same [`crate::comm::CommStats`]-counted
//!   machinery the benches use);
//! - the **bf16 step ratio** (bf16 chain time / f32 chain time);
//! - measured **single-trainer** and **multi-ring** step wall times, so
//!   thread-parallel candidates are scored against observed — not ideal —
//!   parallel efficiency;
//! - steady-state **allocations per step** via
//!   [`crate::testing::instrument::alloc_delta`] (non-zero only in
//!   binaries that install the counting allocator).
//!
//! Everything here is measurement; the search/scoring lives in
//! [`crate::plan`].

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::comm::bucketed::{bucket_elems_from_env, effective_bucket_elems};
use crate::comm::{tags, Fabric};
use crate::coordinator::{multi, single::RefTrainer, SharedBackend};
use crate::data::{DataSource, MicroBatch};
use crate::parallel::arena::ArenaLayout;
use crate::parallel::Rule;
use crate::runtime::{Activation, Backend, ExecMode, NativeBackend, NativeMlpConfig, Precision};
use crate::tensor::HostTensor;
use crate::testing::instrument;

/// Per-stage measured costs (means over the calibration steps, warm-up
/// step excluded).
#[derive(Clone, Debug)]
pub struct StageProfile {
    /// Stage index.
    pub stage: usize,
    /// Mean forward wall time per micro-batch, ns (0 for the loss stage —
    /// its forward is fused into `bwd_ns`).
    pub fwd_ns: f64,
    /// Mean backward wall time per micro-batch, ns.
    pub bwd_ns: f64,
    /// Mean fused-SGD wall time for this stage's parameter run, ns.
    pub sgd_ns: f64,
    /// Activation bytes leaving this stage (0 for the last stage).
    pub boundary_bytes: u64,
    /// Parameter bytes of this stage's arena run.
    pub param_bytes: u64,
    /// Gradient buckets at the profiled bucket size.
    pub grad_buckets: usize,
    /// Bytes per (full) gradient bucket.
    pub grad_bucket_bytes: u64,
    /// Manifest's analytic activation bytes (for cross-checks).
    pub act_bytes: u64,
}

/// The complete calibration record the planner consumes.  All fields are
/// public so tests can construct synthetic profiles directly.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    /// Human-readable model label.
    pub model: String,
    /// Per-stage measurements, stage-ordered.
    pub stages: Vec<StageProfile>,
    /// Micro-batch size b.
    pub microbatch: usize,
    /// Micro-batches per step (N of the square schedule).
    pub n_microbatches: usize,
    /// Total parameter bytes Ψ_P.
    pub psi_p_bytes: u64,
    /// Measured peak live activation bytes of one micro-batch chain.
    pub peak_act_bytes: u64,
    /// Per-layer fwd+bwd cost, contiguous layer order — the partition
    /// search's input.  For backends without sub-stage visibility this is
    /// one entry per stage; [`StageProfiler::profile_native`] refines it
    /// to residual-layer granularity.
    pub layer_costs_ns: Vec<f64>,
    /// Fabric bandwidth, bytes per ns (0.0 = not probed).
    pub bw_bytes_per_ns: f64,
    /// Fabric per-hop latency, ns.
    pub hop_latency_ns: f64,
    /// bf16 chain time / f32 chain time (1.0 = not measured).
    pub bf16_step_ratio: f64,
    /// Measured single-trainer step wall time, ns (0.0 = not measured).
    pub single_step_ns: f64,
    /// Measured multi-ring step wall time at the profiled stage count, ns
    /// (0.0 = not measured).
    pub multi_step_ns: f64,
    /// Host hardware parallelism the multi/zero trainers can draw on.
    pub host_threads: usize,
    /// Calibration steps run (first is warm-up, excluded from means).
    pub calib_steps: usize,
    /// Heap allocations per calibration chain (0 unless the binary
    /// installs [`instrument::CountingAlloc`]).
    pub alloc_per_step: u64,
}

impl ModelProfile {
    /// Stage count of the profiled partition.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Σ forward ns of one micro-batch chain.
    pub fn fwd_total_ns(&self) -> f64 {
        self.stages.iter().map(|s| s.fwd_ns).sum()
    }

    /// Σ backward ns of one micro-batch chain.
    pub fn bwd_total_ns(&self) -> f64 {
        self.stages.iter().map(|s| s.bwd_ns).sum()
    }

    /// Σ fused-SGD ns of one full update.
    pub fn sgd_total_ns(&self) -> f64 {
        self.stages.iter().map(|s| s.sgd_ns).sum()
    }

    /// One micro-batch's full fwd+bwd chain, ns.
    pub fn chain_ns(&self) -> f64 {
        self.fwd_total_ns() + self.bwd_total_ns()
    }

    /// Mean activation bytes crossing one stage boundary.
    pub fn mean_boundary_bytes(&self) -> u64 {
        let cuts: Vec<u64> = self
            .stages
            .iter()
            .filter(|s| s.boundary_bytes > 0)
            .map(|s| s.boundary_bytes)
            .collect();
        if cuts.is_empty() {
            0
        } else {
            cuts.iter().sum::<u64>() / cuts.len() as u64
        }
    }

    /// Human-readable per-stage table (for `--plan auto` logging).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "profile of {} ({} stages, {} mb/step, Ψ_P {} B, peak act {} B, \
             bw {:.3} B/ns, hop {:.0} ns, bf16 ratio {:.2})\n",
            self.model,
            self.n_stages(),
            self.n_microbatches,
            self.psi_p_bytes,
            self.peak_act_bytes,
            self.bw_bytes_per_ns,
            self.hop_latency_ns,
            self.bf16_step_ratio,
        ));
        out.push_str("stage |    fwd ns |    bwd ns |    sgd ns | boundary B |  param B | buckets\n");
        for s in &self.stages {
            out.push_str(&format!(
                "{:5} | {:9.0} | {:9.0} | {:9.0} | {:10} | {:8} | {:7}\n",
                s.stage,
                s.fwd_ns,
                s.bwd_ns,
                s.sgd_ns,
                s.boundary_bytes,
                s.param_bytes,
                s.grad_buckets
            ));
        }
        out
    }
}

/// Options for a profiling pass.
#[derive(Clone, Copy, Debug)]
pub struct ProfileOpts {
    /// Calibration steps; the first is a warm-up excluded from means.
    pub calib_steps: usize,
    /// Probe fabric bandwidth/latency (small constant cost).
    pub probe_fabric: bool,
    /// Also measure bf16 and trainer-level wall times (native only).
    pub calibrate_trainers: bool,
}

impl Default for ProfileOpts {
    fn default() -> Self {
        Self { calib_steps: 3, probe_fabric: true, calibrate_trainers: true }
    }
}

/// The profiling pass.  See the module docs for what is measured.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageProfiler {
    /// Pass options.
    pub opts: ProfileOpts,
}

/// Raw per-chain accumulators of [`StageProfiler::run_chain`].
struct ChainRecord {
    fwd_ns: Vec<f64>,
    bwd_ns: Vec<f64>,
    sgd_ns: Vec<f64>,
    boundary_bytes: Vec<u64>,
    peak_act_bytes: u64,
    total_ns: f64,
    allocs: u64,
}

impl StageProfiler {
    /// A profiler with explicit options.
    pub fn new(opts: ProfileOpts) -> Self {
        Self { opts }
    }

    /// Profile any backend at its manifest's stage granularity.
    pub fn profile<B: Backend>(&self, rt: &B) -> Result<ModelProfile> {
        // Pool spawn + kernel-mode resolution happen before any timed
        // window (DESIGN-PERF.md §Zero-alloc windowing).
        crate::util::par::warm();
        std::hint::black_box(crate::tensor::ops::kernel_mode());

        let m = rt.manifest();
        let n = m.n_stages;
        let layout = ArenaLayout::from_manifest(m);
        let bucket = bucket_elems_from_env();
        let steps = self.opts.calib_steps.max(1);

        let mut records: Vec<ChainRecord> = Vec::with_capacity(steps);
        for s in 0..steps {
            records.push(self.run_chain(rt, &layout, s as u64)?);
        }
        // Warm-up exclusion: with >1 steps, drop the first record.
        let kept: &[ChainRecord] = if records.len() > 1 { &records[1..] } else { &records };
        let kn = kept.len() as f64;

        let mut stages = Vec::with_capacity(n);
        for j in 0..n {
            let be = effective_bucket_elems(bucket, layout.stage_len(j));
            stages.push(StageProfile {
                stage: j,
                fwd_ns: kept.iter().map(|r| r.fwd_ns[j]).sum::<f64>() / kn,
                bwd_ns: kept.iter().map(|r| r.bwd_ns[j]).sum::<f64>() / kn,
                sgd_ns: kept.iter().map(|r| r.sgd_ns[j]).sum::<f64>() / kn,
                boundary_bytes: kept[0].boundary_bytes[j],
                param_bytes: 4 * layout.stage_len(j) as u64,
                grad_buckets: layout.n_buckets(j, be),
                grad_bucket_bytes: 4 * be as u64,
                act_bytes: m.stages[j].act_bytes,
            });
        }
        let layer_costs_ns: Vec<f64> =
            stages.iter().map(|s| s.fwd_ns + s.bwd_ns).collect();
        let (bw, lat) = if self.opts.probe_fabric {
            probe_fabric()?
        } else {
            (0.0, 0.0)
        };
        Ok(ModelProfile {
            model: m.name.clone(),
            stages,
            microbatch: m.target.shape[0],
            n_microbatches: m.n_microbatches,
            psi_p_bytes: m.psi_p_bytes(),
            peak_act_bytes: kept[0].peak_act_bytes,
            layer_costs_ns,
            bw_bytes_per_ns: bw,
            hop_latency_ns: lat,
            bf16_step_ratio: 1.0,
            single_step_ns: 0.0,
            multi_step_ns: 0.0,
            host_threads: host_threads(),
            calib_steps: steps,
            alloc_per_step: kept.iter().map(|r| r.allocs).sum::<u64>() / kept.len() as u64,
        })
    }

    /// Profile a synthetic native MLP, refining the generic pass with
    /// residual-layer cost granularity, the bf16 ratio, and measured
    /// single/multi trainer step times (the parallel-efficiency
    /// calibration the planner's thread-parallel candidates use).
    pub fn profile_native(&self, cfg: &NativeMlpConfig) -> Result<ModelProfile> {
        let rt = NativeBackend::synthetic(*cfg);
        let mut p = self.profile(&rt)?;
        p.model = format!(
            "native_mlp[h{} {}x{} mb{}]",
            cfg.hidden, cfg.n_stages, cfg.layers_per_stage, cfg.microbatch
        );

        // Per-layer costs: split each stage's chain cost evenly over its
        // residual layers (the stage-0 prologue and loss head stay folded
        // into their stage's layers — the partition search only needs
        // relative weights).
        let lps = cfg.layers_per_stage.max(1);
        p.layer_costs_ns = p
            .stages
            .iter()
            .flat_map(|s| {
                let share = (s.fwd_ns + s.bwd_ns) / lps as f64;
                std::iter::repeat_n(share, lps)
            })
            .collect();

        if self.opts.calibrate_trainers {
            // bf16 ratio: one chain on a bf16 twin, against the mean f32
            // chain time from the main pass.
            let rt16 = NativeBackend::synthetic(*cfg).with_precision(Precision::Bf16);
            let layout16 = ArenaLayout::from_manifest(rt16.manifest());
            self.run_chain(&rt16, &layout16, 0)?; // warm bf16 scratch
            let r16 = self.run_chain(&rt16, &layout16, 1)?;
            let f32_chain = p.chain_ns() + p.sgd_total_ns();
            if f32_chain > 0.0 && r16.total_ns > 0.0 {
                p.bf16_step_ratio = (r16.total_ns / f32_chain).clamp(0.25, 4.0);
            }

            // Trainer-level wall times (3 steps each, first not excluded:
            // thread spawn is part of what the multi trainer costs here).
            let calib_steps = 3usize;
            let mut single = RefTrainer::new(&rt, Rule::Dp)?;
            single.train(1)?; // warm
            let t0 = Instant::now();
            single.train(calib_steps)?;
            p.single_step_ns = t0.elapsed().as_nanos() as f64 / calib_steps as f64;

            let shared = SharedBackend(Arc::new(NativeBackend::synthetic(*cfg)));
            let t0 = Instant::now();
            multi::train(shared, Rule::CdpV2, multi::CommPattern::Ring, calib_steps)?;
            p.multi_step_ns = t0.elapsed().as_nanos() as f64 / calib_steps as f64;
        }
        Ok(p)
    }

    /// One calibration chain: a single micro-batch's fwd+bwd over every
    /// stage plus a full fused-SGD sweep, each call individually timed.
    /// Mirrors `RefTrainer::run_microbatch` (the θ-version argument is the
    /// step counter — the native backend is stateless in it).
    fn run_chain<B: Backend>(
        &self,
        rt: &B,
        layout: &ArenaLayout,
        step: u64,
    ) -> Result<ChainRecord> {
        let m = rt.manifest();
        let n = m.n_stages;
        let data = DataSource::from_manifest(m);
        let flat = rt.init_params_flat()?;
        let mut exec = rt.executor(ExecMode::HostLiteral);
        let mut gop = layout.zeros_aligned();
        let mut moms = layout.zeros_aligned();
        let mut next = layout.zeros_aligned();

        let mut rec = ChainRecord {
            fwd_ns: vec![0.0; n],
            bwd_ns: vec![0.0; n],
            sgd_ns: vec![0.0; n],
            boundary_bytes: vec![0; n],
            peak_act_bytes: 0,
            total_ns: 0.0,
            allocs: 0,
        };

        let mb = data.microbatch(step, step % m.n_microbatches.max(1) as u64);
        let (x0, targets) = match mb {
            MicroBatch::Lm { tokens, targets } => (HostTensor::I32(tokens), targets),
            MicroBatch::Class { x, labels } => (HostTensor::F32(x), labels),
        };

        let alloc_before = instrument::alloc_count();
        let chain_t0 = Instant::now();

        // Forward chain, stashing stage inputs (the remat unit); peak
        // live bytes = Σ stashed inputs + the activation in flight.
        let mut acts: Vec<B::Act> = Vec::with_capacity(n);
        acts.push(rt.input(&mut exec, x0)?);
        let mut live: u64 = acts[0].bytes() as u64;
        rec.peak_act_bytes = live;
        for j in 0..n - 1 {
            let t0 = Instant::now();
            let y = rt.fwd(&mut exec, j, step, &flat[layout.stage_range(j)], &acts[j])?;
            rec.fwd_ns[j] = t0.elapsed().as_nanos() as f64;
            rec.boundary_bytes[j] = y.bytes() as u64;
            live += y.bytes() as u64;
            rec.peak_act_bytes = rec.peak_act_bytes.max(live);
            acts.push(y);
        }

        // Backward chain (loss stage fuses its forward).
        let last = n - 1;
        let t0 = Instant::now();
        let (loss, mut gx) = rt.last_bwd(
            &mut exec,
            step,
            &flat[layout.stage_range(last)],
            &acts[last],
            &targets,
            &mut gop[layout.stage_range(last)],
        )?;
        rec.bwd_ns[last] = t0.elapsed().as_nanos() as f64;
        std::hint::black_box(loss);
        for j in (1..last).rev() {
            let t0 = Instant::now();
            gx = rt.mid_bwd(
                &mut exec,
                j,
                step,
                &flat[layout.stage_range(j)],
                &acts[j],
                &gx,
                &mut gop[layout.stage_range(j)],
            )?;
            rec.bwd_ns[j] = t0.elapsed().as_nanos() as f64;
        }
        if n > 1 {
            let t0 = Instant::now();
            rt.first_bwd(
                &mut exec,
                step,
                &flat[layout.stage_range(0)],
                &acts[0],
                &gx,
                &mut gop[layout.stage_range(0)],
            )?;
            rec.bwd_ns[0] = t0.elapsed().as_nanos() as f64;
        }

        // Fused SGD per stage.
        for j in 0..n {
            let r = layout.stage_range(j);
            let t0 = Instant::now();
            rt.sgd(
                &mut exec,
                j,
                step,
                &flat[r.clone()],
                &mut moms[r.clone()],
                &gop[r.clone()],
                m.lr,
                &mut next[r],
            )?;
            rec.sgd_ns[j] = t0.elapsed().as_nanos() as f64;
        }

        rec.total_ns = chain_t0.elapsed().as_nanos() as f64;
        rec.allocs = instrument::alloc_count() - alloc_before;
        Ok(rec)
    }
}

/// Host hardware parallelism (≥ 1).
fn host_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Probe the in-process fabric: `(bytes_per_ns, per_hop_latency_ns)`.
///
/// Bandwidth from repeated 1 MiB send+recv pairs, latency from tiny
/// payloads — both over a two-endpoint [`Fabric`] whose [`CommStats`]
/// count the moved bytes, exactly like the trainers' fabrics.
///
/// [`CommStats`]: crate::comm::CommStats
pub fn probe_fabric() -> Result<(f64, f64)> {
    const BIG_ELEMS: usize = 262_144; // 1 MiB of f32
    const BIG_ITERS: u64 = 8;
    const SMALL_ITERS: u64 = 64;

    let (mut eps, stats) = Fabric::new(2);
    let mut e1 = eps.pop().expect("two endpoints");
    let e0 = eps.pop().expect("two endpoints");

    let big = vec![1.0f32; BIG_ELEMS];
    e0.send_copy(1, tags::grad(0, 0), &big)?;
    std::hint::black_box(e1.recv(0, tags::grad(0, 0))?);

    let t0 = Instant::now();
    for t in 1..=BIG_ITERS {
        e0.send_copy(1, tags::grad(t, 0), &big)?;
        std::hint::black_box(e1.recv(0, tags::grad(t, 0))?);
    }
    let big_ns = t0.elapsed().as_nanos() as f64;
    let bw = (BIG_ITERS as f64 * BIG_ELEMS as f64 * 4.0) / big_ns.max(1.0);

    let small = [1.0f32; 1];
    let t0 = Instant::now();
    for t in 1..=SMALL_ITERS {
        e0.send_copy(1, tags::param(t, 0), &small)?;
        std::hint::black_box(e1.recv(0, tags::param(t, 0))?);
    }
    let lat = t0.elapsed().as_nanos() as f64 / SMALL_ITERS as f64;

    debug_assert!(stats.bytes() > 0, "probe bytes must be counted");
    Ok((bw, lat))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_tiny_native_mlp() {
        let profiler = StageProfiler::new(ProfileOpts {
            calib_steps: 2,
            probe_fabric: false,
            calibrate_trainers: false,
        });
        let rt = NativeBackend::synthetic(NativeMlpConfig::tiny());
        let p = profiler.profile(&rt).unwrap();
        assert_eq!(p.n_stages(), 2);
        assert!(p.chain_ns() > 0.0, "chain must take time");
        assert!(p.sgd_total_ns() > 0.0);
        // Loss stage's forward is fused into its backward.
        assert_eq!(p.stages[1].fwd_ns, 0.0);
        assert!(p.stages[1].bwd_ns > 0.0);
        // Boundary bytes: stage 0 hands mb×hidden f32 to stage 1.
        assert_eq!(p.stages[0].boundary_bytes, 2 * 6 * 4);
        assert_eq!(p.stages[1].boundary_bytes, 0);
        assert!(p.peak_act_bytes >= p.stages[0].boundary_bytes);
        assert_eq!(p.psi_p_bytes, rt.manifest.psi_p_bytes());
        assert!(p.stages.iter().all(|s| s.grad_buckets >= 1));
        assert_eq!(p.layer_costs_ns.len(), 2);
    }

    #[test]
    fn native_profile_refines_layers() {
        let profiler = StageProfiler::new(ProfileOpts {
            calib_steps: 2,
            probe_fabric: false,
            calibrate_trainers: false,
        });
        let cfg = NativeMlpConfig { layers_per_stage: 2, ..NativeMlpConfig::tiny() };
        let p = profiler.profile_native(&cfg).unwrap();
        assert_eq!(p.layer_costs_ns.len(), cfg.n_stages * cfg.layers_per_stage);
        let sum: f64 = p.layer_costs_ns.iter().sum();
        assert!((sum - p.chain_ns()).abs() < 1e-6 * sum.max(1.0));
    }

    #[test]
    fn fabric_probe_yields_positive_calibration() {
        let (bw, lat) = probe_fabric().unwrap();
        assert!(bw > 0.0);
        assert!(lat > 0.0);
    }
}
