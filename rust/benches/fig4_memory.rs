//! Fig 4: activation memory per worker, DP vs CDP, ResNet-50 and ViT-B/16
//! profiles, N ∈ {4, 8, 32} — plus the tiny/lm bundles' own manifests as a
//! third profile source (activation bytes measured from the staged models).

mod harness;

use cyclic_dp::memsim::{extrapolate, resnet50_profile, vit_b16_profile, LayerProfile, MemoryCurve};
use cyclic_dp::model::{artifacts_root, Manifest};
use cyclic_dp::util::stats::fmt_bytes;

fn main() {
    let b = harness::Bench::new("fig4_memory");

    for (arch, layers) in [
        ("resnet50 (heterogeneous)", resnet50_profile(64)),
        ("vit_b16 (homogeneous)", vit_b16_profile(64)),
    ] {
        b.section(arch);
        let curve = MemoryCurve::from_layers(&layers);
        println!(
            "single-pass: peak {} mean {} ({} layers)",
            fmt_bytes(curve.peak() as u64),
            fmt_bytes(curve.mean() as u64),
            layers.len()
        );
        for n in [4usize, 8, 32] {
            let e = extrapolate(&curve, n, 512);
            println!(
                "N={:<3} DP {:>10}/worker  CDP {:>10}/worker  reduction {:>5.1}%",
                n,
                fmt_bytes(e.dp_peak as u64),
                fmt_bytes(e.cdp_peak as u64),
                e.reduction * 100.0
            );
        }
    }

    // bundle-derived profile: the staged models' own act_bytes
    if harness::have_bundle("tiny") {
        b.section("tiny bundle manifest profile (transformer, 4 stages)");
        let m = Manifest::load(&artifacts_root().join("tiny")).unwrap();
        let layers: Vec<LayerProfile> = m
            .stages
            .iter()
            .map(|s| LayerProfile {
                name: format!("stage{}", s.index),
                act_bytes: s.act_bytes,
                flops: s.flops,
            })
            .collect();
        let curve = MemoryCurve::from_layers(&layers);
        for n in [4usize, 8, 32] {
            let e = extrapolate(&curve, n, 256);
            println!(
                "N={:<3} DP {:>10}  CDP {:>10}  reduction {:>5.1}%",
                n,
                fmt_bytes(e.dp_peak as u64),
                fmt_bytes(e.cdp_peak as u64),
                e.reduction * 100.0
            );
        }
    }

    b.section("extrapolation throughput");
    let curve = MemoryCurve::from_layers(&vit_b16_profile(64));
    b.time("extrapolate N=32, 512 samples", 2, 50, || {
        std::hint::black_box(extrapolate(&curve, 32, 512));
    });
}
