//! Wire-transport benchmarks (DESIGN-ROBUSTNESS.md, "Crossing a real
//! wire"): what framing + CRC + socket hops cost against the in-process
//! channel fabric, and proof that the eager-overlap property (gradient
//! reduction starting before the last backward completes) survives the
//! move onto a real socket.  Results go to `BENCH_wire.json`; the CI
//! fault-matrix lane uploads it SHA-stamped.

mod harness;

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use cyclic_dp::cluster::run_workers;
use cyclic_dp::comm::{tags, Endpoint, Fabric, WireConfig, WireKind};
use cyclic_dp::coordinator::{multi, SharedBackend};
use cyclic_dp::parallel::Rule;
use cyclic_dp::runtime::NativeBackend;
use cyclic_dp::testing::instrument;

fn rdv(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cdp-bench-wire-{label}-{}", std::process::id()))
}

fn main() {
    let b = harness::Bench::new("wire");
    let mut stats: Vec<harness::Stat> = Vec::new();
    let mut counters: Vec<(String, f64)> = Vec::new();

    // ---- p2p latency: channels vs framed sockets --------------------------
    // Same 64 KiB tagged payload, same deadline/dedup recv path; the only
    // difference is whether the bytes cross a socket with frame headers
    // and a CRC, or an in-process channel node.
    b.section("p2p send_copy+recv 64KiB: channels vs wire");
    let buf = vec![1.0f32; 16_384];
    {
        let (mut eps, _) = Fabric::new(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let mut t = 0u64;
        stats.push(b.time_stat("in-process channels", 8, 64, || {
            e0.send_copy(1, tags::grad(t, 0), &buf).unwrap();
            std::hint::black_box(e1.recv(0, tags::grad(t, 0)).unwrap());
            t += 1;
        }));
    }
    for (kind, label) in [(WireKind::Uds, "uds loopback"), (WireKind::Tcp, "tcp loopback")] {
        let dir = rdv(kind.name());
        let cfg = WireConfig::new(kind, &dir, 2);
        let (mut eps, _) = Fabric::wire(&cfg).unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let mut t = 0u64;
        stats.push(b.time_stat(label, 8, 64, || {
            e0.send_copy(1, tags::grad(t, 0), &buf).unwrap();
            std::hint::black_box(e1.recv(0, tags::grad(t, 0)).unwrap());
            t += 1;
        }));
        drop(e0);
        drop(e1);
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- trainer throughput + eager overlap over the wire -----------------
    // The multi ring trainer runs unchanged over wire endpoints; its
    // eager bucketed reduction must still start before the cluster's
    // last backward stage completes even with socket latency in the way.
    b.section("multi ring over uds (native mlp, eager overlap)");
    let shared = SharedBackend(Arc::new(NativeBackend::default_mlp()));
    let n = shared.manifest().n_microbatches;

    let run_ring = |label: &str, record: bool| {
        let dir = rdv(label);
        let cfg = WireConfig::new(WireKind::Uds, &dir, n);
        let (endpoints, wire_stats) = Fabric::wire(&cfg).unwrap();
        if record {
            wire_stats.enable_timeline();
        }
        let eps: Arc<Vec<Mutex<Option<Endpoint>>>> =
            Arc::new(endpoints.into_iter().map(|e| Mutex::new(Some(e))).collect());
        let shared_c = shared.clone();
        let steps = if record { 1 } else { 2 };
        run_workers(n, move |w| {
            let mut ep = eps[w].lock().unwrap().take().unwrap();
            multi::run_worker(
                &shared_c,
                &Rule::CdpV2,
                multi::CommPattern::Ring,
                steps,
                multi::MultiOpts {
                    record_timeline: record,
                    ..Default::default()
                },
                None,
                &mut ep,
            )
            .unwrap()
        });
        std::fs::remove_dir_all(&dir).ok();
        wire_stats
    };

    stats.push(b.time_stat("multi ring 2 steps over uds (cdp_v2)", 1, 3, || {
        std::hint::black_box(run_ring("ring-timed", false));
    }));

    // a single step, so overlap cannot come from step interleaving
    let tl = run_ring("ring-timeline", true);
    let digest = instrument::overlap_from_stats(&tl)
        .expect("grad sends and bwd marks recorded");
    let (first_send, last_bwd) = (digest.first_grad_send_ns, digest.last_bwd_done_ns);
    assert!(
        digest.overlapped(),
        "eager reduction over the wire must start before the last backward \
         completes (first send {first_send} ns vs last bwd {last_bwd} ns)"
    );
    println!(
        "  wire overlap: first grad send at {first_send} ns, last bwd done at {last_bwd} ns"
    );
    counters.push(("wire_overlap_first_send_ns".into(), first_send as f64));
    counters.push(("wire_overlap_last_bwd_ns".into(), last_bwd as f64));
    counters.push(("wire_eager_starts_before_last_bwd".into(), 1.0));
    counters.push(("wire_workers".into(), n as f64));

    harness::write_json("BENCH_wire.json", "wire", &stats, &counters);
}
