//! Shared bench harness (criterion is unavailable offline — DESIGN.md
//! substitution #4).  Each bench binary is `harness = false` and prints a
//! table of timed sections; `cargo bench` runs them all.

#![allow(dead_code)]

use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("\n════════ bench: {name} ════════");
        Self { name: name.to_string() }
    }

    /// Time `f` with warmup and report mean ± std / min.
    pub fn time<F: FnMut()>(&self, label: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let mean: Duration = samples.iter().sum::<Duration>() / iters as u32;
        let min = samples.iter().min().copied().unwrap_or_default();
        let max = samples.iter().max().copied().unwrap_or_default();
        println!(
            "  {label:<44} mean {:>10} | min {:>10} | max {:>10} | n={iters}",
            fmt(mean),
            fmt(min),
            fmt(max)
        );
    }

    pub fn section(&self, label: &str) {
        println!("---- {label} ----");
    }
}

pub fn fmt(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Artifacts guard: returns false (and prints) when a bundle is missing.
pub fn have_bundle(name: &str) -> bool {
    let ok = cyclic_dp::model::artifacts_root()
        .join(name)
        .join("manifest.json")
        .exists();
    if !ok {
        println!("SKIP: bundle `{name}` not built — run `make artifacts`");
    }
    ok
}
