//! Shared bench harness (criterion is unavailable offline — DESIGN.md
//! substitution #4).  Each bench binary is `harness = false` and prints a
//! table of timed sections; `cargo bench` runs them all.

#![allow(dead_code)]

use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
}

/// One timed measurement, machine-readable (see [`write_json`]).
#[derive(Clone, Debug)]
pub struct Stat {
    pub label: String,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("\n════════ bench: {name} ════════");
        Self { name: name.to_string() }
    }

    /// Time `f` with warmup and report mean ± std / min.
    pub fn time<F: FnMut()>(&self, label: &str, warmup: usize, iters: usize, f: F) {
        let _ = self.time_stat(label, warmup, iters, f);
    }

    /// Like [`Self::time`], but also returns the measurement for reports.
    pub fn time_stat<F: FnMut()>(
        &self,
        label: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> Stat {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let mean: Duration = samples.iter().sum::<Duration>() / iters as u32;
        let min = samples.iter().min().copied().unwrap_or_default();
        let max = samples.iter().max().copied().unwrap_or_default();
        println!(
            "  {label:<44} mean {:>10} | min {:>10} | max {:>10} | n={iters}",
            fmt(mean),
            fmt(min),
            fmt(max)
        );
        Stat {
            label: label.to_string(),
            mean_ns: mean.as_nanos() as f64,
            min_ns: min.as_nanos() as f64,
            max_ns: max.as_nanos() as f64,
            iters,
        }
    }

    pub fn section(&self, label: &str) {
        println!("---- {label} ----");
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// The commit this report was measured at: `GITHUB_SHA` in CI, else the
/// local `git rev-parse HEAD`, else "unknown" — embedded in every report
/// so the uploaded BENCH_*.json artifacts form a commit-keyed trajectory.
pub fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Write a machine-readable bench report: a list of timings plus named
/// scalar counters (allocation counts, pool hit rates, ...), stamped
/// with the measured commit's git SHA.
pub fn write_json(path: &str, bench: &str, stats: &[Stat], counters: &[(String, f64)]) {
    let mut out = String::new();
    out.push_str(&format!("{{\n  \"bench\": \"{}\",\n", json_escape(bench)));
    out.push_str(&format!("  \"git_sha\": \"{}\",\n", json_escape(&git_sha())));
    out.push_str("  \"timings\": [\n");
    for (i, s) in stats.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"label\": \"{}\", \"mean_ns\": {:.0}, \"min_ns\": {:.0}, \"max_ns\": {:.0}, \"iters\": {}}}{}\n",
            json_escape(&s.label),
            s.mean_ns,
            s.min_ns,
            s.max_ns,
            s.iters,
            if i + 1 < stats.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"counters\": {\n");
    for (i, (k, v)) in counters.iter().enumerate() {
        out.push_str(&format!(
            "    \"{}\": {}{}\n",
            json_escape(k),
            v,
            if i + 1 < counters.len() { "," } else { "" }
        ));
    }
    out.push_str("  }\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

pub fn fmt(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Artifacts guard: returns false (and prints) when a bundle is missing.
pub fn have_bundle(name: &str) -> bool {
    let ok = cyclic_dp::model::artifacts_root()
        .join(name)
        .join("manifest.json")
        .exists();
    if !ok {
        println!("SKIP: bundle `{name}` not built — run `make artifacts`");
    }
    ok
}
