//! Table 2 (short form): held-out accuracy for DP / CDP-v1 / CDP-v2 on the
//! synthetic classification task (mlp bundle; `examples/classify.rs
//! --bundle convnet --seeds 5` is the full-depth run recorded in
//! EXPERIMENTS.md).  The paper's claim under test: the three rules land
//! within noise of each other.

mod harness;

use cyclic_dp::coordinator::single::RefTrainer;
use cyclic_dp::parallel::rule_by_name;
use cyclic_dp::runtime::NativeBackend;

fn main() {
    let b = harness::Bench::new("table2_accuracy");
    // native backend: an on-disk mlp bundle when `make artifacts` ran,
    // else the synthetic in-memory one — either way no XLA needed
    let rt = NativeBackend::load_or_synthetic("mlp").unwrap();
    let steps = 40;

    b.section(&format!("mlp bundle ({}), {steps} steps (short)", rt.manifest.name));
    println!("{:<8} {:>8} {:>8}", "rule", "final", "acc");
    for rule_name in ["dp", "cdp_v1", "cdp_v2"] {
        let rule = rule_by_name(rule_name).unwrap();
        let mut t = RefTrainer::new(&rt, rule).unwrap();
        let logs = t.train(steps).unwrap();
        let acc = t.accuracy(8).unwrap();
        println!(
            "{:<8} {:>8.4} {:>7.2}%",
            rule_name,
            logs.last().unwrap().loss,
            acc * 100.0
        );
    }

    b.section("per-step cost of each rule (same compute, different versions)");
    for rule_name in ["dp", "cdp_v2"] {
        let rule = rule_by_name(rule_name).unwrap();
        let mut t = RefTrainer::new(&rt, rule).unwrap();
        b.time(&format!("train step ({rule_name})"), 2, 10, || {
            t.step().unwrap();
        });
    }
}
